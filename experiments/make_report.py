"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
experiments/dryrun/*.json.  Usage:

    PYTHONPATH=src python experiments/make_report.py > experiments/report_tables.md
"""
import glob
import json
import os
import sys

HERE = os.path.dirname(__file__)
DRY = os.path.join(HERE, "dryrun")


def load():
    recs = []
    for p in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        r = json.load(open(p))
        r["_file"] = os.path.basename(p)
        # variant is authoritative in the FILE NAME (pre/post-optimization
        # baselines are renamed on disk, meta is not rewritten)
        stem = r["_file"][: -len(".json")]
        parts = stem.split("__")
        if "meta" in r:
            r["meta"]["variant"] = parts[3] if len(parts) > 3 else "baseline"
            pb = r["meta"].get("param_bytes_global", 0)
            if pb < 0:                      # early int32-overflow artifact
                r["meta"]["param_bytes_global"] = 0
        recs.append(r)
    return recs


def pick(recs, arch, shape, mesh, variants):
    """Best available record for a cell, preferring earlier variants."""
    got = {r["meta"].get("variant", "baseline"): r for r in recs
           if r.get("meta", {}).get("arch") == arch
           and r["meta"].get("shape") == shape
           and (("multi" if r["meta"].get("multi_pod") else "single")
                == mesh)}
    for v in variants:
        if v in got:
            return got[v]
    return None


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6)):
        if x >= div:
            return f"{x / div:.1f}{unit}"
    return f"{x:.0f}B"


def main():
    recs = load()
    from repro.configs import SHAPES, list_archs

    # ---------------------------------------------------------- dry-run ---
    print("### Dry-run status matrix (compile pass/fail per mesh)\n")
    print("| arch | shape | 16x16 | 2x16x16 | params | opt+param+cache bytes/dev (16x16) |")
    print("|---|---|---|---|---|---|")
    for arch in list_archs():
        for shape in SHAPES:
            row = []
            for mesh in ("single", "multi"):
                r = pick(recs, arch, shape, mesh,
                         ("baseline", "unrolled", "unrolled_fp32attn"))
                row.append(r)
            s = row[0]
            if s is None:
                continue
            stat = []
            for r in row:
                if r is None:
                    stat.append("—")
                elif r["status"] == "ok":
                    stat.append("ok")
                elif r["status"] == "skipped":
                    stat.append("skip")
                else:
                    stat.append("ERR")
            m = s.get("meta", {})
            dev_bytes = ""
            if s["status"] == "ok":
                ma = s.get("memory_analysis", {})
                tot = (ma.get("argument_size_in_bytes", 0)
                       + ma.get("temp_size_in_bytes", 0))
                dev_bytes = fmt_b(tot)
            print(f"| {arch} | {shape} | {stat[0]} | {stat[1]} | "
                  f"{m.get('params_total', 0) / 1e9:.1f}B | {dev_bytes} |")

    # --------------------------------------------------------- roofline ---
    print("\n### Roofline (single-pod 16x16; unrolled per-layer accounting"
          " where available)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| MODEL_FLOPS/dev | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in list_archs():
        for shape in SHAPES:
            r = pick(recs, arch, shape, "single",
                     ("unrolled", "unrolled_fp32attn", "baseline"))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {arch} | {shape} | — | — | — | skipped "
                      f"(sub-quadratic rule) | — | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | ERR | | | | | | |")
                continue
            rl = r["roofline"]
            m = r["meta"]
            fl = r["cost_analysis"].get("flops", 0)
            mf_dev = m["model_flops"] / m["devices"]
            useful = mf_dev / fl if fl else 0
            bound = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
            frac = rl["compute_s"] / bound if bound else 0
            v = m.get("variant")
            tag = {"unrolled_fp32attn": "*", "baseline": "†"}.get(v, "")
            print(f"| {arch} | {shape}{tag} | {rl['compute_s']:.2e} | "
                  f"{rl['memory_s']:.2e} | {rl['collective_s']:.2e} | "
                  f"{rl['dominant'].replace('_s', '')} | {mf_dev:.2e} | "
                  f"{useful:.3f} | {frac:.3f} |")
    print("\n(*) = pre-optimization accounting (fp32-upcast attention"
          " baseline); see §Perf.")
    print("(†) = rolled accounting (scan bodies counted once by XLA —"
          " FLOPs/bytes understate by ~num_layers; compile-proof only).")

    # ------------------------------------------------- collective detail ---
    print("\n### Collective mix (selected cells, bytes/device)\n")
    print("| cell | all-gather | all-reduce | reduce-scatter | all-to-all "
          "| collective-permute |")
    print("|---|---|---|---|---|---|")
    for arch, shape, variants in [
        ("gemma2-9b", "prefill_32k", ("unrolled", "unrolled_fp32attn")),
        ("gemma2-9b", "train_4k", ("unrolled", "unrolled_fp32attn")),
        ("kimi-k2-1t-a32b", "prefill_32k", ("unrolled", "baseline")),
        ("arctic-480b", "train_4k", ("unrolled", "baseline")),
        ("qwen2-72b", "decode_32k", ("unrolled", "unrolled_fp32attn")),
    ]:
        r = pick(recs, arch, shape, "single", variants)
        if r is None or r["status"] != "ok":
            continue
        c = r["collectives"]
        print(f"| {arch}/{shape} | {fmt_b(c.get('all-gather', 0))} | "
              f"{fmt_b(c.get('all-reduce', 0))} | "
              f"{fmt_b(c.get('reduce-scatter', 0))} | "
              f"{fmt_b(c.get('all-to-all', 0))} | "
              f"{fmt_b(c.get('collective-permute', 0))} |")

    # ------------------------------------------------------ perf deltas ---
    print("\n### §Perf raw deltas\n")
    pairs = [
        ("qwen2-72b decode_32k attention precision",
         ("qwen2-72b", "decode_32k", "unrolled_fp32attn"),
         ("qwen2-72b", "decode_32k", "unrolled")),
        ("gemma2-9b decode_32k attention precision",
         ("gemma2-9b", "decode_32k", "unrolled_fp32attn"),
         ("gemma2-9b", "decode_32k", "unrolled")),
        ("gemma2-9b prefill_32k SP -> Megatron-TP",
         ("gemma2-9b", "prefill_32k", "unrolled"),
         ("gemma2-9b", "prefill_32k", "nsp_unrolled")),
        ("kimi prefill_32k SP -> Megatron-TP",
         ("kimi-k2-1t-a32b", "prefill_32k", "unrolled"),
         ("kimi-k2-1t-a32b", "prefill_32k", "nsp_unrolled")),
        ("arctic-480b decode_32k dedup pool vs 6x dense",
         ("arctic-480b", "decode_32k", "dedup_serving_dense_ref"),
         ("arctic-480b", "decode_32k", "dedup_serving")),
        ("gemma2-9b decode_32k dedup pool vs 6x dense",
         ("gemma2-9b", "decode_32k", "dedup_serving_dense_ref"),
         ("gemma2-9b", "decode_32k", "dedup_serving")),
    ]
    for label, a, b in pairs:
        ra = pick(recs, a[0], a[1], "single", (a[2],))
        rb = pick(recs, b[0], b[1], "single", (b[2],))
        if not ra or not rb or ra["status"] != "ok" or rb["status"] != "ok":
            print(f"- {label}: (pending)")
            continue
        ca, cb = ra["cost_analysis"], rb["cost_analysis"]
        ma = ra.get("memory_analysis", {})
        mb = rb.get("memory_analysis", {})
        print(f"- **{label}**: flops {ca.get('flops', 0):.3e} -> "
              f"{cb.get('flops', 0):.3e}; bytes {ca.get('bytes accessed', 0):.3e}"
              f" -> {cb.get('bytes accessed', 0):.3e}; collective "
              f"{ra['collectives']['weighted_total']:.3e} -> "
              f"{rb['collectives']['weighted_total']:.3e}; "
              f"args/dev {fmt_b(ma.get('argument_size_in_bytes', 0))} -> "
              f"{fmt_b(mb.get('argument_size_in_bytes', 0))}; "
              f"params {fmt_b(ra['meta'].get('param_bytes_global', 0))} -> "
              f"{fmt_b(rb['meta'].get('param_bytes_global', 0))}")


if __name__ == "__main__":
    main()
