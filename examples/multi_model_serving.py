"""End-to-end driver (the paper's kind: SERVING): batched requests across
six word2vec-style model variants, served out of the deduplicated page
store through the Eq.-2 buffer pool, with accuracy verification.

    PYTHONPATH=src python examples/multi_model_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.serving import (BatchComputeModel, EmbeddingServingEngine,
                           OpenLoopTraffic, Prefetcher, ServingFrontend,
                           StorageModel, WeightServer)


def serve_once(store, heads, task, *, scheduler, overlap, prefetch,
               label):
    # memory-pressured pool on simulated SSD, Eq.-2-aware eviction
    server = WeightServer(store, capacity_pages=store.num_pages() // 2,
                          policy="optimized_mru",
                          storage=StorageModel("ssd", jitter=0.5,
                                               hedge_after=0.002))
    engine = EmbeddingServingEngine(
        server, heads, scheduler=scheduler,
        prefetcher=Prefetcher(server) if prefetch else None,
        overlap=overlap)

    rng = np.random.default_rng(1)
    eval_sets = {}
    for b in range(80):
        v = int(rng.integers(0, 6))
        docs, labels = task.sample(32, variant=v, seed=100 + b)
        eval_sets[b] = (f"word2vec-v{v}", docs, labels)
        engine.submit(f"word2vec-v{v}", docs)
    stats = engine.run()

    print(f"[{label}]")
    print(f"  served {stats.requests} requests in {stats.batches} batches")
    print(f"  cache hit ratio : {server.pool.hit_ratio:.3f}")
    print(f"  virtual I/O time: {stats.fetch_seconds * 1e3:.2f} ms demand "
          f"+ {stats.prefetch_seconds * 1e3:.2f} ms prefetch")
    print(f"  compute time    : {stats.compute_seconds * 1e3:.2f} ms")
    print(f"  end-to-end      : {stats.makespan_seconds * 1e3:.2f} ms")
    print(f"  p50 / p99       : {stats.percentile(50) * 1e3:.2f} / "
          f"{stats.percentile(99) * 1e3:.2f} ms")
    return stats, eval_sets


def serve_traffic(store, heads, task, *, rate, label):
    """Open-loop request traffic (Poisson arrivals, Zipf popularity)
    through the SLO-driven frontend: individual requests arrive over
    virtual time, merge into batches under a 25ms SLO, and hopeless
    requests are shed instead of served dead-on-arrival."""
    server = WeightServer(store, capacity_pages=store.num_pages() // 2,
                          policy="optimized_mru",
                          storage=StorageModel("ssd"))
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    overlap=True)

    def payload(model, rid, rng):
        v = int(model.rsplit("-v", 1)[1])
        docs, _ = task.sample(4, variant=v, seed=10_000 + rid)
        return docs

    gen = OpenLoopTraffic([f"word2vec-v{v}" for v in range(6)],
                          rate=rate, zipf_alpha=1.1, slo_s=0.025,
                          seed=7, payload_fn=payload)
    frontend = ServingFrontend(engine, max_batch=8,
                               compute_model=BatchComputeModel())
    stats = frontend.run(gen.generate(160))
    served = len(stats.request_latencies)
    print(f"[{label}]")
    print(f"  offered {stats.offered_requests} requests at {rate:g}/s, "
          f"served {served}, shed {stats.shed_requests}, "
          f"missed SLO {stats.slo_misses}")
    print(f"  goodput         : {stats.goodput:.3f}")
    if served:
        print(f"  request p50/p99 : "
              f"{stats.request_percentile(50) * 1e3:.2f} / "
              f"{stats.request_percentile(99) * 1e3:.2f} ms")
    return stats


def main():
    task = SyntheticTextTask(vocab=2048, d=64, seed=0)
    store, heads = build_store(task, num_models=6)
    print(f"store: {store.num_pages()} pages, "
          f"{store.dense_bytes() / store.storage_bytes():.2f}x reduction")

    serial, eval_sets = serve_once(
        store, heads, task, scheduler="round_robin", overlap=False,
        prefetch=False, label="serial round-robin (baseline)")
    asynch, _ = serve_once(
        store, heads, task, scheduler="dedup_affinity", overlap=True,
        prefetch=True, label="async dedup-affinity + prefetch")
    print(f"end-to-end speedup: "
          f"{serial.makespan_seconds / asynch.makespan_seconds:.2f}x")

    serve_traffic(store, heads, task, rate=2000,
                  label="open-loop traffic @ 2000 req/s, 25ms SLO")

    # verify served accuracy against the deduplicated weights
    correct = total = 0
    for b, (name, docs, labels) in eval_sets.items():
        emb = store.materialize(name, "embedding")
        pred = (emb[docs].mean(axis=1) @ heads[name]).argmax(axis=1)
        correct += int((pred == labels).sum())
        total += len(labels)
    print(f"accuracy        : {correct / total:.3f}")


if __name__ == "__main__":
    main()
