"""Quickstart: deduplicate three fine-tuned embedding models, pack them
into pages, and reconstruct them — the paper's Fig.-3 pipeline in ~40
lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from repro.core.blocks import block_tensor
from repro.core.lsh import estimate_r


def main():
    rng = np.random.default_rng(0)
    base = (rng.standard_normal((1024, 128)) * 0.05).astype(np.float32)

    # three fine-tuned variants of one pretrained weight matrix
    variants = {}
    for v in range(3):
        delta = np.zeros_like(base)
        rows = rng.choice(1024, 80, replace=False)       # light fine-tune
        delta[rows] = rng.standard_normal((80, 128)).astype(np.float32) * 0.02
        variants[f"model-v{v}"] = {"weights": base + delta}

    # configure: L2-LSH index (Sec. 4) + two-stage page packing (Sec. 5)
    blocks, _ = block_tensor(base, (64, 64))
    cfg = StoreConfig(
        dedup=DedupConfig(block_shape=(64, 64),
                          lsh=LSHConfig(num_bands=16, rows_per_band=4,
                                        r=estimate_r(blocks, quantile=0.5),
                                        collision_threshold=8),
                          validate=False),
        blocks_per_page=8, pack_strategy="two_stage")
    store = ModelStore(cfg)

    for name, tensors in variants.items():
        res = store.register(name, tensors)
        print(f"registered {name}: {res.deduped_blocks}/{res.total_blocks} "
              f"blocks deduplicated")

    pk = store.repack()
    print(f"\npages: {pk.num_pages} ({pk.num_shared_pages()} shared)")
    print(f"dense storage : {store.dense_bytes() / 2**20:.2f} MiB")
    print(f"dedup storage : {store.storage_bytes() / 2**20:.2f} MiB "
          f"({store.dense_bytes() / store.storage_bytes():.2f}x reduction)")

    # reconstruct and check
    for name, tensors in variants.items():
        rec = store.materialize(name, "weights")
        err = np.abs(rec - tensors["weights"]).max()
        print(f"{name}: max reconstruction err {err:.4f}")

    # persist into a relational database — the paper's native habitat —
    # then reopen it as a live DedupDB and serve straight out of it
    from repro.db import DedupDB

    url = "sqlite:////tmp/repro_quickstart_models.db"
    store.save(url)                       # pages as BLOBs + relational manifest
    print(f"\ncommitted store to {url}")

    db = DedupDB.open(url)                # live: pages stay in the DB
    for name in variants:
        rec = db.store.materialize(name, "weights")   # faults pages lazily
        assert np.array_equal(rec, store.materialize(name, "weights"))
    print(f"reopened {len(db.models())} models from SQLite, bit-exact")

    # one-call serving: buffer pool + scheduler + microbench-calibrated
    # storage clock, wired by the facade
    heads = {name: rng.standard_normal((128, 8)).astype(np.float32)
             for name in variants}
    engine = db.serve_embedding(heads, embed_tensor="weights",
                                capacity_pages=4)
    for name in variants:
        engine.submit(name, rng.integers(0, 1024, size=(4, 16)))
    stats = engine.run()
    print(f"served {stats.batches} batches from the database "
          f"(hit ratio {engine.server.pool.hit_ratio:.2f})")


if __name__ == "__main__":
    main()
