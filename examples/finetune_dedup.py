"""Dedup-aware fine-tuning (paper Sec. 4.3): register two LM variants,
freeze the shared blocks via gradient masks, fine-tune only the private
blocks of the second variant, and show the page store is unchanged for
shared pages.

    PYTHONPATH=src python examples/finetune_dedup.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from repro.core.finetune import gradient_masks


def main():
    rng = np.random.default_rng(0)
    base = {
        "wq": (rng.standard_normal((256, 256)) * 0.02).astype(np.float32),
        "w1": (rng.standard_normal((256, 512)) * 0.02).astype(np.float32),
    }
    variant = {k: v.copy() for k, v in base.items()}
    variant["w1"][:64] += 0.05        # domain fine-tune touches a corner

    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(64, 64),
                          lsh=LSHConfig(num_bands=16, rows_per_band=4,
                                        r=2.0, collision_threshold=8),
                          validate=False),
        blocks_per_page=4))
    store.register("base", base)
    res = store.register("variant", variant)
    print(f"variant: {res.deduped_blocks}/{res.total_blocks} blocks shared "
          f"with base")

    masks = gradient_masks(store.dedup, "variant")
    frozen = {k: 1.0 - m.mean() for k, m in masks.items()}
    print("frozen fraction per tensor:",
          {k: f"{v:.2f}" for k, v in frozen.items()})

    # simulated fine-tune steps: masked SGD only updates private blocks
    weights = {k: store.materialize("variant", k) for k in variant}
    for step in range(5):
        grads = {k: rng.standard_normal(w.shape).astype(np.float32) * 0.01
                 for k, w in weights.items()}
        for k in weights:
            weights[k] = weights[k] - grads[k] * masks[k]

    for k in weights:
        shared_region = masks[k] == 0
        assert np.array_equal(weights[k][shared_region],
                              store.materialize("variant", k)[shared_region])
    print("shared blocks bit-identical after fine-tune "
          "(shared pages need no rewrite)")

    # re-register the tuned weights: only private pages change
    before = store.num_pages()
    store.update("variant", weights, approach=2)
    print(f"pages before/after update: {before}/{store.num_pages()}")


if __name__ == "__main__":
    main()
