"""Train a reduced LM for a few hundred steps with checkpoint/restart —
the training substrate behind the dry-run's production-scale train_step.

    PYTHONPATH=src python examples/train_tiny.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


def main():
    ck = "/tmp/repro_train_tiny"
    out = train_main([
        "--arch", "deepseek-7b", "--reduced",
        "--steps", "200", "--batch", "8", "--seq", "64",
        "--lr", "3e-3", "--ckpt", ck, "--ckpt-every", "50",
        "--log-every", "20",
    ])
    losses = out["losses"]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    print(f"checkpoints in {ck}; rerun with --resume auto to continue "
          f"after a failure")


if __name__ == "__main__":
    main()
