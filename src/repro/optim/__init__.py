from .optimizers import (Optimizer, adafactor, adamw, clip_by_global_norm,
                         cosine_schedule, make_optimizer)

__all__ = ["Optimizer", "adafactor", "adamw", "clip_by_global_norm",
           "cosine_schedule", "make_optimizer"]
