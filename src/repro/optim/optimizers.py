"""Optimizers (no external deps): AdamW and Adafactor.

* AdamW: fp32 ``m``/``v`` states (sharded like the params via
  ``state_specs``) — the default for <=100B configs.
* Adafactor: factored second moment over the trailing two dims, no
  momentum — required for the giant MoEs (kimi-k2 1T: fp32 Adam states
  alone would be 8 TB, >16 GB/chip at 256-way sharding).

Updates are computed in fp32 and cast back to the param dtype (bf16
params act as their own master copy at these batch sizes; the
roofline/§Perf analysis treats optimizer memory explicitly).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable            # params -> state
    update: Callable          # (grads, state, params) -> (params, state)
    state_specs: Callable     # param_specs pytree -> state specs pytree


def cosine_schedule(base_lr: float, warmup: int = 200,
                    total: int = 10_000, min_frac: float = 0.1):
    def lr(step):
        step = step.astype(F32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale), grads), norm


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          schedule=None, max_grad_norm: float = 1.0) -> Optimizer:
    sched = schedule or (lambda s: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(F32)
        bc2 = 1 - b2 ** step.astype(F32)

        def upd(g, m, v, p):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": step, "m": new_m, "v": new_v}, gnorm

    def state_specs(params_sds, pspecs):
        return {"step": P(),
                "m": pspecs,
                "v": pspecs}

    return Optimizer(init, update, state_specs)


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, weight_decay=0.0,
              schedule=None, max_grad_norm: float = 1.0) -> Optimizer:
    sched = schedule or (lambda s: lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], F32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros(p.shape, F32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr_t = sched(step)
        beta = 1.0 - (step.astype(F32) + 1.0) ** (-decay)

        def upd(g, v, p):
            g2 = g * g + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1,
                                               keepdims=True)[..., None],
                                       eps))
                u = g / jnp.sqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                nvv = beta * v["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(nvv, eps))
                nv = {"v": nvv}
            # update clipping (Shazeer & Stern): RMS(u) <= 1
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms)
            if weight_decay:
                u = u + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr_t * u).astype(p.dtype), nv

        is_state = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        out = jax.tree.map(upd, grads, state["v"], params,
                           is_leaf=lambda x: is_state(x))
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": step, "v": new_v}, gnorm

    def state_specs(params_sds, pspecs):
        def st(sds, spec):
            parts = list(spec)
            parts = parts + [None] * (len(sds.shape) - len(parts))
            if len(sds.shape) >= 2:
                # vr drops the last dim's axis; vc the second-to-last's.
                return {"vr": P(*parts[:-1]),
                        "vc": P(*(parts[:-2] + parts[-1:]))}
            return {"v": P(*parts)}
        return {"step": P(),
                "v": jax.tree.map(st, params_sds, pspecs)}

    return Optimizer(init, update, state_specs)


def make_optimizer(name: str, lr: float = 3e-4,
                   schedule=None) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr, schedule=schedule)
    if name == "adafactor":
        return adafactor(lr=lr, schedule=schedule)
    raise ValueError(f"unknown optimizer {name!r}")
