"""PoolSanitizer — TSan for the page pool.

Opt-in runtime instrumentation that wraps the pool/transfer surfaces
(:class:`~repro.core.bufferpool.BufferPool`,
:class:`~repro.serving.device_pool.DevicePagePool`,
:class:`~repro.serving.shard_pool.ShardedPagePool` and each pool's
:class:`~repro.serving.transfer.TransferEngine`), records
``(generation, slot, page, reader|writer)`` events, and raises
:class:`PoolSanitizerError` on protocol violations the type system
cannot see:

* **stale-remap read** — a compute kernel consuming a ``remap`` built
  under an older (pack_generation, slab generation) pair, or against a
  different pool/shard than the one it was built from;
* **missed generation bump** — a load/evict/flush that mutated the
  residency map without advancing ``generation`` (remap caches keep
  validating against stale slots);
* **one-group-one-bump** — a grouped load that bumps more than once
  (PR 5's contract), or a ``stage()`` that bumps at all;
* **double-load** — re-admitting an already-resident page to a second
  slot;
* **slot aliasing** — two pages mapped to one slab slot, or a mapped
  slot simultaneously on the free list;
* **evict-while-pinned** — the buffer pool evicting a page pinned by an
  in-flight ``access_group``;
* **non-owner shard load** — a shard slab admitting a page the current
  placement does not assign to it (placement-totality, PR 4);
* **borrow-slab aliasing** — the borrow staging tail holding duplicate
  slots, out-of-range slots, or pages that should be served from the
  shard's own slab.

Wrapping is by *instance attribute*: the serving layer looks methods up
at call time (``self.pools[shard].load``), so instance wrappers
intercept every production path without touching the classes.  The
module-level :func:`enable` additionally patches the three classes'
``__init__`` so every pool constructed afterwards is born instrumented —
that is what ``REPRO_SANITIZE=1`` flips on under the whole test suite
(see ``tests/conftest.py`` and DESIGN.md §7).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
import weakref
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["PoolSanitizerError", "PoolEvent", "PoolSanitizer",
           "enable", "disable", "enabled"]


class PoolSanitizerError(AssertionError):
    """A page-pool protocol violation detected at runtime."""


@dataclasses.dataclass(frozen=True)
class PoolEvent:
    """One recorded pool transition (bounded history, newest last)."""
    op: str                  # load / load_group / evict / flush / gather / ...
    role: str                # "reader" | "writer"
    pool: int                # id() of the DevicePagePool / BufferPool
    shard: Optional[int]     # shard index when known
    page: Optional[int]
    slot: Optional[int]
    generation: int


class PoolSanitizer:
    """Records pool events and enforces the DESIGN.md §7 contracts.

    ``strict=True`` raises :class:`PoolSanitizerError` at the violating
    call site; ``strict=False`` accumulates violations in
    :attr:`violations` for post-hoc inspection (useful when probing how
    far a broken protocol drifts before crashing).
    """

    MAX_EVENTS = 4096
    MAX_TAGS = 2048

    def __init__(self, strict: bool = True):
        self.strict = strict
        self.events: "collections.deque[PoolEvent]" = \
            collections.deque(maxlen=self.MAX_EVENTS)
        self.violations: List[str] = []
        # id(dev_map) -> (weakref|None, pool id, pack_gen, slab_gen)
        self._tags: Dict[int, Tuple[Any, int, int, int]] = {}

    # ------------------------------------------------------------- plumbing --
    def _violate(self, message: str) -> None:
        self.violations.append(message)
        if self.strict:
            raise PoolSanitizerError(message)

    def _emit(self, op: str, role: str, pool: Any, shard: Optional[int],
              page: Optional[int], slot: Optional[int],
              generation: int) -> None:
        self.events.append(PoolEvent(op, role, id(pool), shard,
                                     page, slot, generation))

    def report(self) -> str:
        """Human-readable summary of recorded history + violations."""
        lines = [f"PoolSanitizer: {len(self.events)} events recorded, "
                 f"{len(self.violations)} violations"]
        lines += [f"  VIOLATION: {v}" for v in self.violations]
        lines += [f"  {e.op:<12} {e.role:<6} shard={e.shard} page={e.page} "
                  f"slot={e.slot} gen={e.generation}"
                  for e in list(self.events)[-20:]]
        return "\n".join(lines)

    # ------------------------------------------------------- remap tagging --
    def _tag(self, dev_map, pool, shard: Optional[int] = None) -> None:
        if dev_map is None:
            return
        try:
            ref = weakref.ref(dev_map)
        except TypeError:
            ref = None
        if len(self._tags) >= self.MAX_TAGS:
            dead = [k for k, (r, *_rest) in self._tags.items()
                    if r is not None and r() is None]
            for k in dead:
                del self._tags[k]
            if len(self._tags) >= self.MAX_TAGS:
                self._tags.clear()               # last resort: stay bounded
        self._tags[id(dev_map)] = (ref, id(pool),
                                   pool.store.pack_generation,
                                   pool.generation)
        self._emit("remap", "writer", pool, shard, None, None,
                   pool.generation)

    def _check_map(self, pool, dev_map, op: str,
                   shard: Optional[int] = None) -> None:
        tag = self._tags.get(id(dev_map))
        if tag is None:
            return                               # map we never saw minted
        ref, pool_id, pack_gen, slab_gen = tag
        if ref is not None and ref() is not dev_map:
            del self._tags[id(dev_map)]          # id() reuse after gc
            return
        if pool_id != id(pool):
            self._violate(
                f"stale-remap read in {op}: dev_map was built for a "
                "different pool/shard than the one now reading it")
        elif pack_gen != pool.store.pack_generation \
                or slab_gen != pool.generation:
            self._violate(
                f"stale-remap read in {op}: dev_map built at "
                f"(pack {pack_gen}, slab gen {slab_gen}) but the pool is "
                f"now at (pack {pool.store.pack_generation}, slab gen "
                f"{pool.generation}) — rebuild the remap after any "
                "load/evict/flush")
        self._emit(op, "reader", pool, shard, None, None, pool.generation)

    # ----------------------------------------------------- slot invariants --
    def _check_slots(self, pool, op: str) -> None:
        slots = list(pool.slot_of.values())
        if len(set(slots)) != len(slots):
            owners: Dict[int, List[int]] = {}
            for pid, s in pool.slot_of.items():
                owners.setdefault(s, []).append(pid)
            aliased = {s: ps for s, ps in owners.items() if len(ps) > 1}
            self._violate(f"slot aliasing after {op}: pages sharing one "
                          f"slab slot: {aliased}")
        leaked = set(slots) & set(pool._free)
        if leaked:
            self._violate(f"slot bookkeeping after {op}: slots {sorted(leaked)} "
                          "are mapped to pages AND on the free list")

    # ------------------------------------------------------ DevicePagePool --
    def attach_device_pool(self, pool, shard: Optional[int] = None):
        """Wrap one DevicePagePool's mutation + compute surface (and its
        TransferEngine's stage path) with recording and checks."""
        if getattr(pool, "_repro_sanitizer", None) is self:
            return pool
        pool._repro_sanitizer = self
        san = self
        orig_load, orig_load_group = pool.load, pool.load_group
        orig_evict, orig_flush = pool.evict, pool.flush
        orig_remap = pool.remap
        orig_stage = pool.transfer.stage

        @functools.wraps(orig_load)
        def load(pid):
            pid = int(pid)
            resident = pid in pool.slot_of
            slot0 = pool.slot_of.get(pid)
            gen0 = pool.generation
            out = orig_load(pid)
            if resident:
                if pool.slot_of.get(pid) != slot0 or pool.generation != gen0:
                    san._violate(
                        f"double-load: page {pid} was already resident in "
                        f"slot {slot0} but load() re-admitted it "
                        f"(slot now {pool.slot_of.get(pid)})")
            else:
                if pid not in pool.slot_of:
                    san._violate(f"load({pid}) returned without admitting "
                                 "the page")
                elif pool.generation <= gen0:
                    san._violate(
                        f"missed generation bump: load({pid}) admitted the "
                        f"page into slot {pool.slot_of[pid]} but generation "
                        f"stayed at {gen0} — cached remaps now alias stale "
                        "slots")
            san._check_slots(pool, f"load({pid})")
            san._emit("load", "writer", pool, shard, pid,
                      pool.slot_of.get(pid), pool.generation)
            return out

        @functools.wraps(orig_load_group)
        def load_group(pids):
            pids = [int(p) for p in pids]
            missing = [p for p in dict.fromkeys(pids)
                       if p not in pool.slot_of]
            gen0 = pool.generation
            out = orig_load_group(pids)
            if missing:
                lost = [p for p in missing if p not in pool.slot_of]
                if lost:
                    san._violate(f"load_group did not admit pages {lost}")
                bumps = pool.generation - gen0
                if bumps == 0:
                    san._violate(
                        "missed generation bump: load_group admitted "
                        f"{len(missing)} pages with no generation bump")
                elif bumps > 1:
                    san._violate(
                        f"one-group-one-bump violated: ONE grouped load of "
                        f"{len(missing)} pages bumped generation {bumps} "
                        "times (remap caches invalidated per page, not per "
                        "group)")
            san._check_slots(pool, "load_group")
            san._emit("load_group", "writer", pool, shard, None, None,
                      pool.generation)
            return out

        @functools.wraps(orig_evict)
        def evict(pid):
            pid = int(pid)
            resident = pid in pool.slot_of
            slot0 = pool.slot_of.get(pid)
            gen0 = pool.generation
            out = orig_evict(pid)
            if resident:
                if pid in pool.slot_of:
                    san._violate(f"evict({pid}) left the page mapped to "
                                 f"slot {pool.slot_of[pid]}")
                elif pool.generation <= gen0:
                    san._violate(
                        f"missed generation bump: evict({pid}) freed slot "
                        f"{slot0} but generation stayed at {gen0} — cached "
                        "remaps still point at the freed slot")
            san._check_slots(pool, f"evict({pid})")
            san._emit("evict", "writer", pool, shard, pid, slot0,
                      pool.generation)
            return out

        @functools.wraps(orig_flush)
        def flush():
            gen0 = pool.generation
            had = len(pool.slot_of)
            out = orig_flush()
            if pool.slot_of:
                san._violate(f"flush() left {len(pool.slot_of)} pages "
                             "resident")
            if pool.generation <= gen0:
                san._violate(
                    f"missed generation bump: flush() dropped {had} pages "
                    f"but generation stayed at {gen0}")
            san._emit("flush", "writer", pool, shard, None, None,
                      pool.generation)
            return out

        @functools.wraps(orig_remap)
        def remap(vt, key=None, strict=True):
            out = orig_remap(vt, key=key, strict=strict)
            san._tag(out, pool, shard)
            return out

        def _reader(name):
            orig = getattr(pool, name)

            @functools.wraps(orig)
            def wrapped(dev_map, *a, **k):
                san._check_map(pool, dev_map, name, shard)
                return orig(dev_map, *a, **k)
            return wrapped

        @functools.wraps(orig_stage)
        def stage(pids):
            gen0 = pool.generation
            out = orig_stage(pids)
            if pool.generation != gen0:
                san._violate(
                    "stage() bumped the pool generation: staging must be "
                    "invisible until the group commits (one-group-one-bump)")
            san._emit("stage", "writer", pool, shard, None, None,
                      pool.generation)
            return out

        pool.load, pool.load_group = load, load_group
        pool.evict, pool.flush, pool.remap = evict, flush, remap
        pool.gather_rows = _reader("gather_rows")
        pool.virtual_matmul = _reader("virtual_matmul")
        pool.unblock = _reader("unblock")
        pool.transfer.stage = stage
        return pool

    # ---------------------------------------------------------- BufferPool --
    def attach_buffer_pool(self, bp, shard: Optional[int] = None):
        """Wrap one BufferPool's eviction path (evict-while-pinned)."""
        if getattr(bp, "_repro_sanitizer", None) is self:
            return bp
        bp._repro_sanitizer = self
        san = self
        orig_evict_one = bp._evict_one

        @functools.wraps(orig_evict_one)
        def _evict_one():
            before = set(bp.resident)
            pinned = set(bp._pinned)
            out = orig_evict_one()
            for victim in before - set(bp.resident):
                if victim in pinned:
                    san._violate(
                        f"evict-while-pinned: page {victim} was evicted "
                        "while pinned by an in-flight access_group "
                        f"(pinned set: {sorted(pinned)})")
                san._emit("bp_evict", "writer", bp, shard, victim, None,
                          bp.tick)
            return out

        bp._evict_one = _evict_one
        return bp

    # ----------------------------------------------------- ShardedPagePool --
    def attach_sharded_pool(self, sp):
        """Wrap a ShardedPagePool: per-shard ownership checks on the
        member pools plus borrow-staging aliasing checks."""
        if getattr(sp, "_repro_sanitizer", None) is self:
            return sp
        sp._repro_sanitizer = self
        san = self
        for s, pool in enumerate(sp.pools):
            self.attach_device_pool(pool, shard=s)
            orig_load = pool.load
            orig_load_group = pool.load_group

            def mk(shard, orig, group):
                @functools.wraps(orig)
                def checked(arg):
                    pl = sp.placement()
                    pids = [int(p) for p in arg] if group else [int(arg)]
                    bad = [p for p in pids
                           if shard not in pl.shards_of(p)]
                    if bad:
                        san._violate(
                            f"non-owner shard load: shard {shard} admitted "
                            f"pages {bad} that placement (pack gen "
                            f"{pl.pack_generation}) assigns elsewhere — "
                            "borrowed pages must go through stage_borrows")
                    return orig(arg)
                return checked

            pool.load = mk(s, orig_load, group=False)
            pool.load_group = mk(s, orig_load_group, group=True)
        orig_stage_borrows = sp.stage_borrows

        @functools.wraps(orig_stage_borrows)
        def stage_borrows(shard, pages, model):
            out = orig_stage_borrows(shard, pages, model)
            if out is None:                      # refused (over capacity)
                return out
            st = sp.staged(shard)
            slots = list(st.values())
            if len(set(slots)) != len(slots):
                san._violate(
                    f"borrow-slab aliasing on shard {shard}: two staged "
                    f"pages share a staging slot ({st})")
            oob = [i for i in slots
                   if not 0 <= i < sp.borrow_capacity]
            if oob:
                san._violate(
                    f"borrow-slab aliasing on shard {shard}: staging slots "
                    f"{oob} outside the borrow tail "
                    f"[0, {sp.borrow_capacity})")
            pl = sp.placement()
            for pid in st:
                if shard in pl.shards_of(pid):
                    san._violate(
                        f"borrow-slab aliasing on shard {shard}: page "
                        f"{pid} is owned by this shard — it must be served "
                        "from the shard slab, not the borrow tail")
                elif pid in sp.pools[shard].slot_of:
                    san._violate(
                        f"borrow-slab aliasing on shard {shard}: page "
                        f"{pid} staged in the borrow tail while also "
                        "resident in the shard slab (two sources of truth)")
            san._emit("stage_borrows", "writer", sp, shard, None, None,
                      pl.pack_generation)
            return out

        sp.stage_borrows = stage_borrows
        for s, bp in enumerate(sp.buffer_pools):
            self.attach_buffer_pool(bp, shard=s)
        return sp


# ------------------------------------------------------------ global switch --
_GLOBAL: Optional[PoolSanitizer] = None
_PATCHED: Dict[type, Any] = {}


def enabled() -> Optional[PoolSanitizer]:
    """The process-wide sanitizer, if :func:`enable` has run."""
    return _GLOBAL


def enable(strict: bool = True) -> PoolSanitizer:
    """Instrument every pool constructed from now on (idempotent).

    Patches ``BufferPool/DevicePagePool/ShardedPagePool.__init__`` to
    attach one shared :class:`PoolSanitizer` at construction.  This is
    what ``REPRO_SANITIZE=1`` triggers from ``tests/conftest.py``.
    """
    global _GLOBAL
    if _GLOBAL is not None:
        return _GLOBAL
    san = PoolSanitizer(strict=strict)

    from ..core.bufferpool import BufferPool
    from ..serving.device_pool import DevicePagePool
    from ..serving.shard_pool import ShardedPagePool

    def patch(cls, attach):
        orig = cls.__init__

        @functools.wraps(orig)
        def __init__(self, *a, **k):
            orig(self, *a, **k)
            attach(self)

        cls.__init__ = __init__
        _PATCHED[cls] = orig

    # ShardedPagePool builds its member pools in __init__, so they are
    # device-pool-instrumented first and ownership-wrapped second.
    patch(BufferPool, san.attach_buffer_pool)
    patch(DevicePagePool, san.attach_device_pool)
    patch(ShardedPagePool, san.attach_sharded_pool)
    _GLOBAL = san
    return san


def disable() -> None:
    """Undo :func:`enable` for pools constructed afterwards (already
    attached instances keep their wrappers)."""
    global _GLOBAL
    for cls, orig in _PATCHED.items():
        cls.__init__ = orig
    _PATCHED.clear()
    _GLOBAL = None


if os.environ.get("REPRO_SANITIZE", "") == "1":   # pragma: no cover - env hook
    enable(strict=True)
