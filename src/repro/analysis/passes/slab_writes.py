"""slab-write: the grouped-transfer bypass lint.

PR 5's contract: all device-slab mutation funnels through
``TransferEngine`` (one staged stack -> one ``slab.at[slots].set``
scatter -> ONE generation bump) or ``DevicePagePool``'s own
load/evict/flush bookkeeping.  A ``slab.at[...].set`` (or host-mirror
``host_slab[...] = ...`` assignment, or ``dynamic_update_slice`` on a
slab) anywhere else silently bypasses generation accounting: remaps
built before the write keep validating, and readers gather stale rows.

Suppress a deliberate site with ``# repro: allow-slab-write``.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, LintPass, Source
from .common import call_attr, expr_names

__all__ = ["SlabWritePass"]

# modules that OWN slab mutation (the transfer/bookkeeping layer)
DEFAULT_OWNERS = (
    "repro/serving/transfer.py",
    "repro/serving/device_pool.py",
    "repro/serving/shard_pool.py",
)


def _mentions_slab(node: ast.AST) -> bool:
    return any("slab" in n for n in expr_names(node))


class SlabWritePass(LintPass):
    """Flags direct slab writes outside the transfer layer."""
    name = "slab-write"
    pragma = "allow-slab-write"
    description = ("direct device-slab writes outside the "
                   "TransferEngine/DevicePagePool mutation layer")

    def __init__(self, owners=DEFAULT_OWNERS):
        self.owners = tuple(owners)

    def run(self, src: Source) -> List[Finding]:
        if src.endswith(*self.owners):
            return []
        out: List[Finding] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                attr = call_attr(node)
                # slab.at[slots].set(values)
                if (attr == "set"
                        and isinstance(node.func, ast.Attribute)
                        and isinstance(node.func.value, ast.Subscript)
                        and isinstance(node.func.value.value, ast.Attribute)
                        and node.func.value.value.attr == "at"
                        and _mentions_slab(node.func.value.value.value)):
                    out.append(self.finding(
                        src, node,
                        "direct slab.at[...].set bypasses the grouped "
                        "TransferEngine scatter + generation bump"))
                # jax.lax.dynamic_update_slice(slab, ...)
                elif (attr == "dynamic_update_slice"
                        and any(_mentions_slab(a) for a in node.args)):
                    out.append(self.finding(
                        src, node,
                        "dynamic_update_slice on a slab bypasses the "
                        "grouped TransferEngine scatter"))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Subscript) \
                            and _mentions_slab(t.value):
                        out.append(self.finding(
                            src, node,
                            "in-place slab/mirror write outside the "
                            "transfer layer skips generation accounting"))
                        break
        return [f for f in out if f is not None]
