"""wallclock: no raw ``time.time()`` in the repro tree.

The serving layer's timings are *virtual* (``StorageModel`` /
``FetchComputeTimeline``); where real elapsed time is genuinely wanted
(benchmark harness walls), ``time.perf_counter()`` is the monotonic
choice — ``time.time()`` jumps under NTP and silently corrupts measured
bandwidths.  Sites that truly need wall-clock epoch time carry
``# repro: allow-wallclock``.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, LintPass, Source

__all__ = ["WallClockPass"]


class WallClockPass(LintPass):
    """Flags raw time.time() anywhere in the scanned tree."""
    name = "wallclock"
    pragma = "allow-wallclock"
    description = "raw time.time() where the virtual clock or perf_counter belongs"

    def run(self, src: Source) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(src.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "time"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "time"):
                out.append(self.finding(
                    src, node,
                    "time.time() — use time.perf_counter() for measured "
                    "durations or the virtual clock (StorageModel / "
                    "FetchComputeTimeline) for charged time"))
        return [f for f in out if f is not None]
