"""frontend-clock: the request-level serving tier lives on the virtual
clock — no free latency, no wall time.

The frontend's p50/p99/goodput numbers are *virtual-clock* quantities:
a traffic/frontend code path that measures wall time (even the
otherwise-tolerated ``time.perf_counter()``) would mix nondeterministic
runner noise into a latency distribution the bench guard treats as
deterministic, and a path that dispatches work (``.run(...)`` /
``.generate(...)``) without charging the clock (``advance`` /
``tick_to`` / ``charge_fetch``) serves requests in zero simulated time
— free latency, the exact lie the SLO accounting exists to prevent.

Two rules over the configured frontend files (default:
``serving/frontend.py`` + ``serving/traffic.py``):

  * **no wall time** — any ``time.*()`` call is flagged (the frontend
    has no measured-duration escape hatch; the engines keep theirs).
  * **dispatch charges the clock** — a function that calls ``.run(`` or
    ``.generate(`` must also call ``advance``/``tick_to``/
    ``charge_fetch`` somewhere in its body.

``# repro: allow-untimed`` on the ``def`` line documents a helper whose
caller owns the charge.
"""
from __future__ import annotations

import ast
from typing import List, Sequence

from ..lint import Finding, LintPass, Source
from .common import call_attr, call_root, iter_functions

__all__ = ["FrontendClockPass"]

#: calls that consume simulated service time
DISPATCH_TOKENS = {"run", "generate"}
#: calls that put seconds on the virtual clock
CLOCK_TOKENS = {"advance", "tick_to", "charge_fetch"}

_DEFAULT_FILES = ("serving/frontend.py", "serving/traffic.py")


class FrontendClockPass(LintPass):
    """Pins the traffic/frontend modules to the virtual clock."""
    name = "frontend-clock"
    pragma = "allow-untimed"
    description = ("frontend/traffic paths that consume time without "
                   "charging the virtual clock")

    def __init__(self, files: Sequence[str] = _DEFAULT_FILES):
        self.files = tuple(files)

    def run(self, src: Source) -> List[Finding]:
        if not src.endswith(*self.files):
            return []
        out: List[Finding] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call) and call_root(node) == "time":
                out.append(self.finding(
                    src, node,
                    f"time.{call_attr(node)}() in a frontend module — "
                    "request-level serving is strictly virtual-clock "
                    "(VirtualClock.advance/tick_to); wall time here "
                    "corrupts the deterministic latency distribution"))
        for qual, fn in iter_functions(src.tree):
            dispatches, charges = [], False
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                attr = call_attr(node)
                if attr in DISPATCH_TOKENS \
                        and isinstance(node.func, ast.Attribute):
                    dispatches.append(node)
                if attr in CLOCK_TOKENS:
                    charges = True
            if dispatches and not charges:
                out.append(self.finding(
                    src, fn,
                    f"{qual} dispatches work ("
                    + ", ".join(sorted({call_attr(n) for n in dispatches}))
                    + ") but never charges the virtual clock "
                    "(advance/tick_to/charge_fetch) — free latency; "
                    "charge the clock or mark `# repro: allow-untimed` "
                    "if the caller owns the charge"))
        return [f for f in out if f is not None]
