"""unused: unused/shadowed bindings and dead code.

Four cheap-but-real hygiene checks:

* unused module-level imports (skipped in ``__init__.py`` re-export
  modules; names listed in ``__all__`` or re-exported via the
  ``import x as x`` idiom count as used),
* function locals assigned once and never read (``_``-prefixed names
  are the deliberate-discard idiom and are skipped),
* parameters/assignments that shadow load-bearing builtins
  (``# repro: allow-shadow`` when deliberate),
* statements unreachable after ``return``/``raise``/``break``/
  ``continue``.

Suppress with ``# repro: allow-unused`` / ``# repro: allow-shadow`` on
the line (or the line above).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from ..lint import Finding, LintPass, Source
from .common import iter_functions

__all__ = ["UnusedBindingPass"]

SHADOW_BUILTINS = {
    "id", "list", "dict", "set", "tuple", "type", "input", "filter",
    "map", "sum", "min", "max", "vars", "next", "iter", "hash", "len",
    "str", "int", "float", "bytes", "all", "any", "open", "eval",
    "format", "sorted", "zip", "range", "object", "dir", "abs",
    "round", "pow", "print", "bool",
}
_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)
_TERMINAL = (ast.Return, ast.Raise, ast.Break, ast.Continue)


def _loaded_names(tree: ast.AST) -> set:
    out = {n.id for n in ast.walk(tree)
           if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
    # `x += ...` reads x even though the target's ctx is Store (and when
    # x is a numpy view, the "store" IS the read-modify-write the caller
    # wants) — AugAssign names count as used
    out |= {n.target.id for n in ast.walk(tree)
            if isinstance(n, ast.AugAssign)
            and isinstance(n.target, ast.Name)}
    return out


class UnusedBindingPass(LintPass):
    """Unused imports/locals, builtin shadowing, dead code."""
    name = "unused"
    pragma = "allow-unused"
    description = "unused imports/locals, shadowed builtins, dead code"

    def _mk(self, src: Source, node: ast.AST, message: str,
            token: str) -> Optional[Finding]:
        line = getattr(node, "lineno", 1)
        if src.allowed(line, token):
            return None
        return Finding(src.path, line, getattr(node, "col_offset", 0),
                       self.name, message)

    # -- unused module-level imports -----------------------------------------
    def _check_imports(self, src: Source) -> List[Optional[Finding]]:
        if src.path.endswith("__init__.py"):
            return []
        used = _loaded_names(src.tree)
        used |= {n.attr for n in ast.walk(src.tree)
                 if isinstance(n, ast.Attribute)}
        exported = set()
        for node in src.tree.body:
            if isinstance(node, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == "__all__"
                            for t in node.targets) \
                    and isinstance(node.value, (ast.List, ast.Tuple)):
                exported |= {e.value for e in node.value.elts
                             if isinstance(e, ast.Constant)}
        out: List[Optional[Finding]] = []
        for node in src.tree.body:
            if not isinstance(node, (ast.Import, ast.ImportFrom)):
                continue
            if isinstance(node, ast.ImportFrom) \
                    and node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                if a.asname == a.name and a.asname is not None:
                    continue                     # `import x as x` re-export
                bound = a.asname or a.name.split(".")[0]
                if bound in used or bound in exported:
                    continue
                out.append(self._mk(
                    src, node, f"import `{bound}` is never used",
                    "allow-unused"))
        return out

    # -- unused locals -------------------------------------------------------
    def _check_locals(self, src: Source) -> List[Optional[Finding]]:
        out: List[Optional[Finding]] = []
        for qual, fn in iter_functions(src.tree):
            loads = _loaded_names(fn)
            declared = set()
            for node in ast.walk(fn):
                if isinstance(node, (ast.Global, ast.Nonlocal)):
                    declared |= set(node.names)
            assigns = {}
            for node in ast.walk(fn):
                if isinstance(node, _FUNCS) and node is not fn:
                    continue
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    name = node.targets[0].id
                    assigns.setdefault(name, []).append(node)
            for name, nodes in assigns.items():
                if name.startswith("_") or name in loads \
                        or name in declared or len(nodes) > 1:
                    continue
                out.append(self._mk(
                    src, nodes[0],
                    f"local `{name}` in {qual} is assigned but never read",
                    "allow-unused"))
        return out

    # -- shadowed builtins ---------------------------------------------------
    def _check_shadows(self, src: Source) -> List[Optional[Finding]]:
        out: List[Optional[Finding]] = []
        for qual, fn in iter_functions(src.tree):
            a = fn.args
            for p in a.posonlyargs + a.args + a.kwonlyargs:
                if p.arg in SHADOW_BUILTINS:
                    out.append(self._mk(
                        src, p,
                        f"parameter `{p.arg}` of {qual} shadows a builtin",
                        "allow-shadow"))
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id in SHADOW_BUILTINS:
                        out.append(self._mk(
                            src, node,
                            f"assignment to `{t.id}` shadows a builtin",
                            "allow-shadow"))
        return out

    # -- dead code -----------------------------------------------------------
    def _check_dead(self, src: Source) -> List[Optional[Finding]]:
        out: List[Optional[Finding]] = []
        for node in ast.walk(src.tree):
            for field in ("body", "orelse", "finalbody"):
                block = getattr(node, field, None)
                if not isinstance(block, list):
                    continue
                for i, stmt in enumerate(block[:-1]):
                    if isinstance(stmt, _TERMINAL):
                        out.append(self._mk(
                            src, block[i + 1],
                            "unreachable statement after "
                            f"`{type(stmt).__name__.lower()}`",
                            "allow-unused"))
                        break
        return out

    def run(self, src: Source) -> List[Finding]:
        out = (self._check_imports(src) + self._check_locals(src)
               + self._check_shadows(src) + self._check_dead(src))
        return [f for f in out if f is not None]
