"""api-drift: public-API docstring/signature drift checks.

Three checks:

* ``__all__`` consistency (every scanned file): each exported name must
  actually be defined at module top level, and must appear only once.
* docstring presence (API-surface modules only): public top-level
  classes/functions must carry a docstring — the serving/storage layers
  ARE the repo's API, and an undocumented entry point is where protocol
  contracts silently drift.
* kwarg drift (API-surface modules): a docstring that names a keyword
  as ``arg=`` must refer to a parameter the signature still has —
  the classic drift is renaming a parameter and leaving the docstring
  advertising the old spelling.

``# repro: allow-drift`` on the ``def``/``class`` line suppresses the
docstring checks for that object.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Sequence

from ..lint import Finding, LintPass, Source

__all__ = ["ApiDriftPass", "DEFAULT_API_SURFACE"]

# modules whose public surface must stay documented and drift-free
DEFAULT_API_SURFACE = (
    "repro/serving/", "repro/storage/", "repro/analysis/",
    "repro/core/store.py", "repro/core/bufferpool.py", "repro/db.py",
)

# ``name=value`` (no space: ``seconds = seek + b/bw`` is an equation,
# not a kwarg reference), and not ``name==`` comparisons
_KWARG_RE = re.compile(r"``([a-z_][A-Za-z0-9_]*)=(?!=)")
_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _top_level_names(tree: ast.Module) -> set:
    names = set()
    for node in tree.body:
        if isinstance(node, _FUNCS + (ast.ClassDef,)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        names.add(n.id)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            names.add(node.target.id)
        elif isinstance(node, ast.Import):
            for a in node.names:
                names.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                names.add(a.asname or a.name)
        elif isinstance(node, (ast.If, ast.Try)):
            for sub in ast.walk(node):
                if isinstance(sub, _FUNCS + (ast.ClassDef,)):
                    names.add(sub.name)
                elif isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if isinstance(t, ast.Name):
                            names.add(t.id)
    return names


def _params(fn) -> set:
    a = fn.args
    out = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    if a.vararg:
        out.add(a.vararg.arg)
    if a.kwarg:
        out.add(a.kwarg.arg)
    return out


def _has_kwargs(fn) -> bool:
    return fn.args.kwarg is not None


class ApiDriftPass(LintPass):
    """__all__ consistency, docstring presence, kwarg drift."""
    name = "api-drift"
    pragma = "allow-drift"
    description = "__all__ consistency + public docstring/signature drift"

    def __init__(self, surface: Sequence[str] = DEFAULT_API_SURFACE):
        self.surface = tuple(surface)

    def _in_surface(self, src: Source) -> bool:
        return any(s in src.path if s.endswith("/") else src.path.endswith(s)
                   for s in self.surface)

    def _check_all(self, src: Source) -> List[Optional[Finding]]:
        out: List[Optional[Finding]] = []
        for node in src.tree.body:
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "__all__"
                    and isinstance(node.value, (ast.List, ast.Tuple))):
                continue
            exported = [e.value for e in node.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)]
            defined = _top_level_names(src.tree)
            # PEP 562: a module __getattr__ serves lazy exports, so
            # absence from the static top level proves nothing
            lazy = any(isinstance(n, _FUNCS) and n.name == "__getattr__"
                       for n in src.tree.body)
            for name in exported:
                if name not in defined and not lazy:
                    out.append(self.finding(
                        src, node,
                        f"__all__ exports `{name}` which is not defined "
                        "at module top level"))
            for name in sorted({n for n in exported
                                if exported.count(n) > 1}):
                out.append(self.finding(
                    src, node, f"__all__ lists `{name}` more than once"))
        return out

    def _check_doc(self, src: Source) -> List[Optional[Finding]]:
        out: List[Optional[Finding]] = []
        for node in src.tree.body:
            if isinstance(node, _FUNCS + (ast.ClassDef,)) \
                    and not node.name.startswith("_") \
                    and ast.get_docstring(node) is None:
                out.append(self.finding(
                    src, node,
                    f"public {type(node).__name__.replace('Def', '').lower()}"
                    f" `{node.name}` has no docstring (API-surface module)"))
        return out

    def _check_kwargs(self, src: Source) -> List[Optional[Finding]]:
        out: List[Optional[Finding]] = []

        def check(node, doc: Optional[str], params: set, has_kw: bool):
            if not doc or has_kw:
                return
            for m in _KWARG_RE.finditer(doc):
                if m.group(1) not in params:
                    out.append(self.finding(
                        src, node,
                        f"docstring of `{node.name}` references kwarg "
                        f"``{m.group(1)}=`` which is not a parameter "
                        "(signature drift?)"))

        for node in ast.walk(src.tree):
            if isinstance(node, _FUNCS):
                check(node, ast.get_docstring(node), _params(node),
                      _has_kwargs(node))
            elif isinstance(node, ast.ClassDef):
                init = next((n for n in node.body
                             if isinstance(n, _FUNCS)
                             and n.name == "__init__"), None)
                if init is not None:
                    check(node, ast.get_docstring(node), _params(init),
                          _has_kwargs(init))
        return out

    def run(self, src: Source) -> List[Finding]:
        out = self._check_all(src)
        if self._in_surface(src):
            out.extend(self._check_doc(src))
            out.extend(self._check_kwargs(src))
        return [f for f in out if f is not None]
