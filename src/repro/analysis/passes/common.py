"""Shared AST helpers for the contract lint passes."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

__all__ = ["iter_functions", "call_attr", "call_root", "expr_names"]

_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)


def iter_functions(tree: ast.Module) -> Iterator[Tuple[str, ast.AST]]:
    """Yield ``(qualname, node)`` for every function/method in the
    module, with ``Class.method`` / ``outer.inner`` dotted names."""
    def walk(node: ast.AST, stack: List[str]) -> Iterator[Tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _FUNCS):
                qual = ".".join(stack + [child.name])
                yield qual, child
                yield from walk(child, stack + [child.name])
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, stack + [child.name])
            else:
                yield from walk(child, stack)
    yield from walk(tree, [])


def call_attr(node: ast.Call) -> Optional[str]:
    """The attribute/function name being called, if syntactically
    evident: ``a.b.c(...)`` -> ``c``, ``f(...)`` -> ``f``."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def call_root(node: ast.Call) -> Optional[str]:
    """Leftmost name of a dotted call: ``time.time()`` -> ``time``."""
    f = node.func
    while isinstance(f, ast.Attribute):
        f = f.value
    if isinstance(f, ast.Name):
        return f.id
    return None


def expr_names(node: ast.AST) -> List[str]:
    """Every ``Name`` id and ``Attribute`` attr mentioned under ``node``
    (used for fuzzy 'does this expression touch a slab' tests)."""
    out: List[str] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Attribute):
            out.append(n.attr)
    return out
