"""channel-charge: every fetch path must charge a virtual-clock channel.

The serving results are *time* numbers: a code path that faults pages
from the storage backend (``fault_pages``/``get_pages``/``page_stack``/
``page_array``) without charging a named channel (``fetch_seconds``/
``fetch_group_seconds``/``transfer_seconds``/``_charge_hbm``/
``record``/``record_single``/``_borrow`` or by delegating to the
charged ``access_pages*`` wrappers) makes the clock lie — bytes moved
for free.  The pass checks each function in ``serving/`` for the
pairing; helpers whose *caller* owns the charge carry
``# repro: allow-uncharged`` on the ``def`` line documenting that.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, LintPass, Source
from .common import call_attr, iter_functions

__all__ = ["ChannelChargePass"]

# calls that move bytes from the storage tier
FETCH_TOKENS = {"fault_pages", "get_pages", "page_stack", "page_array",
                "materialize", "materialize_rows"}
# calls that put virtual seconds on a channel (or delegate to one that does)
CHARGE_TOKENS = {"fetch_seconds", "fetch_group_seconds", "transfer_seconds",
                 "charge_fetch", "_charge_hbm", "record", "record_single",
                 "_borrow", "access_pages", "access_pages_grouped", "step"}


class ChannelChargePass(LintPass):
    """Pairs storage-fetch calls with virtual-clock charges."""
    name = "channel-charge"
    pragma = "allow-uncharged"
    description = "storage fetches in serving/ that never charge a channel"

    def __init__(self, path_fragment: str = "repro/serving/"):
        self.path_fragment = path_fragment

    def run(self, src: Source) -> List[Finding]:
        if self.path_fragment not in src.path:
            return []
        out: List[Finding] = []
        for qual, fn in iter_functions(src.tree):
            fetches, charges = [], False
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                attr = call_attr(node)
                if attr in FETCH_TOKENS:
                    fetches.append(node)
                if attr in CHARGE_TOKENS:
                    charges = True
            if fetches and not charges:
                # report at the def line so one pragma covers the helper
                out.append(self.finding(
                    src, fn,
                    f"{qual} fetches pages ("
                    + ", ".join(sorted({call_attr(n) for n in fetches}))
                    + ") but never charges a virtual-clock channel; "
                    "charge one or mark `# repro: allow-uncharged` if "
                    "the caller owns the charge"))
        return [f for f in out if f is not None]
