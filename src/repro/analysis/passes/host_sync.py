"""host-sync: no host materialization in serving hot paths.

The serving compute path is virtual-clock driven: real device work is
simulated/overlapped, so an unannotated host sync (``np.asarray`` on a
device array, ``jax.device_get``, ``.block_until_ready()``,
``float(dev_scalar)``, ``.item()``) in a hot path serializes the very
transfers the timeline claims to overlap.  The pass bans those calls
inside a configured set of hot ``Class.method`` qualnames per module;
deliberate host hops (e.g. the host-mirror fallback kernels) carry
``# repro: allow-host`` pragmas documenting why the sync is safe.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..lint import Finding, LintPass, Source
from .common import call_attr, call_root, iter_functions

__all__ = ["HostSyncPass", "DEFAULT_HOT_PATHS"]

BANNED_ATTRS = {"asarray", "ascontiguousarray", "device_get",
                "block_until_ready", "item"}
BANNED_NAMES = {"float"}
# roots whose .asarray IS host materialization (jnp.asarray stays lazy)
HOST_ROOTS = {"np", "numpy", "onp", "jax"}

# module suffix -> hot Class.method qualnames (the demand serve path)
DEFAULT_HOT_PATHS: Dict[str, Set[str]] = {
    "repro/serving/device_pool.py": {
        "DevicePagePool.load", "DevicePagePool.load_group",
        "DevicePagePool.evict", "DevicePagePool.remap",
        "DevicePagePool.gather_rows", "DevicePagePool.virtual_matmul",
        "DevicePagePool.unblock",
    },
    "repro/serving/transfer.py": {
        "TransferEngine.stage", "TransferEngine.load_group",
        "TransferEngine.record_single",
    },
    "repro/serving/shard_pool.py": {
        "ShardedPagePool.stage_borrows", "ShardedPagePool._sync_stage",
        "ShardedPagePool.remap", "ShardedPagePool.gather_rows",
        "ShardedPagePool.virtual_matmul", "ShardedPagePool.unblock",
        "ShardedWeightServer.access_pages",
        "ShardedWeightServer.access_pages_grouped",
        "ShardedWeightServer.device_gather_rows",
        "ShardedWeightServer.device_matmul",
        "ShardedWeightServer.device_tensor",
        "ShardedWeightServer.prestage",
    },
    "repro/serving/engine.py": {
        "WeightServer.access_pages", "WeightServer.access_pages_grouped",
        "WeightServer.prestage", "WeightServer.device_gather_rows",
        "WeightServer.device_matmul", "WeightServer.device_tensor",
        "EmbeddingServingEngine._infer", "LMServingEngine._compute",
    },
}


class HostSyncPass(LintPass):
    """Flags host materialization inside configured hot paths."""
    name = "host-sync"
    pragma = "allow-host"
    description = ("host materialization (np.asarray/device_get/"
                   "block_until_ready/float) in serving hot paths")

    def __init__(self, hot: Optional[Dict[str, Set[str]]] = None):
        self.hot = DEFAULT_HOT_PATHS if hot is None else hot

    def _hot_quals(self, src: Source) -> Optional[Set[str]]:
        for suffix, quals in self.hot.items():
            if src.path.endswith(suffix):
                return quals
        return None

    def run(self, src: Source) -> List[Finding]:
        quals = self._hot_quals(src)
        if not quals:
            return []
        out: List[Finding] = []
        for qual, fn in iter_functions(src.tree):
            if qual not in quals:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                attr = call_attr(node)
                root = call_root(node)
                bad = None
                if attr in BANNED_ATTRS:
                    if attr in ("asarray", "ascontiguousarray") \
                            and root not in HOST_ROOTS:
                        continue          # jnp.asarray etc. stays on device
                    bad = attr
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in BANNED_NAMES:
                    bad = node.func.id
                if bad is not None:
                    out.append(self.finding(
                        src, node,
                        f"host sync `{bad}` inside hot path {qual}; "
                        "annotate deliberate host hops with "
                        "`# repro: allow-host`"))
        return [f for f in out if f is not None]
