"""span-discipline: traces must nest and charged work must be spanned.

The obs tracer's conservation invariant — per-channel span time equals
``VirtualClock.spent`` *exactly* — only holds when (a) every span is
opened and closed through the context manager, so exception paths can
never leave a span dangling, and (b) the code paths that put virtual
seconds on a clock channel do so inside an open span, so the trace
actually attributes the time the clock booked.  Two rules:

* **Rule A** — ``span_begin``/``span_end`` are the tracer's low-level
  plumbing; calling them anywhere outside ``obs/trace.py`` bypasses
  the context manager's exception safety and is flagged unconditionally
  (no pragma).
* **Rule B** — a ``serving/`` function that both fetches pages (the
  ChannelChargePass FETCH tokens) *and* charges a channel (its CHARGE
  tokens) must have every charge call lexically inside a ``with``
  statement whose items include a ``span(...)`` call.  Helpers whose
  caller owns the span carry ``# repro: allow-unspanned`` on the
  ``def`` line documenting that.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, LintPass, Source
from .channel_charge import CHARGE_TOKENS, FETCH_TOKENS
from .common import call_attr, iter_functions

__all__ = ["SpanDisciplinePass"]

# the only module allowed to touch the low-level span plumbing
_TRACER_MODULE = "obs/trace.py"
_RAW_SPAN_CALLS = {"span_begin", "span_end"}


def _spanned_node_ids(fn: ast.AST) -> set:
    """ids of every AST node lexically inside a ``with`` block whose
    items include a ``span(...)`` call (the tracer context manager)."""
    out: set = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.With):
            continue
        if not any(isinstance(it.context_expr, ast.Call)
                   and call_attr(it.context_expr) == "span"
                   for it in node.items):
            continue
        for stmt in node.body:
            for sub in ast.walk(stmt):
                out.add(id(sub))
    return out


class SpanDisciplinePass(LintPass):
    """Context-manager-only spans; charged fetch paths must be spanned."""
    name = "span-discipline"
    pragma = "allow-unspanned"
    description = ("raw span_begin/span_end outside the tracer, or "
                   "charged fetch paths in serving/ outside a span")

    def __init__(self, path_fragment: str = "repro/",
                 charged_fragment: str = "serving/"):
        self.path_fragment = path_fragment
        self.charged_fragment = charged_fragment

    def run(self, src: Source) -> List[Finding]:
        if self.path_fragment not in src.path:
            return []
        out: List[Finding] = []
        # Rule A: the raw begin/end API never leaves the tracer module.
        # Unsuppressable by design: bypass the pragma-aware finding()
        # and build the Finding directly.
        if not src.path.endswith(_TRACER_MODULE):
            for node in ast.walk(src.tree):
                if isinstance(node, ast.Call) \
                        and call_attr(node) in _RAW_SPAN_CALLS:
                    out.append(Finding(
                        src.path, node.lineno, node.col_offset, self.name,
                        f"raw {call_attr(node)}() call outside the tracer "
                        "module; open spans with the `with tracer.span("
                        "...)` context manager so exception paths close "
                        "them"))
        # Rule B: fetch+charge functions keep their charges inside spans
        if self.charged_fragment in src.path:
            out.extend(self._check_charged(src))
        return [f for f in out if f is not None]

    def _check_charged(self, src: Source) -> List[Finding]:
        out: List[Finding] = []
        for qual, fn in iter_functions(src.tree):
            fetches = False
            charges: List[ast.Call] = []
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                attr = call_attr(node)
                if attr in FETCH_TOKENS:
                    fetches = True
                if attr in CHARGE_TOKENS:
                    charges.append(node)
            if not (fetches and charges):
                continue
            spanned = _spanned_node_ids(fn)
            loose = [c for c in charges if id(c) not in spanned]
            if loose:
                # report at the def line so one pragma covers the helper
                out.append(self.finding(
                    src, fn,
                    f"{qual} fetches pages and charges a channel ("
                    + ", ".join(sorted({call_attr(c) for c in loose}))
                    + ") outside any `with ...span(...)` block; the "
                    "trace cannot attribute that time — wrap the "
                    "charge in a span or mark `# repro: "
                    "allow-unspanned` if the caller owns the span"))
        return out
