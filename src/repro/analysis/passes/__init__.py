"""Registry of the repo's contract lint passes."""
from .api_drift import ApiDriftPass
from .channel_charge import ChannelChargePass
from .durability import DurabilityPass
from .frontend_clock import FrontendClockPass
from .host_sync import HostSyncPass
from .silent_except import SilentExceptPass
from .slab_writes import SlabWritePass
from .span_discipline import SpanDisciplinePass
from .unused import UnusedBindingPass
from .wallclock import WallClockPass

__all__ = [
    "ApiDriftPass",
    "ChannelChargePass",
    "DurabilityPass",
    "FrontendClockPass",
    "HostSyncPass",
    "SilentExceptPass",
    "SlabWritePass",
    "SpanDisciplinePass",
    "UnusedBindingPass",
    "WallClockPass",
    "ALL_PASSES",
    "default_passes",
]

ALL_PASSES = (
    SlabWritePass,
    HostSyncPass,
    ChannelChargePass,
    FrontendClockPass,
    SpanDisciplinePass,
    DurabilityPass,
    WallClockPass,
    ApiDriftPass,
    UnusedBindingPass,
    SilentExceptPass,
)


def default_passes():
    """Fresh instances of every registered pass, default-configured."""
    return [cls() for cls in ALL_PASSES]
