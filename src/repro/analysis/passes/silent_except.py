"""silent-except: no bare ``except:`` and no silently swallowed
exceptions in the repro tree.

The recovery layer (storage/faults.py, DESIGN.md §8) depends on a
typed taxonomy: transient errors retry, corruption quarantines, fatal
errors propagate.  A bare ``except:`` (which also catches
KeyboardInterrupt/SystemExit) or an ``except Exception: pass`` handler
erases that distinction — a corrupt page or an exhausted retry budget
silently becomes "fine", and the serving result is garbage with no
counter incremented anywhere.

Flagged:
  * ``except:`` with no exception type, anywhere;
  * any handler whose body does nothing (only ``pass`` / ``...``) while
    catching ``Exception`` / ``BaseException`` — swallowing the broad
    classes whole.

A narrow typed handler with an empty body (e.g. ``except KeyError:
pass`` probing a dict) is deliberate control flow and stays legal.
Sites that genuinely need a broad silent catch carry
``# repro: allow-silent-except`` with a rationale.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, LintPass, Source

__all__ = ["SilentExceptPass"]

_BROAD = ("Exception", "BaseException")


def _names(node) -> List[str]:
    """Exception class names named by an ``except`` clause type."""
    if node is None:
        return []
    if isinstance(node, ast.Tuple):
        return [n for e in node.elts for n in _names(e)]
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        return [node.attr]
    return []


def _body_is_silent(body) -> bool:
    """True when the handler does nothing at all: only ``pass`` or a
    bare ``...`` expression."""
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) \
                and isinstance(stmt.value, ast.Constant) \
                and stmt.value.value is Ellipsis:
            continue
        return False
    return True


class SilentExceptPass(LintPass):
    """Flags bare ``except:`` and broad-catch handlers that swallow the
    exception without doing anything."""
    name = "silent-except"
    pragma = "allow-silent-except"
    description = ("bare except: or except Exception with a do-nothing "
                   "body — erases the fault taxonomy")

    def run(self, src: Source) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                out.append(self.finding(
                    src, node,
                    "bare except: catches KeyboardInterrupt/SystemExit "
                    "too — name the exception types (see the "
                    "storage/faults.py taxonomy)"))
                continue
            names = _names(node.type)
            if any(n in _BROAD for n in names) \
                    and _body_is_silent(node.body):
                out.append(self.finding(
                    src, node,
                    f"except {'/'.join(names)} with a do-nothing body "
                    "silently swallows every failure — handle, re-raise, "
                    "or narrow the type"))
        return [f for f in out if f is not None]
