"""durability: every durable mutation in the storage layer sits at a
registered crash seam.

The crash-point sweep (``storage/crashpoints.py``) proves recovery by
SIGKILLing the process at every registered seam — but only at
*registered* ones.  A new ``os.replace`` / ``os.rename`` (an atomic
file commit) or a sqlite ``.commit()`` added without a
``crash_point(...)`` call nearby is a durable state transition the
sweep can never kill at: the exhaustiveness guarantee silently decays.

One rule over the configured storage files (default: everything under
``repro/storage/`` plus ``repro/core/store.py``): a function that
issues a durable commit —

  * ``os.replace(...)`` or ``os.rename(...)`` (Rule A), or
  * ``<self|con|cur>...commit()`` (Rule B, the sqlite spelling)

— must also call ``crash_point(...)`` somewhere in its *own* body
(nested defs own their own seams).  ``# repro: allow-unjournaled`` on
the flagged line (or the comment line above) documents a deliberate
exception, e.g. schema DDL on a brand-new database where there is no
earlier state to recover to.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from ..lint import Finding, LintPass, Source
from .common import call_attr, call_root

__all__ = ["DurabilityPass"]

#: Rule B receivers: a ``.commit()`` on anything rooted at one of these
#: is a database transaction commit, not e.g. a VCS wrapper
_COMMIT_ROOTS = {"self", "con", "cur"}

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _own_calls(fn: ast.AST) -> List[ast.Call]:
    """Every Call in ``fn``'s own body, excluding nested def/class
    bodies — a nested helper owns its own crash seams."""
    out: List[ast.Call] = []

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _DEFS):
                continue
            if isinstance(child, ast.Call):
                out.append(child)
            walk(child)

    walk(fn)
    return out


def _iter_defs(tree: ast.Module):
    """(qualname, node) for every function/method, like
    ``common.iter_functions`` but NOT descending into nested defs'
    bodies twice is fine — we just need each def once."""
    def walk(node: ast.AST, stack: List[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = ".".join(stack + [child.name])
                yield qual, child
                yield from walk(child, stack + [child.name])
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, stack + [child.name])
            else:
                yield from walk(child, stack)
    yield from walk(tree, [])


class DurabilityPass(LintPass):
    """Durable commits in the storage layer must sit at a registered
    crash point, or the kill-at-every-seam sweep stops being
    exhaustive."""
    name = "durability"
    pragma = "allow-unjournaled"
    description = ("storage-layer os.replace/os.rename/db-commit calls "
                   "outside any crash_point seam")

    def __init__(self, files: Optional[Sequence[str]] = None):
        #: explicit suffix scoping (fixtures/tests); None = the default
        #: storage-layer scope rule in :meth:`_in_scope`
        self.files = tuple(files) if files is not None else None

    def _in_scope(self, src: Source) -> bool:
        if self.files is not None:
            return src.endswith(*self.files)
        return ("repro/storage/" in src.path
                or src.path.endswith("repro/core/store.py"))

    def run(self, src: Source) -> List[Finding]:
        if not self._in_scope(src):
            return []
        out: List[Finding] = []
        for qual, fn in _iter_defs(src.tree):
            calls = _own_calls(fn)
            journaled = any(call_attr(c) == "crash_point" for c in calls)
            if journaled:
                continue
            for c in calls:
                attr, root = call_attr(c), call_root(c)
                if root == "os" and attr in ("replace", "rename"):
                    what = f"os.{attr}"
                elif attr == "commit" and root in _COMMIT_ROOTS:
                    what = f"{root}...commit()"
                else:
                    continue
                out.append(self.finding(
                    src, c,
                    f"{qual} issues a durable commit ({what}) with no "
                    "crash_point(...) in the same function — the "
                    "kill-at-every-seam sweep cannot reach this "
                    "transition; register a seam (crashpoints.py) or "
                    "mark `# repro: allow-unjournaled` with a rationale"))
        return [f for f in out if f is not None]
