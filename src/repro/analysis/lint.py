"""Repo-specific static analysis over stdlib :mod:`ast`.

The serving/storage layers stay correct only because of protocol
contracts the type system cannot see — grouped slab writes, virtual
clock channel charging, remap-generation freshness (DESIGN.md §7).
This module is the tiny framework the contract lints plug into:

* :class:`Finding` — one violation, printable as ``path:line:col``.
* :class:`Source` — a parsed file plus its ``# repro: allow-<token>``
  pragma table.  A pragma on the flagged line *or the line directly
  above it* suppresses a finding whose pass declares that token.
* :class:`LintPass` — base class; subclasses implement
  :meth:`LintPass.run` and emit findings via :meth:`LintPass.finding`
  (which consults the pragma table, so passes never re-implement
  suppression).
* :func:`run_lint` — collect ``.py`` files, parse once, run every pass.

No third-party dependencies: the passes must run in a bare CI
container before anything is installed.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from pathlib import Path
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence

__all__ = [
    "Finding",
    "Source",
    "LintPass",
    "collect_paths",
    "run_lint",
]

# ``# repro: allow-host`` / ``# repro: allow-host, allow-uncharged``;
# free-form rationale after the tokens is encouraged and ignored
_PRAGMA_RE = re.compile(r"#\s*repro:\s*(.*)$")
_TOKEN_RE = re.compile(r"allow-[a-z][a-z0-9-]*")


def parse_pragmas(text: str) -> Dict[int, FrozenSet[str]]:
    """1-based line -> set of ``allow-*`` tokens declared on that line.

    Scope rules live in :meth:`Source.allowed`: a comment-only pragma
    line also covers the line below it; a trailing pragma covers only
    its own line, so it cannot bleed onto the next statement.
    """
    out: Dict[int, FrozenSet[str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if not m:
            continue
        toks = frozenset(_TOKEN_RE.findall(m.group(1)))
        if toks:
            out[i] = toks
    return out


def comment_only_lines(text: str) -> FrozenSet[int]:
    """1-based numbers of lines that are nothing but a comment."""
    return frozenset(i for i, line in enumerate(text.splitlines(), start=1)
                     if line.lstrip().startswith("#"))


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation at ``path:line:col`` from pass ``name``."""
    path: str
    line: int
    col: int
    name: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.name}] {self.message}"


class Source:
    """A parsed source file: text, AST, and the pragma table."""

    def __init__(self, path: str, text: str):
        # normalized separators so passes can match path suffixes portably
        self.path = str(path).replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=self.path)
        self.pragmas = parse_pragmas(text)
        self._comment_only = comment_only_lines(text)

    @classmethod
    def load(cls, path) -> "Source":
        return cls(str(path), Path(path).read_text())

    def allowed(self, line: int, token: str) -> bool:
        """True if ``token`` is granted on ``line``, or on a
        comment-only pragma line directly above it (a trailing pragma
        on the previous statement does NOT bleed downward)."""
        if token in self.pragmas.get(line, ()):
            return True
        return line - 1 in self._comment_only \
            and token in self.pragmas.get(line - 1, ())

    def endswith(self, *suffixes: str) -> bool:
        return self.path.endswith(suffixes)


class LintPass:
    """Base class for one contract check.

    Subclasses set ``name`` (finding tag), ``pragma`` (the
    ``allow-*`` token that suppresses it; ``None`` = unsuppressable)
    and ``description``, then implement :meth:`run`.
    """

    name: str = "lint"
    pragma: Optional[str] = None
    description: str = ""

    def run(self, src: Source) -> List[Finding]:
        raise NotImplementedError

    def finding(self, src: Source, node: ast.AST, message: str
                ) -> Optional[Finding]:
        """Build a finding unless a pragma on/above the line allows it."""
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        if self.pragma is not None and src.allowed(line, self.pragma):
            return None
        return Finding(src.path, line, col, self.name, message)


def collect_paths(paths: Sequence) -> List[Path]:
    """Expand files/directories into a sorted list of ``.py`` files,
    skipping hidden directories and caches."""
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                parts = f.relative_to(p).parts
                if any(s.startswith(".") or s == "__pycache__"
                       for s in parts):
                    continue
                out.append(f)
        elif p.suffix == ".py":
            out.append(p)
    return out


def run_lint(paths: Sequence, passes: Optional[Iterable[LintPass]] = None,
             ) -> List[Finding]:
    """Run ``passes`` (default: every registered pass) over ``paths``.

    Returns findings sorted by (path, line, col).  Files that fail to
    parse produce a single ``syntax`` finding instead of crashing the
    whole run.
    """
    if passes is None:
        from .passes import default_passes
        passes = default_passes()
    passes = list(passes)
    findings: List[Finding] = []
    for path in collect_paths(paths):
        try:
            src = Source.load(path)
        except SyntaxError as e:
            findings.append(Finding(str(path).replace(os.sep, "/"),
                                    e.lineno or 1, e.offset or 0,
                                    "syntax", f"failed to parse: {e.msg}"))
            continue
        for p in passes:
            findings.extend(f for f in p.run(src) if f is not None)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.name))
    return findings
