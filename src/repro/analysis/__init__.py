"""Static contract lints + the runtime page-pool sanitizer.

Two halves (DESIGN.md §7):

* :mod:`repro.analysis.lint` / :mod:`repro.analysis.passes` — stdlib
  AST lints for the protocol contracts (grouped slab writes, host-sync
  hygiene, channel charging, wall-clock bans, API drift).  Run via
  ``scripts/run_lints.py`` / ``make lint``; importing them pulls no
  heavy deps, so they work in a bare container.
* :mod:`repro.analysis.sanitizer` — the opt-in runtime PoolSanitizer
  ("TSan for the page pool"); imported lazily here because it touches
  the jax-backed serving classes.  ``REPRO_SANITIZE=1`` turns it on
  under the whole test suite (see ``tests/conftest.py``).
"""
from .lint import Finding, LintPass, Source, collect_paths, run_lint
from .passes import ALL_PASSES, default_passes

__all__ = [
    "Finding",
    "LintPass",
    "Source",
    "collect_paths",
    "run_lint",
    "ALL_PASSES",
    "default_passes",
    "PoolSanitizer",
    "PoolSanitizerError",
    "PoolEvent",
    "enable",
    "disable",
]

_SANITIZER_NAMES = {"PoolSanitizer", "PoolSanitizerError", "PoolEvent",
                    "enable", "disable"}


def __getattr__(name):
    # lazy: the sanitizer imports the jax-backed pool classes, which the
    # lint driver must not pay for in a bare CI container
    if name in _SANITIZER_NAMES:
        from . import sanitizer
        return getattr(sanitizer, name)
    raise AttributeError(name)
