"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are *grouped* into homogeneous stacks (e.g. kimi-k2 = 1 dense layer
+ 60 MoE layers) so each group is a single ``lax.scan`` over stacked
params — one compiled layer body per group regardless of depth.
Per-layer scalars (sliding-window size) ride along as scan inputs, so
gemma2's local/global alternation is data, not control flow.

Entry points: ``init_params``, ``forward`` (train), ``loss``, ``prefill``
(returns KV/SSM cache), ``decode_step`` (one token).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import hint
from .attention import attend, decode_attend
from .layers import dot, embed, mlp, norm, rms_norm, rotary, unembed
from .ssm import mamba_mixer, ssm_dims

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    name: str
    kind: str              # dense | moe | ssm | hybrid
    n: int
    windows: Tuple[int, ...]   # per-layer sliding window (0 = global)


def build_groups(cfg: ModelConfig) -> List[GroupSpec]:
    L = cfg.num_layers

    def windows(n, offset=0):
        ws = []
        for i in range(n):
            li = i + offset
            if cfg.sliding_window == 0:
                ws.append(0)
            elif cfg.window_pattern == -3:     # hymba: first/middle/last global
                ws.append(0 if li in (0, L // 2, L - 1) else cfg.sliding_window)
            elif cfg.window_pattern > 0:       # every Nth layer global
                ws.append(cfg.sliding_window if li % cfg.window_pattern == 0
                          else 0)
            else:
                ws.append(cfg.sliding_window)
        return tuple(ws)

    if cfg.family == "ssm":
        return [GroupSpec("blocks", "ssm", L, (0,) * L)]
    if cfg.family == "hybrid":
        return [GroupSpec("blocks", "hybrid", L, windows(L))]
    if cfg.moe is not None:
        groups = []
        fd = cfg.first_dense_layers
        if fd:
            groups.append(GroupSpec("dense_blocks", "dense", fd, windows(fd)))
        groups.append(GroupSpec("blocks", "moe", L - fd, windows(L - fd, fd)))
        return groups
    return [GroupSpec("blocks", "dense", L, windows(L))]


# ------------------------------------------------------------------- init ---
def _norm_params(d, cfg, key=None):
    p = {"scale": jnp.zeros((d,), F32) if cfg.norm_type == "rms"
         else jnp.ones((d,), F32)}
    if cfg.norm_type == "layer":
        p["bias"] = jnp.zeros((d,), F32)
    return p


def _attn_params(key, cfg: ModelConfig, dtype):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 0.02
    p = {
        "wq": (jax.random.normal(k1, (d, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, K * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, K * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H * hd, d)) * s
               / math.sqrt(2 * cfg.num_layers)).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), F32)
        p["k_norm"] = jnp.zeros((hd,), F32)
    return p


def _mlp_params(key, d, f, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 0.02
    p = {"w1": (jax.random.normal(k1, (d, f)) * s).astype(dtype),
         "w2": (jax.random.normal(k2, (f, d)) * s
                / math.sqrt(2 * cfg.num_layers)).astype(dtype)}
    if cfg.gated_mlp:
        p["w3"] = (jax.random.normal(k3, (d, f)) * s).astype(dtype)
    return p


def _moe_params(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s = 0.02
    p = {
        "router": (jax.random.normal(k1, (d, E)) * s).astype(F32),
        "ew1": (jax.random.normal(k2, (E, d, f)) * s).astype(dtype),
        "ew2": (jax.random.normal(k3, (E, f, d)) * s
                / math.sqrt(2 * cfg.num_layers)).astype(dtype),
    }
    if cfg.gated_mlp:
        p["ew3"] = (jax.random.normal(k4, (E, d, f)) * s).astype(dtype)
    if m.dense_ff:
        dp = _mlp_params(k5, d, m.dense_ff, cfg, dtype)
        p["dw1"], p["dw2"] = dp["w1"], dp["w2"]
        if cfg.gated_mlp:
            p["dw3"] = dp["w3"]
    return p


def _mamba_params(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    din, H, conv_ch = ssm_dims(d, s)
    gn = s.n_groups * s.d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sc = 0.02
    dt = jnp.exp(jax.random.uniform(k3, (H,), F32,
                                    math.log(1e-3), math.log(1e-1)))
    return {
        "in_proj": (jax.random.normal(k1, (d, 2 * din + 2 * gn + H)) * sc
                    ).astype(dtype),
        "out_proj": (jax.random.normal(k2, (din, d)) * sc
                     / math.sqrt(2 * cfg.num_layers)).astype(dtype),
        "conv_w": (jax.random.normal(k4, (s.d_conv, conv_ch)) * sc
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.log(jnp.expm1(dt)),               # softplus^-1(dt)
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=F32)),
        "Dp": jnp.ones((H,), F32),
        "ssm_norm": jnp.zeros((din,), F32),
    }


def _layer_params(key, kind: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p: Dict = {"ln1": _norm_params(cfg.d_model, cfg)}
    if kind == "ssm":
        p["mamba"] = _mamba_params(ks[0], cfg, dtype)
        return p
    if kind == "hybrid":
        p["attn"] = _attn_params(ks[0], cfg, dtype)
        p["mamba"] = _mamba_params(ks[1], cfg, dtype)
    else:
        p["attn"] = _attn_params(ks[0], cfg, dtype)
    p["ln2"] = _norm_params(cfg.d_model, cfg)
    if kind == "moe":
        p["moe"] = _moe_params(ks[2], cfg, dtype)
    else:
        p["mlp"] = _mlp_params(ks[2], cfg.d_model, cfg.d_ff, cfg, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_norm": _norm_params(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[1],
                                            (cfg.d_model, cfg.vocab))
                          * 0.02).astype(dtype)
    for gi, g in enumerate(build_groups(cfg)):
        lkeys = jax.random.split(jax.random.fold_in(keys[2], gi), g.n)
        params[g.name] = jax.vmap(
            lambda k: _layer_params(k, g.kind, cfg, dtype))(lkeys)
    return params


# ---------------------------------------------------------------- forward ---
def _attention(h, p, cfg: ModelConfig, positions, window,
               cache_kv=None, pos=None):
    """Returns (attn_out, (k, v) or updated cache slices)."""
    B, S, _ = h.shape
    H, K, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
    q = dot(h, p["wq"].astype(h.dtype))
    k = dot(h, p["wk"].astype(h.dtype))
    v = dot(h, p["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd).astype(h.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta).astype(h.dtype)

    if cache_kv is None:                       # train / prefill
        # Sequence-parallel callers re-shard to head sharding here (an
        # all-to-all) rather than all-gathering the full K/V sequence.
        q = hint(q, "attn_q")
        k = hint(k, "attn_kv")
        v = hint(v, "attn_kv")
        out = attend(q, k, v, causal=True, window=window,
                     softcap=cfg.attn_softcap)
        out = hint(out, "attn_o")
        new_kv = (k, v)
    else:                                      # decode: append then attend
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(ck, k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, pos, 0, 0))
        out = decode_attend(q, ck, cv, kv_len=pos + 1, window=window,
                            softcap=cfg.attn_softcap)
        new_kv = (ck, cv)
    out = dot(out.reshape(B, S, H * hd), p["wo"].astype(h.dtype))
    return out.astype(h.dtype), new_kv


def _block(x, lp, window, cfg: ModelConfig, kind: str, positions,
           cache=None, pos=None):
    """One layer body.  cache: dict slice for this layer (decode) or None.
    Returns (x, ys) where ys carries cache material."""
    from .moe import moe_block        # local import to avoid cycles

    h = norm(x, lp["ln1"], cfg.norm_type, cfg.norm_eps)
    ys = {}
    if kind == "ssm":
        y, (cst, sst) = mamba_mixer(
            h, lp["mamba"], cfg.d_model, cfg.ssm,
            conv_state=None if cache is None else cache["conv_state"],
            ssm_state=None if cache is None else cache["ssm_state"],
            decode=cache is not None)
        ys["conv_state"], ys["ssm_state"] = cst, sst
        x = hint(x + y, "residual")
        return x, ys

    if kind == "hybrid":
        a, kv = _attention(h, lp["attn"], cfg, positions, window,
                           cache_kv=None if cache is None
                           else (cache["k"], cache["v"]), pos=pos)
        m, (cst, sst) = mamba_mixer(
            h, lp["mamba"], cfg.d_model, cfg.ssm,
            conv_state=None if cache is None else cache["conv_state"],
            ssm_state=None if cache is None else cache["ssm_state"],
            decode=cache is not None)
        ys["k"], ys["v"] = kv
        ys["conv_state"], ys["ssm_state"] = cst, sst
        x = hint(x + 0.5 * (a + m), "residual")
    else:
        a, kv = _attention(h, lp["attn"], cfg, positions, window,
                           cache_kv=None if cache is None
                           else (cache["k"], cache["v"]), pos=pos)
        ys["k"], ys["v"] = kv
        x = hint(x + a, "residual")

    h2 = norm(x, lp["ln2"], cfg.norm_type, cfg.norm_eps)
    if kind == "moe":
        y = moe_block(h2, lp["moe"], cfg.moe, cfg.act, cfg.gated_mlp)
    else:
        y = mlp(h2, lp["mlp"], cfg.act, cfg.gated_mlp)
    x = hint(x + y.astype(x.dtype), "residual")
    return x, ys


def _run_group(x, gparams, g: GroupSpec, cfg: ModelConfig, positions,
               cache=None, pos=None, collect_cache=False):
    windows = jnp.asarray(g.windows, jnp.int32)

    def body(carry, xs):
        if cache is None:
            lp, w = xs
            c = None
        else:
            lp, w, c = xs
        out, ys = _block(carry, lp, w, cfg, g.kind, positions, cache=c,
                         pos=pos)
        if not collect_cache and cache is None:
            ys = None
        return out, ys

    body_fn = jax.checkpoint(body) if (cfg.remat and cache is None) else body
    xs = (gparams, windows) if cache is None else (gparams, windows, cache)
    x, ys = jax.lax.scan(body_fn, x, xs,
                         unroll=g.n if cfg.scan_unroll else 1)
    return x, ys


def _embed_inputs(params, cfg: ModelConfig, tokens, img_embeds=None):
    x = embed(tokens, params["embed"], cfg.embed_scale)
    if cfg.vlm_stub and img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    return x


def forward(params, cfg: ModelConfig, tokens, img_embeds=None):
    """Training/eval forward -> logits [B, S_total, V]."""
    x = _embed_inputs(params, cfg, tokens, img_embeds)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x = hint(x, "residual")
    for g in build_groups(cfg):
        x, _ = _run_group(x, params[g.name], g, cfg, positions)
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = unembed(x, params["embed"] if cfg.tie_embeddings
                     else params["head"], cfg.tie_embeddings,
                     cfg.final_softcap)
    return hint(logits, "logits")


def loss(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    logits = forward(params, cfg, batch["tokens"],
                     batch.get("image_embeds"))
    labels = batch["labels"]
    if cfg.vlm_stub and logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]      # drop image positions
    lp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    return -ll.mean()


# ---------------------------------------------------------------- serving ---
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict:
    """Zeroed decode cache; ``pos`` tracks the filled length."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache: Dict = {"pos": jnp.zeros((), jnp.int32)}
    for g in build_groups(cfg):
        c: Dict = {}
        if g.kind in ("dense", "moe", "hybrid"):
            c["k"] = jnp.zeros((g.n, batch, max_len, cfg.kv_heads, cfg.hd),
                               dtype)
            c["v"] = jnp.zeros_like(c["k"])
        if g.kind in ("ssm", "hybrid"):
            din, H, conv_ch = ssm_dims(cfg.d_model, cfg.ssm)
            c["conv_state"] = jnp.zeros(
                (g.n, batch, cfg.ssm.d_conv - 1, conv_ch), dtype)
            c["ssm_state"] = jnp.zeros(
                (g.n, batch, H, cfg.ssm.head_dim, cfg.ssm.d_state), F32)
        cache[g.name] = c
    return cache


def prefill(params, cfg: ModelConfig, tokens, img_embeds=None,
            max_len: Optional[int] = None):
    """Process the prompt; returns (last-token logits, filled cache)."""
    x = _embed_inputs(params, cfg, tokens, img_embeds)
    B, S, _ = x.shape
    max_len = max_len or S
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x = hint(x, "residual")
    cache: Dict = {"pos": jnp.asarray(S, jnp.int32)}
    for g in build_groups(cfg):
        x, ys = _run_group(x, params[g.name], g, cfg, positions,
                           collect_cache=True)
        c: Dict = {}
        if "k" in ys:
            k, v = ys["k"], ys["v"]               # [n, B, S, K, hd]
            if max_len != S:
                padded = jnp.zeros(k.shape[:2] + (max_len,) + k.shape[3:],
                                   k.dtype)
                k = jax.lax.dynamic_update_slice(
                    padded, k, (0, 0, 0, 0, 0))
                v = jax.lax.dynamic_update_slice(
                    jnp.zeros_like(padded), v, (0, 0, 0, 0, 0))
            c["k"], c["v"] = k, v
        if "ssm_state" in ys:
            c["conv_state"] = ys["conv_state"]
            c["ssm_state"] = ys["ssm_state"]
        cache[g.name] = c
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    last = x[:, -1:]
    logits = unembed(last, params["embed"] if cfg.tie_embeddings
                     else params["head"], cfg.tie_embeddings,
                     cfg.final_softcap)
    return hint(logits, "logits"), cache


def decode_step(params, cfg: ModelConfig, cache: Dict, tokens):
    """One decode step: tokens [B, 1] -> (logits [B, 1, V], new cache)."""
    pos = cache["pos"]
    x = embed(tokens, params["embed"], cfg.embed_scale)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    new_cache: Dict = {"pos": pos + 1}
    for g in build_groups(cfg):
        x, ys = _run_group(x, params[g.name], g, cfg, positions,
                           cache=cache[g.name], pos=pos)
        new_cache[g.name] = ys
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = unembed(x, params["embed"] if cfg.tie_embeddings
                     else params["head"], cfg.tie_embeddings,
                     cfg.final_softcap)
    return hint(logits, "logits"), new_cache
