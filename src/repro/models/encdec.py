"""Whisper-style encoder-decoder backbone (conv/mel frontend is a STUB:
the encoder consumes precomputed frame embeddings per the assignment).

LayerNorm + plain GELU MLP + learned decoder positions + sinusoidal
encoder positions, no RoPE — faithful to the whisper backbone.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import hint
from .attention import attend, decode_attend
from .layers import dot, layer_norm, mlp

F32 = jnp.float32


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    half = channels // 2
    scale = math.log(10_000) / (half - 1)
    inv = jnp.exp(-scale * jnp.arange(half, dtype=F32))
    ang = jnp.arange(length, dtype=F32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _ln(d):
    return {"scale": jnp.ones((d,), F32), "bias": jnp.zeros((d,), F32)}


def _attn_p(key, d, H, hd, dtype, prefix=""):
    ks = jax.random.split(key, 4)
    s = 0.02
    names = ["cq", "ck", "cv", "co"] if prefix == "c" else \
        ["wq", "wk", "wv", "wo"]
    return {
        names[0]: (jax.random.normal(ks[0], (d, H * hd)) * s).astype(dtype),
        names[1]: (jax.random.normal(ks[1], (d, H * hd)) * s).astype(dtype),
        names[2]: (jax.random.normal(ks[2], (d, H * hd)) * s).astype(dtype),
        names[3]: (jax.random.normal(ks[3], (H * hd, d)) * s).astype(dtype),
    }


def init_params(key, cfg: ModelConfig, max_dec: int = 4096) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    d, H, hd, f = cfg.d_model, cfg.num_heads, cfg.hd, cfg.d_ff
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": _ln(d), "attn": _attn_p(k1, d, H, hd, dtype),
                "ln2": _ln(d),
                "mlp": {"w1": (jax.random.normal(k2, (d, f)) * 0.02
                               ).astype(dtype),
                        "w2": (jax.random.normal(jax.random.fold_in(k2, 1),
                                                 (f, d)) * 0.02
                               ).astype(dtype)}}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": _ln(d), "attn": _attn_p(k1, d, H, hd, dtype),
                "lnc": _ln(d), "cross": _attn_p(k2, d, H, hd, dtype, "c"),
                "ln2": _ln(d),
                "mlp": {"w1": (jax.random.normal(k3, (d, f)) * 0.02
                               ).astype(dtype),
                        "w2": (jax.random.normal(jax.random.fold_in(k3, 1),
                                                 (f, d)) * 0.02
                               ).astype(dtype)}}

    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, d)) * 0.02
                  ).astype(dtype),
        "pos_embed": (jax.random.normal(ks[1], (max_dec, d)) * 0.01
                      ).astype(dtype),
        "enc_blocks": jax.vmap(enc_layer)(
            jax.random.split(ks[2], cfg.enc_layers)),
        "enc_norm": _ln(d),
        "dec_blocks": jax.vmap(dec_layer)(
            jax.random.split(ks[3], cfg.num_layers)),
        "dec_norm": _ln(d),
    }


def _self_attn(h, p, cfg, causal, cache_kv=None, pos=None):
    B, S, _ = h.shape
    H, hd = cfg.num_heads, cfg.hd
    q = dot(h, p["wq"].astype(h.dtype)).reshape(B, S, H, hd)
    k = dot(h, p["wk"].astype(h.dtype)).reshape(B, S, H, hd).astype(h.dtype)
    v = dot(h, p["wv"].astype(h.dtype)).reshape(B, S, H, hd).astype(h.dtype)
    if cache_kv is None:
        out = attend(q, k, v, causal=causal, window=0)
        kv = (k, v)
    else:
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(ck, k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, pos, 0, 0))
        out = decode_attend(q, ck, cv, kv_len=pos + 1)
        kv = (ck, cv)
    return dot(out.reshape(B, S, H * hd),
               p["wo"].astype(h.dtype)).astype(h.dtype), kv


def _cross_attn(h, p, cfg, enc_kv, enc_len=None, single=False):
    B, S, _ = h.shape
    H, hd = cfg.num_heads, cfg.hd
    q = dot(h, p["cq"].astype(h.dtype)).reshape(B, S, H, hd)
    k, v = enc_kv
    if single:
        out = decode_attend(q, k, v, kv_len=k.shape[1] if enc_len is None
                            else enc_len, q_pos=k.shape[1])
    else:
        out = attend(q, k, v, causal=False, window=0)
    return dot(out.reshape(B, S, H * hd),
               p["co"].astype(h.dtype)).astype(h.dtype)


def encode(params, cfg: ModelConfig, frames) -> jnp.ndarray:
    """frames: [B, S, D] precomputed embeddings (frontend stub)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoids(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    x = hint(x, "residual")

    def body(carry, lp):
        h = layer_norm(carry, lp["ln1"]["scale"], lp["ln1"]["bias"])
        a, _ = _self_attn(h, lp["attn"], cfg, causal=False)
        x = carry + a
        h2 = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = hint(x + mlp(h2, lp["mlp"], "gelu", False), "residual")
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body) if cfg.remat else body,
                        x, params["enc_blocks"],
                        unroll=cfg.enc_layers if cfg.scan_unroll else 1)
    return layer_norm(x, params["enc_norm"]["scale"],
                      params["enc_norm"]["bias"])


def _dec_embed(params, tokens, pos0=0):
    x = jnp.take(params["embed"], tokens, axis=0)
    S = tokens.shape[1]
    pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos0, S, 0)
    return x + pe[None].astype(x.dtype)


def _cross_kv(lp, cfg, enc_out):
    B, S, _ = enc_out.shape
    H, hd = cfg.num_heads, cfg.hd
    k = dot(enc_out, lp["ck"].astype(enc_out.dtype)).reshape(B, S, H, hd)
    v = dot(enc_out, lp["cv"].astype(enc_out.dtype)).reshape(B, S, H, hd)
    return k.astype(enc_out.dtype), v.astype(enc_out.dtype)


def decode_train(params, cfg: ModelConfig, tokens, enc_out):
    x = _dec_embed(params, tokens)
    x = hint(x, "residual")

    def body(carry, lp):
        h = layer_norm(carry, lp["ln1"]["scale"], lp["ln1"]["bias"])
        a, _ = _self_attn(h, lp["attn"], cfg, causal=True)
        x = carry + a
        hc = layer_norm(x, lp["lnc"]["scale"], lp["lnc"]["bias"])
        x = x + _cross_attn(hc, lp["cross"], cfg, _cross_kv(lp["cross"],
                                                            cfg, enc_out))
        h2 = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = hint(x + mlp(h2, lp["mlp"], "gelu", False), "residual")
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body) if cfg.remat else body,
                        x, params["dec_blocks"],
                        unroll=cfg.num_layers if cfg.scan_unroll else 1)
    x = layer_norm(x, params["dec_norm"]["scale"], params["dec_norm"]["bias"])
    logits = dot(x, params["embed"].T.astype(x.dtype))
    return hint(logits, "logits")


def loss(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    enc = encode(params, cfg, batch["frames"])
    logits = decode_train(params, cfg, batch["tokens"], enc)
    lp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    ll = jnp.take_along_axis(lp, batch["labels"][..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    return -ll.mean()


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    L, H, hd = cfg.num_layers, cfg.num_heads, cfg.hd
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((L, batch, max_len, H, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, H, hd), dtype),
        "enc_k": jnp.zeros((L, batch, enc_len, H, hd), dtype),
        "enc_v": jnp.zeros((L, batch, enc_len, H, hd), dtype),
    }


def prefill(params, cfg: ModelConfig, frames, tokens,
            max_len: Optional[int] = None) -> Tuple[jnp.ndarray, Dict]:
    """Encode audio, precompute cross-KV, run the decoder prompt."""
    enc = encode(params, cfg, frames)
    S_dec = tokens.shape[1]
    max_len = max_len or S_dec
    x = _dec_embed(params, tokens)

    def body(carry, lp):
        h = layer_norm(carry, lp["ln1"]["scale"], lp["ln1"]["bias"])
        a, (k, v) = _self_attn(h, lp["attn"], cfg, causal=True)
        x = carry + a
        hc = layer_norm(x, lp["lnc"]["scale"], lp["lnc"]["bias"])
        ekv = _cross_kv(lp["cross"], cfg, enc)
        x = x + _cross_attn(hc, lp["cross"], cfg, ekv)
        h2 = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = hint(x + mlp(h2, lp["mlp"], "gelu", False), "residual")
        return x, (k, v, ekv[0], ekv[1])

    x, (ks, vs, eks, evs) = jax.lax.scan(
        body, x, params["dec_blocks"],
        unroll=cfg.num_layers if cfg.scan_unroll else 1)
    if max_len != S_dec:
        pad = jnp.zeros(ks.shape[:2] + (max_len,) + ks.shape[3:], ks.dtype)
        ks = jax.lax.dynamic_update_slice(pad, ks, (0,) * 5)
        vs = jax.lax.dynamic_update_slice(jnp.zeros_like(pad), vs, (0,) * 5)
    x = layer_norm(x, params["dec_norm"]["scale"], params["dec_norm"]["bias"])
    logits = dot(x[:, -1:], params["embed"].T.astype(x.dtype))
    cache = {"pos": jnp.asarray(S_dec, jnp.int32),
             "k": ks, "v": vs, "enc_k": eks, "enc_v": evs}
    return hint(logits, "logits"), cache


def decode_step(params, cfg: ModelConfig, cache: Dict, tokens):
    pos = cache["pos"]
    x = _dec_embed(params, tokens, pos0=pos)

    def body(carry, xs):
        lp, ck, cv, ek, ev = xs
        h = layer_norm(carry, lp["ln1"]["scale"], lp["ln1"]["bias"])
        a, (nk, nv) = _self_attn(h, lp["attn"], cfg, causal=True,
                                 cache_kv=(ck, cv), pos=pos)
        x = carry + a
        hc = layer_norm(x, lp["lnc"]["scale"], lp["lnc"]["bias"])
        x = x + _cross_attn(hc, lp["cross"], cfg, (ek, ev), single=True)
        h2 = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = x + mlp(h2, lp["mlp"], "gelu", False)
        return x, (nk, nv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["enc_k"], cache["enc_v"]),
        unroll=cfg.num_layers if cfg.scan_unroll else 1)
    x = layer_norm(x, params["dec_norm"]["scale"], params["dec_norm"]["bias"])
    logits = dot(x, params["embed"].T.astype(x.dtype))
    new_cache = {"pos": pos + 1, "k": ks, "v": vs,
                 "enc_k": cache["enc_k"], "enc_v": cache["enc_v"]}
    return hint(logits, "logits"), new_cache
