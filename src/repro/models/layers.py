"""Shared building blocks for the model zoo (pure functional JAX).

Conventions:
  * params are plain dicts of jnp arrays; per-layer params are *stacked*
    on a leading layer axis so layer loops are ``jax.lax.scan``s.
  * matmuls accumulate in fp32 (``preferred_element_type``) with bf16
    operands — the precision scheme the roofline assumes (197 TFLOP/s
    bf16 MXU).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint

F32 = jnp.float32


def dot(a, b, **kw):
    return jnp.matmul(a, b, preferred_element_type=F32, **kw)


def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(F32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(F32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(F32) \
        + bias.astype(F32)
    return out.astype(x.dtype)


def norm(x, p, kind: str, eps: float):
    if kind == "layer":
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


def softcap(x, cap: float):
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(F32) / cap)).astype(x.dtype)


def rotary(x, positions, theta: float):
    """Apply RoPE.  x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = positions[..., None].astype(F32) * freqs          # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp(x, p, act: str, gated: bool):
    """SwiGLU/GeGLU (gated) or plain 2-matmul MLP."""
    h = dot(x, p["w1"])                                     # [.., F]
    if gated:
        h = activation(h, act) * dot(x, p["w3"])
    else:
        h = activation(h, act)
    h = hint(h.astype(x.dtype), "act_ff")
    return dot(h, p["w2"]).astype(x.dtype)


def embed(tokens, table, scale: bool):
    x = jnp.take(table, tokens, axis=0)
    if scale:
        # keep the scale in the embedding dtype: a python-float multiply
        # upcasts the whole activation (and, hoisted, the table) to fp32
        x = x * jnp.asarray(math.sqrt(table.shape[-1]), x.dtype)
    return x


def unembed(x, table_or_head, tied: bool, cap: float = 0.0):
    w = table_or_head.T if tied else table_or_head
    logits = dot(x, w.astype(x.dtype))
    return softcap(logits, cap)


def causal_conv1d(x, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv used by mamba: x [B,S,C], w [K,C], b [C].

    With ``state`` ([B, K-1, C], the trailing inputs of the previous step)
    this is the streaming/decode form; returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xin = jnp.concatenate([state, x], axis=1)               # [B, S+K-1, C]
    y = sum(xin[:, i: i + x.shape[1]] * w[i] for i in range(k))
    new_state = xin[:, -(k - 1):] if k > 1 else state
    return (y + b).astype(x.dtype), new_state
