"""Unified model API: ``build(cfg)`` returns callables shared by the
trainer, serving engine, and dry-run; ``input_specs`` produces
ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no allocation)
for every (arch x shape) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from . import encdec, transformer

WHISPER_DEC_LEN = 448          # whisper decoder context (prompt length)
WHISPER_ENC_LEN = 1500         # encoder frames for decode cells


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable                 # (key, max_dec) -> params
    loss: Callable                 # (params, batch) -> scalar
    prefill: Callable              # (params, batch, max_len) -> (logits, cache)
    decode: Callable               # (params, cache, tokens) -> (logits, cache)
    init_cache: Callable           # (batch, max_len, enc_len) -> cache


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.encdec:
        return ModelAPI(
            cfg=cfg,
            init=lambda key, max_dec=4096: encdec.init_params(key, cfg,
                                                              max_dec),
            loss=lambda p, b: encdec.loss(p, cfg, b),
            prefill=lambda p, b, max_len=None: encdec.prefill(
                p, cfg, b["frames"], b["tokens"], max_len),
            decode=lambda p, c, t: encdec.decode_step(p, cfg, c, t),
            init_cache=lambda batch, max_len, enc_len=WHISPER_ENC_LEN:
                encdec.init_cache(cfg, batch, max_len, enc_len),
        )
    return ModelAPI(
        cfg=cfg,
        init=lambda key, max_dec=0: transformer.init_params(key, cfg),
        loss=lambda p, b: transformer.loss(p, cfg, b),
        prefill=lambda p, b, max_len=None: transformer.prefill(
            p, cfg, b["tokens"], b.get("image_embeds"), max_len),
        decode=lambda p, c, t: transformer.decode_step(p, cfg, c, t),
        init_cache=lambda batch, max_len, enc_len=0:
            transformer.init_cache(cfg, batch, max_len),
    )


# ------------------------------------------------------------ input specs ---
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict:
    """Batch ShapeDtypeStructs for one (arch x shape) cell.

    ``train``:  token/label batch (modality stubs included).
    ``prefill``: prompt batch.
    ``decode``:  one new token + a cache filled to ``seq_len``.
    """
    B, S = spec.global_batch, spec.seq_len
    d = cfg.d_model
    act_dt = cfg.dtype

    dec_len = min(WHISPER_DEC_LEN, S)
    if spec.kind == "train":
        if cfg.encdec:
            return {"frames": _sds((B, S, d), act_dt),
                    "tokens": _sds((B, dec_len), "int32"),
                    "labels": _sds((B, dec_len), "int32")}
        if cfg.vlm_stub:
            P = cfg.num_patches
            return {"tokens": _sds((B, S - P), "int32"),
                    "image_embeds": _sds((B, P, d), act_dt),
                    "labels": _sds((B, S - P), "int32")}
        return {"tokens": _sds((B, S), "int32"),
                "labels": _sds((B, S), "int32")}

    if spec.kind == "prefill":
        if cfg.encdec:
            return {"frames": _sds((B, S, d), act_dt),
                    "tokens": _sds((B, dec_len), "int32")}
        if cfg.vlm_stub:
            P = cfg.num_patches
            return {"tokens": _sds((B, S - P), "int32"),
                    "image_embeds": _sds((B, P, d), act_dt)}
        return {"tokens": _sds((B, S), "int32")}

    # decode: one token against a seq_len cache
    api = build(cfg)
    cache = jax.eval_shape(
        lambda: api.init_cache(B, S))
    return {"tokens": _sds((B, 1), "int32"), "cache": cache}


def param_shapes(cfg: ModelConfig, spec: Optional[ShapeSpec] = None):
    """Abstract param pytree (no allocation) for lowering."""
    api = build(cfg)
    max_dec = spec.seq_len if (spec and cfg.encdec) else 4096
    return jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0), max_dec))
