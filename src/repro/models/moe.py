"""Mixture-of-Experts block: top-k routing with capacity, EP-sharded.

Dispatch is scatter/gather-based (no [T, E, C] one-hot blowup, which is
intractable at kimi-k2 scale: T=65k, E=384).  Token -> (expert, slot)
assignments are computed with per-expert running counts; overflow tokens
are dropped (capacity factor knob).  Experts run as one grouped einsum
over the expert axis, which GSPMD shards over the ``model`` (EP) axis.

Supports arctic's parallel dense-FFN residual (``dense_ff``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from ..distributed.sharding import hint
from .layers import activation, dot, mlp

F32 = jnp.float32


def _capacity(moe: MoEConfig, num_tokens: int) -> int:
    c = int(moe.capacity_factor * num_tokens * moe.top_k / moe.num_experts)
    return max(8, -(-c // 8) * 8)          # >=8 and lane-aligned


def moe_block(x, p, moe: MoEConfig, act: str, gated: bool):
    """x: [B, S, D] (or [B, 1, D] decode) -> same shape."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    E, K = moe.num_experts, moe.top_k
    C = _capacity(moe, T)

    router_logits = dot(xt, p["router"].astype(xt.dtype))          # [T, E]
    probs = jax.nn.softmax(router_logits.astype(F32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                   # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # (expert, slot) assignment with running per-expert counts.
    counts = jnp.zeros((E,), jnp.int32)
    slot_list, keep_list = [], []
    for j in range(K):
        e = gate_idx[:, j]                                          # [T]
        onehot = jax.nn.one_hot(e, E, dtype=jnp.int32)              # [T, E]
        pos = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]      # [T, E]
        slot_in_e = jnp.take_along_axis(pos, e[:, None], axis=1)[:, 0]
        counts = counts + onehot.sum(axis=0)
        keep = slot_in_e < C
        slot_list.append(jnp.where(keep, e * C + slot_in_e, E * C))  # E*C=drop
        keep_list.append(keep)
    slots = jnp.stack(slot_list, axis=1)                            # [T, K]
    keeps = jnp.stack(keep_list, axis=1)                            # [T, K]

    # Dispatch: scatter token rows into [E*C, D] (dropped -> overflow row).
    disp = jnp.zeros((E * C + 1, D), xt.dtype)
    tok_rows = jnp.repeat(xt[:, None, :], K, axis=1).reshape(T * K, D)
    disp = disp.at[slots.reshape(-1)].set(tok_rows, mode="drop")
    xe = hint(disp[: E * C].reshape(E, C, D), "moe_disp")

    # Grouped expert FFN (EP over the expert axis).
    h = jnp.einsum("ecd,edf->ecf", xe, p["ew1"].astype(xe.dtype),
                   preferred_element_type=F32)
    if gated:
        g = jnp.einsum("ecd,edf->ecf", xe, p["ew3"].astype(xe.dtype),
                       preferred_element_type=F32)
        h = activation(h, act) * g
    else:
        h = activation(h, act)
    ye = jnp.einsum("ecf,efd->ecd", h.astype(xe.dtype),
                    p["ew2"].astype(xe.dtype),
                    preferred_element_type=F32)                     # [E, C, D]

    # Combine: gather each token's k expert outputs, weight by gates.
    ye_flat = jnp.concatenate(
        [ye.reshape(E * C, D), jnp.zeros((1, D), ye.dtype)], axis=0)
    per_k = ye_flat[slots.reshape(-1)].reshape(T, K, D)
    w = (gate_vals * keeps).astype(per_k.dtype)                     # [T, K]
    yt = jnp.einsum("tkd,tk->td", per_k, w,
                    preferred_element_type=F32).astype(x.dtype)

    if moe.dense_ff and "dw1" in p:                                 # arctic
        dense_p = {"w1": p["dw1"], "w2": p["dw2"]}
        if gated:
            dense_p["w3"] = p["dw3"]
        yt = yt + mlp(xt, dense_p, act, gated).astype(x.dtype)
    return yt.reshape(B, S, D)
