"""Attention: GQA/MHA with RoPE, qk-norm, logit softcap, sliding window.

Two execution paths:
  * :func:`attend` — chunked online-softmax attention (flash-style in pure
    JAX: ``lax.scan`` over KV chunks, O(S·chunk) memory) for training and
    long prefill.  The Pallas flash kernel in ``kernels/flash_attention.py``
    is the TPU hot path; this is its reference/portable implementation.
  * :func:`decode_attend` — single-step decode against a (possibly
    partially filled) KV cache.

Shapes: q [B, Sq, H, hd]; k, v [B, Skv, K, hd]; H = K * G (GQA groups).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -2.0e38


def _mask(q_pos, kv_pos, causal: bool, window: int, kv_len):
    """[Sq, C] boolean validity mask. ``window`` may be a traced scalar
    (0 = global)."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        in_win = (q_pos[:, None] - kv_pos[None, :]) < w
        m &= jnp.where(w > 0, in_win, True)
    if kv_len is not None:
        m &= kv_pos[None, :] < kv_len
    return m


def _cap(s, cap: float):
    if cap:
        s = cap * jnp.tanh(s / cap)
    return s


def attend(q, k, v, *, causal: bool = True, window=0, softcap: float = 0.0,
           q_offset=0, kv_len=None, chunk: int = 1024,
           scale: Optional[float] = None):
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else hd ** -0.5

    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = Skv
    nc = (Skv + pad) // chunk

    # Keep K/V in their storage dtype and accumulate in fp32 on the MXU
    # (preferred_element_type) — a materialized fp32 upcast of the whole
    # K/V stream dominated HBM traffic (§Perf iteration 1).
    qg = (q.astype(F32) * scale).astype(k.dtype).reshape(B, Sq, K, G, hd)
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    kc = jnp.moveaxis(k.reshape(B, nc, chunk, K, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, chunk, K, hd), 1, 0)

    def body(carry, xs):
        m, l, acc = carry
        ci, kb, vb = xs
        kv_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kb,
                       preferred_element_type=F32)
        s = _cap(s, softcap)
        valid = _mask(q_pos, kv_pos, causal, window, kv_len)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(vb.dtype), vb,
            preferred_element_type=F32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, F32)
    l0 = jnp.zeros((B, K, G, Sq), F32)
    a0 = jnp.zeros((B, K, G, Sq, hd), F32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nc, dtype=jnp.int32), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)   # [B,K,G,Sq,hd]->[B,Sq,H,hd]
    return out.astype(q.dtype)


def decode_attend(q, k, v, *, kv_len, window=0, softcap: float = 0.0,
                  q_pos=None, scale: Optional[float] = None):
    """One-token decode: q [B, 1, H, hd] against cache k/v [B, S, K, hd].

    ``kv_len`` (traced) is the filled length; ``q_pos`` the absolute
    position of the query token (defaults to kv_len - 1 after append).
    """
    B, _, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else hd ** -0.5
    q_pos = kv_len - 1 if q_pos is None else q_pos

    # bf16 K/V operands with fp32 MXU accumulation: no materialized
    # upcast of the cache (§Perf iteration 1).
    qg = (q.astype(F32) * scale).astype(k.dtype).reshape(B, K, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=F32)
    s = _cap(s, softcap)
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    valid = kv_pos[None] < kv_len
    w = jnp.asarray(window, jnp.int32)
    in_win = (q_pos - kv_pos[None]) < w
    valid &= jnp.where(w > 0, in_win, True)
    s = jnp.where(valid[:, None, None] if valid.ndim == 2 else valid,
                  s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)
