"""Mamba-2 (SSD: state-space duality) mixer, chunked for TPU.

The SSD recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,
y_t = C_t h_t + D x_t  is evaluated chunk-parallel (arXiv:2405.21060):
intra-chunk terms as a masked quadratic form (MXU-friendly), inter-chunk
via a ``lax.scan`` over per-chunk states.  Single-token decode keeps the
dense state ``[B, H, hd, N]`` plus the causal-conv tail.

Layout: d_inner = expand * d_model; H = d_inner / head_dim heads;
B/C are shared per group (n_groups, typically 1).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import SSMConfig
from .layers import causal_conv1d, dot, rms_norm

F32 = jnp.float32


def ssm_dims(d_model: int, s: SSMConfig) -> Tuple[int, int, int]:
    """(d_inner, num_heads, conv_channels)."""
    din = s.expand * d_model
    nheads = din // s.head_dim
    conv_ch = din + 2 * s.n_groups * s.d_state
    return din, nheads, conv_ch


def _split_proj(zxbcdt, d_model, s: SSMConfig):
    din, nheads, _ = ssm_dims(d_model, s)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [din, din + din + 2 * gn], axis=-1)
    return z, xbc, dt          # z: [..,din], xbc: [..,din+2gn], dt: [..,H]


def ssd_chunked(xh, dt, A, Bm, Cm, Dp, chunk: int,
                state0: Optional[jnp.ndarray] = None):
    """Chunk-parallel SSD.

    xh [B,S,H,hd]; dt [B,S,H] (softplus applied); A [H] (<0);
    Bm, Cm [B,S,G,N]; Dp [H].  Returns (y [B,S,H,hd], final_state
    [B,H,hd,N]).
    """
    B, S, H, hd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    R = H // G                                   # heads per group
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // Q

    def chunked(t, extra=()):                    # [B, S, ...] -> [nc, B, Q, ...]
        return jnp.moveaxis(t.reshape((B, nc, Q) + t.shape[2:]), 1, 0)

    xc, dtc = chunked(xh), chunked(dt)
    Bc, Cc = chunked(Bm), chunked(Cm)

    if state0 is None:
        state0 = jnp.zeros((B, H, hd, N), F32)

    def body(state, xs):
        xq, dtq, Bq, Cq = xs                     # [B,Q,...]
        xf = xq.astype(F32)
        dA = dtq.astype(F32) * A.astype(F32)     # [B,Q,H]
        cum = jnp.cumsum(dA, axis=1)             # inclusive cumsum
        # --- intra-chunk (masked quadratic): h_i += Σ_{j<=i} e^{cum_i-cum_j} dt_j B_j x_j
        diff = cum[:, :, None, :] - cum[:, None, :, :]      # [B,Q,Q,H]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bqgn,bpgn->bqpg", Cq.astype(F32), Bq.astype(F32),
                        preferred_element_type=F32)          # [B,Q,Q,G]
        CB = jnp.repeat(CB, R, axis=3)                       # [B,Q,Q,H]
        W = CB * L * dtq.astype(F32)[:, None, :, :]          # weight for x_j
        y_diag = jnp.einsum("bqph,bphd->bqhd", W, xf,
                            preferred_element_type=F32)
        # --- inter-chunk: h_i also carries e^{cum_i} * S_in
        Cq_h = jnp.repeat(Cq.astype(F32), R, axis=2)         # [B,Q,H,N]
        y_off = jnp.einsum("bqhn,bhdn->bqhd", Cq_h, state,
                           preferred_element_type=F32)
        y_off = y_off * jnp.exp(cum)[:, :, :, None]
        y = y_diag + y_off
        # --- state update: S_out = e^{cum_Q} S_in + Σ_j e^{cum_Q-cum_j} dt_j B_j⊗x_j
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)         # [B,Q,H]
        Bq_h = jnp.repeat(Bq.astype(F32), R, axis=2)         # [B,Q,H,N]
        contrib = jnp.einsum("bqh,bqhd,bqhn->bhdn",
                             decay_to_end * dtq.astype(F32), xf, Bq_h,
                             preferred_element_type=F32)
        state = jnp.exp(cum[:, -1, :])[:, :, None, None] * state + contrib
        return state, y

    state, ys = jax.lax.scan(body, state0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S + pad, H, hd)[:, :S]
    y = y + Dp.astype(F32)[None, None, :, None] * xh[:, :S].astype(F32)
    return y, state


def ssd_decode(x1, dt1, A, B1, C1, Dp, state):
    """Single-token SSD update.

    x1 [B,H,hd]; dt1 [B,H]; B1,C1 [B,G,N]; state [B,H,hd,N].
    """
    Bsz, H, hd = x1.shape
    G = B1.shape[1]
    R = H // G
    dA = jnp.exp(dt1.astype(F32) * A.astype(F32))            # [B,H]
    B_h = jnp.repeat(B1.astype(F32), R, axis=1)              # [B,H,N]
    C_h = jnp.repeat(C1.astype(F32), R, axis=1)
    contrib = (dt1.astype(F32)[:, :, None, None]
               * x1.astype(F32)[..., None] * B_h[:, :, None, :])
    state = dA[:, :, None, None] * state + contrib
    y = jnp.einsum("bhdn,bhn->bhd", state, C_h,
                   preferred_element_type=F32)
    y = y + Dp.astype(F32)[None, :, None] * x1.astype(F32)
    return y, state


def mamba_mixer(x, p, d_model: int, s: SSMConfig,
                conv_state=None, ssm_state=None, decode: bool = False):
    """Full mamba-2 block: in_proj -> conv -> SSD -> gated norm -> out_proj.

    Prefill/train: x [B,S,D], returns (y, (conv_state, ssm_state)).
    Decode: x [B,1,D] with states threaded through.
    """
    din, H, conv_ch = ssm_dims(d_model, s)
    gn = s.n_groups * s.d_state
    zxbcdt = dot(x, p["in_proj"].astype(x.dtype)).astype(x.dtype)
    z, xbc, dt = _split_proj(zxbcdt, d_model, s)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))
    A = -jnp.exp(p["A_log"].astype(F32))

    xbc, conv_state = causal_conv1d(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [din, din + gn], axis=-1)
    Bsz, S = x.shape[0], x.shape[1]
    xh = xs.reshape(Bsz, S, H, s.head_dim)
    Bm = Bm.reshape(Bsz, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(Bsz, S, s.n_groups, s.d_state)

    if decode:
        y, ssm_state = ssd_decode(xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                                  p["Dp"], ssm_state)
        y = y[:, None]                                       # [B,1,H,hd]
    else:
        y, ssm_state = ssd_chunked(xh, dt, A, Bm, Cm, p["Dp"], s.chunk,
                                   ssm_state)
    y = y.reshape(Bsz, S, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype),
                 p["ssm_norm"])
    out = dot(y, p["out_proj"].astype(x.dtype)).astype(x.dtype)
    return out, (conv_state, ssm_state)
