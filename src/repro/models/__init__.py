from .registry import ModelAPI, build, input_specs, param_shapes

__all__ = ["ModelAPI", "build", "input_specs", "param_shapes"]
