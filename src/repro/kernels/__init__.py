"""Pallas TPU kernels for the paper's perf-critical compute hot-spots.

Each kernel = <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling)
+ a jit'd wrapper in ops.py + a pure-jnp oracle in ref.py.  On CPU the
kernels run with interpret=True (validated against ref.py in tests/).
"""
from . import ref
from .ops import dedup_embedding, dedup_matmul, flash_attention, lsh_signature

__all__ = ["ref", "dedup_embedding", "dedup_matmul", "flash_attention",
           "lsh_signature"]
