"""Pallas TPU kernel: embedding lookup from a deduplicated row-block pool.

The paper's word2vec scenario (Sec. 7.1.1/7.2.1): the embedding matrix is
stored as row blocks ([bv, D] slabs), deduplicated across model variants.
Token ids are scalar-prefetched; for token ``t`` the index_map selects
physical block ``row_block_map[ids[t] // bv]`` and the kernel copies row
``ids[t] % bv`` out of it.  Consecutive tokens hitting the same physical
block reuse the already-resident VMEM tile (Pallas skips the DMA when the
index_map output repeats) — sorting/batching ids by block, as the serving
engine's batcher does, is the VMEM analogue of the paper's cache-locality
optimization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params


def _kernel(ids_ref, rbmap_ref, w_ref, o_ref, *, bv: int):
    t = pl.program_id(0)
    row = ids_ref[t] % bv
    o_ref[0, :] = w_ref[0, row, :]


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def dedup_embedding(ids, pool, row_block_map, *, bd: int = 512,
                    interpret: bool = False):
    """ids [B] int32 -> [B, D] rows of the virtual embedding.

    pool [n_distinct, bv, D]; row_block_map [V/bv] int32.
    """
    (B,) = ids.shape
    n_distinct, bv, D = pool.shape
    bd = min(bd, D)
    assert D % bd == 0, (D, bd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # ids, row_block_map
        grid=(B, D // bd),
        in_specs=[
            pl.BlockSpec((1, bv, bd),
                         lambda t, j, ids, rbmap: (rbmap[ids[t] // bv], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda t, j, ids, rbmap: (t, j)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, bv=bv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), pool.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "parallel")),
        interpret=interpret,
    )
    return fn(ids.astype(jnp.int32), row_block_map.astype(jnp.int32), pool)
