"""Pallas TPU kernel: matmul against a *virtual* (deduplicated) weight.

The paper stores a weight tensor as pages of distinct blocks plus a
per-tensor indirection (Sec. 3/5).  On TPU we keep the distinct-block
pool in HBM and let the **scalar-prefetched block map drive the
``BlockSpec`` index_map**: for output tile (i, j) at contraction step k,
the kernel DMAs physical block ``block_map[k, j]`` from the pool into
VMEM instead of a dense W tile.  Dedup therefore happens *inside the
HBM->VMEM stream*: shared blocks are fetched once per (k, j) visit, and
Pallas's pipeline skips the re-fetch entirely when consecutive grid
steps map to the same physical block — the VMEM-level analogue of the
paper's shared-page buffer-pool hit.

Tiling: block shape (bk, bn) is the storage block shape — hardware
aligned (multiples of 8x128; default 256x256 = MXU-native).  x is tiled
(bm, bk); the k-loop is the innermost ("arbitrary") grid dim and
accumulates into the output tile in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

F32 = jnp.float32


def _kernel(bmap_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=F32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "interpret", "out_dtype"))
def dedup_matmul(x, pool, block_map, *, bm: int = 128,
                 interpret: bool = False, out_dtype=None):
    """x [M, K] @ W_virtual -> [M, N].

    pool [n_distinct, bk, bn]; block_map [K/bk, N/bn] int32.
    M must be a multiple of ``bm`` (ops.py pads).
    """
    M, K = x.shape
    nkb, nnb = block_map.shape
    bk, bn = pool.shape[1], pool.shape[2]
    assert K == nkb * bk, (K, nkb, bk)
    N = nnb * bn
    out_dtype = out_dtype or x.dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M // bm, nnb, nkb),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, bmap: (i, k)),
            pl.BlockSpec((1, bk, bn),
                         lambda i, j, k, bmap: (bmap[k, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, bmap: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, nk=nkb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(block_map, x, pool)
