"""Pallas version-compat helpers (leaf module: no intra-package imports,
so kernel modules and ops.py can both depend on it in any load order)."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (jax >= 0.5) vs ``pltpu.TPUCompilerParams``
    (jax 0.4.x)."""
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
