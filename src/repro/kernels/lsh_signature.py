"""Pallas TPU kernel: fused L2-LSH signature computation (index build).

Signature = floor((blocks @ proj + bias) / r) — a matmul with a fused
quantize epilogue.  This is the hot loop of the paper's duplicate
detection (Alg. 1 computes a signature per block per model); fusing the
floor/divide avoids materializing the fp32 projection in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

F32 = jnp.float32


def _kernel(x_ref, p_ref, b_ref, o_ref, acc_ref, *, nk: int, r: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], p_ref[...],
                            preferred_element_type=F32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = jnp.floor((acc_ref[...] + b_ref[...]) / r
                               ).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("r", "bm", "bk", "bh", "interpret"))
def lsh_signature(blocks, proj, bias, *, r: float, bm: int = 128,
                  bk: int = 512, bh: int = 128, interpret: bool = False):
    """blocks [n, dim] fp32; proj [dim, num_hashes]; bias [num_hashes]
    -> int32 [n, num_hashes].  ops.py pads n/dim/num_hashes to tiles."""
    n, dim = blocks.shape
    num_hashes = proj.shape[1]
    bm, bk, bh = min(bm, n), min(bk, dim), min(bh, num_hashes)
    assert n % bm == 0 and dim % bk == 0 and num_hashes % bh == 0
    nk = dim // bk

    fn = pl.pallas_call(
        functools.partial(_kernel, nk=nk, r=r),
        grid=(n // bm, num_hashes // bh, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bh), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bh), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bh), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, num_hashes), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bh), F32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(blocks, proj, bias.reshape(1, -1))
