"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def materialize_virtual(pool, block_map, K: int, N: int):
    """pool [n_distinct, bk, bn] + block_map [K/bk, N/bn] -> dense W [K, N]."""
    nkb, nnb = block_map.shape
    bk, bn = pool.shape[1], pool.shape[2]
    blocks = pool[block_map.reshape(-1)]                 # [nkb*nnb, bk, bn]
    W = (blocks.reshape(nkb, nnb, bk, bn)
               .transpose(0, 2, 1, 3)
               .reshape(nkb * bk, nnb * bn))
    return W[:K, :N]


def dedup_matmul(x, pool, block_map, out_dtype=None):
    """x [M, K] @ W_virtual[K, N]  (paper Sec. 2.2 FFNN inference, with the
    tensor blocks deduplicated through the block map)."""
    K = block_map.shape[0] * pool.shape[1]
    N = block_map.shape[1] * pool.shape[2]
    W = materialize_virtual(pool, block_map, K, N)
    y = jnp.matmul(x, W.astype(x.dtype), preferred_element_type=F32)
    return y.astype(out_dtype or x.dtype)


def dedup_embedding(ids, pool, row_block_map, d_model: int):
    """Embedding lookup from a deduplicated row-block pool.

    pool [n_distinct, bv, D]; row_block_map [V/bv] -> distinct id.
    ids [B] -> [B, D].
    """
    bv = pool.shape[1]
    rb = ids // bv
    off = ids % bv
    blocks = pool[row_block_map[rb]]                     # [B, bv, D]
    return jnp.take_along_axis(
        blocks, off[:, None, None].astype(jnp.int32).repeat(1, 1),
        axis=1)[:, 0, :d_model]


def lsh_signature(blocks, proj, bias, r: float):
    """[n, dim] fp32 -> int32 signatures [n, num_hashes] (Sec. 4.2.2)."""
    h = jnp.floor((blocks.astype(F32) @ proj.astype(F32) + bias) / r)
    return h.astype(jnp.int32)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None):
    """q [B, Sq, H, hd]; k, v [B, Skv, K, hd] -> [B, Sq, H, hd]."""
    B, Sq, H, hd = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = scale if scale is not None else hd ** -0.5
    qg = (q.astype(F32) * scale).reshape(B, Sq, Kh, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(F32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= qp >= kp
    if window:
        m &= (qp - kp) < window
    s = jnp.where(m[None, None, None], s, -2e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(F32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
