"""Pallas TPU kernel: flash attention (prefill hot-spot).

Online-softmax blocked attention: grid (B*K_heads*G, Sq/bq, Skv/bkv) with
the KV dim innermost; m/l/acc accumulators live in VMEM scratch across KV
steps.  Supports causal masking, sliding window, and gemma2 logit
softcap.  Causal/window-skipped KV blocks are masked (the index map still
visits them; the §Perf log covers the block-skip upgrade).

This kernel is the TPU hot path behind ``models.attention.attend`` (the
pure-JAX chunked implementation doubles as its oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

F32 = jnp.float32
NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nkv: int, bq: int, bkv: int, causal: bool, window: int,
            softcap: float, scale: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q = q_ref[0].astype(F32) * scale                     # [bq, hd]
    k = k_ref[0].astype(F32)                             # [bkv, hd]
    s = jnp.dot(q, k.T, preferred_element_type=F32)      # [bq, bkv]
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kp = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))           # [bq]
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p, v_ref[0].astype(F32),
                              preferred_element_type=F32))
    m_ref[...] = m_new

    @pl.when(j == nkv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale=None, bq: int = 512,
                    bkv: int = 512, interpret: bool = False):
    """q [B, Sq, H, hd]; k, v [B, Skv, K, hd] (GQA) -> [B, Sq, H, hd].

    Sq % bq == 0 and Skv % bkv == 0 (ops.py pads).
    """
    B, Sq, H, hd = q.shape
    Skv, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = scale if scale is not None else hd ** -0.5
    bq, bkv = min(bq, Sq), min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0

    # Layout: fold heads into the batch grid dim; q by (kv-head, group).
    qf = (q.reshape(B, Sq, Kh, G, hd)
           .transpose(0, 2, 3, 1, 4)
           .reshape(B * Kh * G, Sq, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kh, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kh, Skv, hd)
    nkv = Skv // bkv

    fn = pl.pallas_call(
        functools.partial(_kernel, nkv=nkv, bq=bq, bkv=bkv, causal=causal,
                          window=window, softcap=softcap, scale=scale),
        grid=(B * Kh * G, Sq // bq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Kh * G, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), F32),
                        pltpu.VMEM((bq,), F32),
                        pltpu.VMEM((bq, hd), F32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    of = fn(qf, kf, vf)
    return (of.reshape(B, Kh, G, Sq, hd)
              .transpose(0, 3, 1, 2, 4)
              .reshape(B, Sq, H, hd))
