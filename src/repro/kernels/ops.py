"""Public jit'd wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; on CPU (this container) the
kernels execute through ``interpret=True`` — same kernel body, Python
interpretation, used by the allclose test sweeps against ``ref.py``.
Wrappers handle padding to tile multiples and unpadding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from ._compat import tpu_compiler_params  # re-export: version-compat shim
from .dedup_embedding import dedup_embedding as _dedup_embedding
from .dedup_matmul import dedup_matmul as _dedup_matmul
from .flash_attention import flash_attention as _flash_attention
from .lsh_signature import lsh_signature as _lsh_signature


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def dedup_matmul(x, pool, block_map, bm: int = 128, out_dtype=None):
    """x [M, K] (or [..., K]) @ virtual W -> [..., N]."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x2, padm = _pad_to(x2, 0, bm)
    y = _dedup_matmul(x2, pool, block_map, bm=bm,
                      interpret=_interpret(), out_dtype=out_dtype)
    if padm:
        y = y[: y.shape[0] - padm]
    return y.reshape(lead + (y.shape[-1],))


def dedup_embedding(ids, pool, row_block_map):
    lead = ids.shape
    out = _dedup_embedding(ids.reshape(-1), pool, row_block_map,
                           interpret=_interpret())
    return out.reshape(lead + (out.shape[-1],))


def dedup_embedding_striped(ids, pool, block_map, width=None):
    """Row gather from a 2-D virtual tensor stored as ``(bh, bw)`` blocks.

    The plain ``dedup_embedding`` kernel assumes row blocks spanning the
    full model dimension (``pool [n, bv, D]``).  Storage blocks are square
    tiles, so a row of the virtual tensor crosses ``gw`` column stripes:
    this adapter runs the kernel once per stripe against the same resident
    pool — each stripe's ``block_map[:, j]`` is its own row-block map —
    and concatenates, trimming the ragged last stripe to ``width``.

    ids [B]; pool [n_blocks, bh, bw]; block_map [gh, gw] int32.
    Returns [B, width or gw*bw].
    """
    gh, gw = block_map.shape
    outs = [dedup_embedding(ids, pool, block_map[:, j]) for j in range(gw)]
    out = outs[0] if gw == 1 else jnp.concatenate(outs, axis=1)
    return out if width is None else out[:, :width]


def lsh_signature(blocks, proj, bias, r: float):
    n, dim = blocks.shape
    blocks = blocks.reshape(n, dim).astype(jnp.float32)
    blocks, padn = _pad_to(blocks, 0, 128)
    blocks, padk = _pad_to(blocks, 1, 512 if dim >= 512 else 8)
    proj = jnp.pad(proj.astype(jnp.float32), ((0, padk), (0, 0)))
    nh = proj.shape[1]
    proj, padh = _pad_to(proj, 1, 128 if nh >= 128 else 8)
    bias = jnp.pad(bias.astype(jnp.float32), (0, padh))
    bk = 512 if blocks.shape[1] % 512 == 0 else 8
    bh = 128 if proj.shape[1] % 128 == 0 else 8
    sig = _lsh_signature(blocks, proj, bias, r=float(r), bk=bk, bh=bh,
                         interpret=_interpret())
    return sig[:n, :nh]


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, bq=512, bkv=512):
    Sq, Skv = q.shape[1], k.shape[1]
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    q, padq = _pad_to(q, 1, bq)
    k, padk = _pad_to(k, 1, bkv)
    v, _ = _pad_to(v, 1, bkv)
    if padk and not causal:
        raise ValueError("non-causal padding needs an explicit kv mask; "
                         "pad Skv to a bkv multiple upstream")
    out = _flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale, bq=bq, bkv=bkv,
                           interpret=_interpret())
    return out[:, :Sq]


__all__ = ["dedup_matmul", "dedup_embedding", "dedup_embedding_striped",
           "lsh_signature", "flash_attention", "ref", "tpu_compiler_params"]
