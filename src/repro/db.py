"""DedupDB: the one-call facade over store + backend + server + engines.

The paper's deployment story in five verbs::

    from repro.db import DedupDB

    db = DedupDB.open("sqlite:///models.db")     # or file:// / objsim://
    db.register("bert-v0", tensors)              # Alg. 1 dedup
    db.update("bert-v0", new_tensors)            # Sec. 7.6 delta update
    db.commit()                                  # transactional manifest
    engine = db.serve_embedding(heads)           # Eq.-2 pool + scheduler

``open`` on a URL with a committed manifest returns a *live* database:
pages stay paged in the backend and fault in (grouped) as serving
touches them.  ``serve_embedding`` / ``serve_lm`` wire a
:class:`~repro.serving.engine.WeightServer` whose miss costs are charged
from a :meth:`StorageModel.from_backend` microbenchmark calibration of
the very backend serving the pages — not a hardcoded hdd/ssd/nvme
preset — plus the scheduler/prefetcher stack from PR 1/2.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

import numpy as np

from .core.dedup import DedupResult, Evaluator
from .core.store import ModelStore, StoreConfig
from .serving.engine import (EmbeddingServingEngine, LMServingEngine,
                             StorageModel, WeightServer)
from .storage import PageBackend, open_backend

__all__ = ["DedupDB"]


class DedupDB:
    """A deduplicated model database bound to one storage backend."""

    def __init__(self, store: ModelStore, backend: PageBackend):
        self.store = store
        self.backend = backend

    # ------------------------------------------------------------- open --
    @classmethod
    def open(cls, url, cfg: Optional[StoreConfig] = None) -> "DedupDB":
        """Open (or initialize) a dedup database at a storage URL.

        With a committed manifest the store comes back *live* (paged,
        nothing densified); on a fresh target an empty store is bound to
        the backend and the first :meth:`commit` creates the manifest.
        ``cfg`` overrides the persisted store configuration."""
        from .storage.faults import maybe_wrap
        backend = maybe_wrap(open_backend(url))   # REPRO_FAULTS chaos hook
        if backend.has_manifest():
            store = ModelStore.open(backend, cfg)
        else:
            store = ModelStore(cfg)
            store._backend = backend             # bind for commit()/save()
        return cls(store, backend)

    def close(self) -> None:
        self.backend.close()

    # -------------------------------------------------------- lifecycle --
    def register(self, model: str, tensors: Mapping[str, np.ndarray],
                 evaluator: Optional[Evaluator] = None,
                 layers=None) -> DedupResult:
        return self.store.register(model, tensors, evaluator, layers)

    def update(self, model: str, tensors: Mapping[str, np.ndarray],
               evaluator: Optional[Evaluator] = None,
               approach: int = 2) -> DedupResult:
        return self.store.update(model, tensors, evaluator, approach)

    def remove(self, model: str) -> None:
        self.store.remove(model)

    def commit(self) -> Dict:
        """Persist the current packing: content-addressed pages + the
        transactional manifest, pruning pages orphaned by repacks."""
        return self.store.save(self.backend)

    def models(self):
        return sorted(self.store.dedup.models)

    # ---------------------------------------------------------- serving --
    def storage_model(self, page_bytes: Optional[int] = None,
                      **kw) -> StorageModel:
        """A :class:`StorageModel` calibrated from this backend's
        microbenchmark (the tier that actually holds the pages)."""
        if page_bytes is None:
            bh, bw = self.store.cfg.dedup.block_shape
            page_bytes = self.store.cfg.blocks_per_page * bh * bw \
                * self.store.native_page_dtype().itemsize
        return StorageModel.from_backend(self.backend,
                                         page_bytes=page_bytes, **kw)

    def weight_server(self, capacity_pages: Optional[int] = None,
                      policy: str = "optimized_mru",
                      storage: Optional[StorageModel] = None,
                      compute_backend: str = "numpy",
                      kernel_mode: str = "auto",
                      shards: int = 1,
                      placement: str = "sharers",
                      transfer: str = "grouped") -> WeightServer:
        """ModelStore + Eq.-2 buffer pool + calibrated storage clock.
        ``compute_backend="device"`` serves through the HBM page slab
        (DESIGN.md §3); slab faults then source pages straight from this
        database's backend.  ``shards > 1`` partitions the slab across a
        device mesh with the selected placement policy (DESIGN.md §5;
        capacity is then per shard).  ``transfer`` selects the host->HBM
        movement path (DESIGN.md §6: "grouped" batches a miss group into
        one staged transfer; "per_page" is the legacy per-miss path)."""
        if capacity_pages is None:
            capacity_pages = max(1, self.store.num_pages())
        if shards > 1:
            if compute_backend != "device":
                raise ValueError("shards > 1 requires "
                                 "compute_backend='device'")
            from .launch.mesh import shard_devices
            from .serving.shard_pool import ShardedWeightServer
            return ShardedWeightServer(self.store, capacity_pages, policy,
                                       storage or self.storage_model(),
                                       shards=shards, placement=placement,
                                       kernel_mode=kernel_mode,
                                       devices=shard_devices(shards),
                                       transfer=transfer)
        return WeightServer(self.store, capacity_pages, policy,
                            storage or self.storage_model(),
                            backend=compute_backend, kernel_mode=kernel_mode,
                            transfer=transfer)

    def serve_embedding(self, heads: Dict[str, np.ndarray],
                        capacity_pages: Optional[int] = None,
                        policy: str = "optimized_mru",
                        scheduler="round_robin",
                        overlap: bool = False, prefetch: bool = False,
                        compute_backend: str = "numpy",
                        kernel_mode: str = "auto",
                        storage: Optional[StorageModel] = None,
                        embed_tensor: str = "embedding",
                        shards: int = 1, placement: str = "sharers",
                        transfer: str = "grouped",
                        ) -> EmbeddingServingEngine:
        """The paper's multi-model embedding scenario, served out of this
        database in one call.  Returns the engine; ``submit``/``run`` it."""
        server = self.weight_server(capacity_pages, policy, storage,
                                    compute_backend, kernel_mode,
                                    shards=shards, placement=placement,
                                    transfer=transfer)
        prefetcher = None
        if prefetch:
            from .serving.prefetch import Prefetcher
            prefetcher = Prefetcher(server)
            overlap = True        # speculation only pays under compute
        return EmbeddingServingEngine(server, heads,
                                      embed_tensor=embed_tensor,
                                      scheduler=scheduler,
                                      prefetcher=prefetcher, overlap=overlap)

    def serve_lm(self, apis: Dict[str, object],
                 params_template: Dict[str, dict],
                 capacity_pages: Optional[int] = None,
                 policy: str = "optimized_mru",
                 scheduler="fifo",
                 overlap: bool = False, prefetch: bool = False,
                 compute_backend: str = "numpy",
                 kernel_mode: str = "auto",
                 storage: Optional[StorageModel] = None,
                 shards: int = 1, placement: str = "sharers",
                 transfer: str = "grouped",
                 ) -> LMServingEngine:
        """LM variants served via prefill/decode with weights faulted
        through the pool (and the backend) on model switch."""
        server = self.weight_server(capacity_pages, policy, storage,
                                    compute_backend, kernel_mode,
                                    shards=shards, placement=placement,
                                    transfer=transfer)
        prefetcher = None
        if prefetch:
            from .serving.prefetch import Prefetcher
            prefetcher = Prefetcher(server)
            overlap = True
        return LMServingEngine(server, apis, params_template,
                               scheduler=scheduler, prefetcher=prefetcher,
                               overlap=overlap)
