from .analysis import (HW, collective_bytes_from_hlo, roofline_terms,
                       summarize_cell)

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_terms",
           "summarize_cell"]
