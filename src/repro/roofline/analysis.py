"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e-class, per assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

The compiled module is the *per-device* SPMD program, so
``cost_analysis()`` FLOPs/bytes and parsed collective bytes are already
per-chip; terms are seconds-per-step on one chip:

  compute  = flops / peak_flops
  memory   = bytes_accessed / hbm_bw
  collective = collective_bytes / ici_bw

collective_bytes sums the *result* buffer of every collective op in the
optimized HLO (start/done pairs counted once); all-reduce is counted
twice (reduce-scatter + all-gather phases of a ring).  This is a
bandwidth-optimal-ring lower bound — latency terms and DCN (pod axis)
slowdown are noted qualitatively in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

HW = {
    "peak_flops": 197e12,        # bf16 per chip
    "hbm_bw": 819e9,             # bytes/s
    "ici_bw": 50e9,              # bytes/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _array_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind result bytes from optimized HLO text."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|\S+)\s+([\w-]+)", rhs)
        if not m:
            continue
        type_str, opname = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start":
                base = c
                break
        if base is None:
            continue       # -done ops carry no new transfer
        out[base] += _array_bytes(type_str)
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k in _COLLECTIVES)
    # all-reduce moves ~2x its buffer over the wire (RS + AG ring phases)
    out["weighted_total"] = out["total"] + out["all-reduce"]
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float) -> Dict[str, float]:
    terms = {
        "compute_s": flops / HW["peak_flops"],
        "memory_s": bytes_accessed / HW["hbm_bw"],
        "collective_s": collective_bytes / HW["ici_bw"],
    }
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = (terms["compute_s"] / bound) if bound else 0.0
    return terms


def summarize_cell(record: Dict, model_flops: Optional[float] = None) -> Dict:
    """record: one dry-run JSON dict -> roofline summary row."""
    cost = record.get("cost_analysis", {})
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = record.get("collectives", {})
    terms = roofline_terms(flops, bytes_accessed,
                           float(coll.get("weighted_total", 0.0)))
    out = dict(record.get("meta", {}))
    out.update(terms)
    out["flops"] = flops
    out["bytes_accessed"] = bytes_accessed
    out["collective_bytes"] = coll.get("weighted_total", 0.0)
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flops_ratio"] = model_flops / flops if flops else 0.0
    return out
