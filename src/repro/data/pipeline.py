"""Deterministic synthetic data pipeline.

Design goals for the 1000+-node story:
  * **Host-sharded determinism**: batch content is a pure function of
    (seed, step, host_slice), so any host can regenerate any shard —
    restart/elastic re-mesh never needs data-state checkpoints, and a
    straggler's microbatch can be dropped or recomputed by a peer.
  * **Model-served tasks** for the paper's evaluation scenarios: a
    synthetic text-classification family (shared "pretrained" embedding +
    per-variant fine-tune deltas) that gives dedup benchmarks real
    accuracy signals on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np


def token_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
                  host_index: int = 0, host_count: int = 1
                  ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite LM batches; ``labels`` = next-token shift of ``tokens``.
    Each (step, host) pair derives its own RNG stream."""
    per_host = batch // host_count
    step = 0
    while True:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, host_index]))
        toks = rng.integers(0, vocab, (per_host, seq + 1), dtype=np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        step += 1


def make_batch_from_specs(specs, *, seed: int = 0) -> Dict:
    """Concrete batch matching an ``input_specs`` pytree (smoke tests)."""
    rng = np.random.default_rng(seed)

    def gen(sds):
        if np.issubdtype(sds.dtype, np.integer):
            return rng.integers(0, 64, sds.shape).astype(sds.dtype)
        return rng.standard_normal(sds.shape).astype(sds.dtype)

    return jax.tree.map(gen, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@dataclasses.dataclass
class SyntheticTextTask:
    """A linearly-separable 'review classification' family (paper Sec. 7.1.2).

    A shared 'pretrained' embedding [V, d] plus per-variant class
    directions; variant k's corpus is drawn from its own label planes, so
    fine-tuning mutates a small fraction of embedding rows — exactly the
    paper's multi-version-model sharing structure.
    """
    vocab: int = 2048
    d: int = 64
    num_classes: int = 2
    doc_len: int = 16
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.base_embed = (rng.standard_normal((self.vocab, self.d))
                           * 0.05).astype(np.float32)
        self.class_w = (rng.standard_normal((self.d, self.num_classes))
                        * 0.5).astype(np.float32)
        # class-informative token sets
        self.token_class = rng.integers(0, self.num_classes, self.vocab)

    def variant_embedding(self, variant: int,
                          touched_frac: float = 0.08) -> np.ndarray:
        """Fine-tuned copy: a small random subset of rows gets a delta."""
        rng = np.random.default_rng(self.seed + 1000 + variant)
        emb = self.base_embed.copy()
        n_touch = int(self.vocab * touched_frac)
        rows = rng.choice(self.vocab, n_touch, replace=False)
        emb[rows] += (rng.standard_normal((n_touch, self.d))
                      * 0.02).astype(np.float32)
        return emb

    def sample(self, n: int, *, variant: int = 0,
               seed: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(docs [n, doc_len] int32, labels [n]) — label = majority class
        of the informative tokens in the doc."""
        rng = np.random.default_rng(self.seed + 77 + variant
                                    if seed is None else seed)
        labels = rng.integers(0, self.num_classes, n)
        docs = np.empty((n, self.doc_len), np.int64)
        for i, y in enumerate(labels):
            pool = np.where(self.token_class == y)[0]
            other = rng.integers(0, self.vocab, self.doc_len // 4)
            main = rng.choice(pool, self.doc_len - len(other))
            docs[i] = np.concatenate([main, other])
        return docs.astype(np.int32), labels.astype(np.int32)

    def accuracy(self, embed: np.ndarray, head: np.ndarray,
                 docs: np.ndarray, labels: np.ndarray) -> float:
        """Mean-pooled bag-of-embeddings classifier accuracy."""
        feats = embed[docs].mean(axis=1)                 # [n, d]
        pred = (feats @ head).argmax(axis=1)
        return float((pred == labels).mean())

    def train_head(self, embed: np.ndarray, variant: int = 0,
                   n: int = 512, steps: int = 200,
                   lr: float = 0.5) -> np.ndarray:
        """Logistic-regression head on top of (frozen) embeddings."""
        docs, labels = self.sample(n, variant=variant, seed=self.seed + 5)
        feats = embed[docs].mean(axis=1)
        W = np.zeros((self.d, self.num_classes), np.float32)
        onehot = np.eye(self.num_classes, dtype=np.float32)[labels]
        for _ in range(steps):
            logits = feats @ W
            logits -= logits.max(axis=1, keepdims=True)
            p = np.exp(logits)
            p /= p.sum(axis=1, keepdims=True)
            grad = feats.T @ (p - onehot) / len(labels)
            W -= lr * grad
        return W
