from .pipeline import (SyntheticTextTask, make_batch_from_specs,
                       token_batches)

__all__ = ["SyntheticTextTask", "make_batch_from_specs", "token_batches"]
