"""Packing distinct tensor blocks into pages (paper Sec. 5).

Every tensor must be *exactly* the union of a subset of pages (MTPPDP);
minimizing stored pages is NP-hard (reduction from Set Basis, Thm. 1).

Implemented strategies (paper Tab. 7):
  * ``pack_dedup_base``  — DedupBase: pack in write order, drop duplicate pages.
  * ``pack_greedy1``     — Alg. 2: per-equivalent-class packing.
  * ``pack_greedy2``     — Alg. 3: largest-tensor-first / hottest-block-first.
  * ``pack_two_stage``   — Alg. 2 then Alg. 3 on blocks from non-full pages.

A *page* is an ordered list of distinct-block ids (its slot layout); pages
may overlap in blocks (Alg. 3 may duplicate — Sec. 5.3 bounds the copies).
The coverage invariant (checked by :func:`check_coverage`, and by a
hypothesis property test) is: for every tensor, the union of the contents
of its assigned pages equals exactly its set of distinct blocks.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, FrozenSet, List, Mapping, Sequence, Tuple

import numpy as np

TensorRef = Tuple[str, str]


@dataclasses.dataclass
class PackResult:
    pages: List[List[int]]                      # page id -> ordered block slots
    tensor_pages: Dict[TensorRef, List[int]]    # tensor -> page ids (exact cover)
    strategy: str = ""

    @property
    def num_pages(self) -> int:
        return len(self.pages)

    def num_shared_pages(self) -> int:
        counts: Dict[int, int] = defaultdict(int)
        for pids in self.tensor_pages.values():
            for p in set(pids):
                counts[p] += 1
        return sum(1 for c in counts.values() if c > 1)

    def pages_of(self, tensor: TensorRef) -> List[List[int]]:
        return [self.pages[p] for p in self.tensor_pages[tensor]]


def equivalent_classes(tensor_sets: Mapping[TensorRef, FrozenSet[int]]
                       ) -> Dict[FrozenSet[TensorRef], List[int]]:
    """Group distinct blocks by the exact set of tensors owning them
    (Sec. 5.2, Fig. 6)."""
    owners: Dict[int, set] = defaultdict(set)
    for t, blocks in tensor_sets.items():
        for b in blocks:
            owners[b].add(t)
    classes: Dict[FrozenSet[TensorRef], List[int]] = defaultdict(list)
    for b in sorted(owners):
        classes[frozenset(owners[b])].append(b)
    return dict(classes)


def _chunk(blocks: Sequence[int], l: int) -> List[List[int]]:
    return [list(blocks[i: i + l]) for i in range(0, len(blocks), l)]


# --------------------------------------------------------------- DedupBase ---
def pack_dedup_base(tensor_seqs: Mapping[TensorRef, np.ndarray],
                    l: int) -> PackResult:
    """Default DB paging: blocks packed in write order per tensor, then
    byte-identical pages deduplicated (paper Fig. 5 'default packing')."""
    pages: List[List[int]] = []
    seen: Dict[Tuple[int, ...], int] = {}
    tensor_pages: Dict[TensorRef, List[int]] = {}
    for t, seq in tensor_seqs.items():
        pids: List[int] = []
        for chunk in _chunk([int(x) for x in seq], l):
            key = tuple(chunk)
            if key not in seen:
                seen[key] = len(pages)
                pages.append(chunk)
            pids.append(seen[key])
        tensor_pages[t] = pids
    return PackResult(pages, tensor_pages, "dedup_base")


# ------------------------------------------------------------------ Alg. 2 ---
def pack_greedy1(tensor_sets: Mapping[TensorRef, FrozenSet[int]],
                 l: int) -> PackResult:
    """Equivalent-class greedy (Alg. 2).  ``Alg2(P) <= OPT + 2^k - 1``."""
    classes = equivalent_classes(tensor_sets)
    pages: List[List[int]] = []
    tensor_pages: Dict[TensorRef, List[int]] = defaultdict(list)
    for owners in sorted(classes, key=lambda o: (-len(classes[o]), sorted(o))):
        for chunk in _chunk(classes[owners], l):
            pid = len(pages)
            pages.append(chunk)
            for t in owners:
                tensor_pages[t].append(pid)
    for t in tensor_sets:
        tensor_pages.setdefault(t, [])
    return PackResult(pages, dict(tensor_pages), "greedy1")


# ------------------------------------------------------------------ Alg. 3 ---
def _pack_approx(tensor_sets: Mapping[TensorRef, FrozenSet[int]],
                 l: int,
                 initial_pages: List[List[int]],
                 sharing_freq: Mapping[int, int],
                 class_of: Mapping[int, int]) -> Tuple[List[List[int]],
                                                       Dict[TensorRef, List[int]]]:
    """Alg. 3 core: largest-tensor-first, reuse packed pages, then pack the
    remainder hottest-block-first (sharing frequency, then class order)."""
    pages = [list(p) for p in initial_pages]
    page_sets = [frozenset(p) for p in pages]
    tensor_pages: Dict[TensorRef, List[int]] = {}
    order = sorted(tensor_sets, key=lambda t: (-len(tensor_sets[t]), t))
    for t in order:
        tset = tensor_sets[t]
        covered: set = set()
        pids: List[int] = []
        # Greedy maximal reusable subset: biggest new-coverage subset pages first.
        candidates = [i for i, ps in enumerate(page_sets) if ps and ps <= tset]
        candidates.sort(key=lambda i: -len(page_sets[i]))
        for i in candidates:
            new = page_sets[i] - covered
            if new:
                covered |= page_sets[i]
                pids.append(i)
        delta = sorted(tset - covered,
                       key=lambda b: (-sharing_freq.get(b, 1),
                                      class_of.get(b, 0), b))
        for chunk in _chunk(delta, l):
            pid = len(pages)
            pages.append(chunk)
            page_sets.append(frozenset(chunk))
            pids.append(pid)
        tensor_pages[t] = pids
    return pages, tensor_pages


def pack_greedy2(tensor_sets: Mapping[TensorRef, FrozenSet[int]],
                 l: int) -> PackResult:
    """Alg. 3 applied to the whole problem (Tab. 7 'Greedy-2')."""
    classes = equivalent_classes(tensor_sets)
    class_of: Dict[int, int] = {}
    freq: Dict[int, int] = {}
    for ci, owners in enumerate(sorted(classes, key=lambda o: sorted(o))):
        for b in classes[owners]:
            class_of[b] = ci
            freq[b] = len(owners)
    pages, tensor_pages = _pack_approx(tensor_sets, l, [], freq, class_of)
    return PackResult(pages, tensor_pages, "greedy2")


# --------------------------------------------------------------- Two-stage ---
def pack_two_stage(tensor_sets: Mapping[TensorRef, FrozenSet[int]],
                   l: int) -> PackResult:
    """Stage 1 = Alg. 2 keeping only *full* pages; stage 2 = Alg. 3 over the
    blocks that landed in non-full pages (Sec. 5.2)."""
    classes = equivalent_classes(tensor_sets)
    class_of: Dict[int, int] = {}
    freq: Dict[int, int] = {}
    for ci, owners in enumerate(sorted(classes, key=lambda o: sorted(o))):
        for b in classes[owners]:
            class_of[b] = ci
            freq[b] = len(owners)

    full_pages: List[List[int]] = []
    full_owner: List[FrozenSet[TensorRef]] = []
    leftover: Dict[TensorRef, set] = defaultdict(set)
    for owners in sorted(classes, key=lambda o: (-len(classes[o]), sorted(o))):
        blocks = classes[owners]
        n_full = (len(blocks) // l) * l
        for chunk in _chunk(blocks[:n_full], l):
            full_pages.append(chunk)
            full_owner.append(owners)
        for b in blocks[n_full:]:
            for t in owners:
                leftover[t].add(b)

    stage2_sets = {t: frozenset(bs) for t, bs in leftover.items() if bs}
    pages, s2_tensor_pages = _pack_approx(stage2_sets, l, list(full_pages),
                                          freq, class_of)

    tensor_pages: Dict[TensorRef, List[int]] = defaultdict(list)
    for pid, owners in enumerate(full_owner):
        for t in owners:
            tensor_pages[t].append(pid)
    for t, pids in s2_tensor_pages.items():
        tensor_pages[t].extend(pids)
    for t in tensor_sets:
        tensor_pages.setdefault(t, [])
    return PackResult(pages, dict(tensor_pages), "two_stage")


STRATEGIES = {
    "dedup_base": None,   # needs logical sequences, see pack()
    "greedy1": pack_greedy1,
    "greedy2": pack_greedy2,
    "two_stage": pack_two_stage,
}


def pack(tensor_sets: Mapping[TensorRef, FrozenSet[int]], l: int,
         strategy: str = "two_stage",
         tensor_seqs: Mapping[TensorRef, np.ndarray] = None) -> PackResult:
    if strategy == "dedup_base":
        if tensor_seqs is None:
            raise ValueError("dedup_base needs logical block sequences")
        return pack_dedup_base(tensor_seqs, l)
    try:
        fn = STRATEGIES[strategy]
    except KeyError:
        raise ValueError(f"unknown strategy {strategy!r}") from None
    return fn(tensor_sets, l)


# ------------------------------------------------------------- validation ---
def check_coverage(result: PackResult,
                   tensor_sets: Mapping[TensorRef, FrozenSet[int]],
                   l: int) -> None:
    """MTPPDP conditions: page size <= l and exact cover per tensor."""
    for p in result.pages:
        assert 0 < len(p) <= l, f"page size {len(p)} violates limit {l}"
    for t, tset in tensor_sets.items():
        union = set()
        for pid in result.tensor_pages[t]:
            union |= set(result.pages[pid])
        assert union == set(tset), (
            f"tensor {t}: page union != block set "
            f"(missing={set(tset) - union}, extra={union - set(tset)})")


def alg2_bound(tensor_sets: Mapping[TensorRef, FrozenSet[int]], l: int) -> int:
    """Thm. 2 upper bound: OPT_lower + 2^k - 1 where OPT >= ceil(|∪t_i|/l)."""
    all_blocks = set()
    for s in tensor_sets.values():
        all_blocks |= s
    k = len(tensor_sets)
    return -(-len(all_blocks) // l) + (1 << k) - 1
