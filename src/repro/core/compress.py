"""Composition with pruning and quantization (paper Sec. 7.6.2, Tab. 9).

Dedup is a *cross-model* compression; pruning/quantization are per-model.
The paper observes they compose because pruning/quantizing does not
significantly change cross-model block similarity.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def magnitude_prune(x: np.ndarray, sparsity: float) -> np.ndarray:
    """Zero the smallest-|w| fraction (Han et al. '15 iterative pruning)."""
    flat = np.abs(x).ravel()
    k = int(len(flat) * sparsity)
    if k == 0:
        return np.array(x, copy=True)
    thresh = np.partition(flat, k - 1)[k - 1]
    out = np.array(x, copy=True)
    out[np.abs(out) <= thresh] = 0.0
    return out


def quantize_int8(x: np.ndarray) -> Tuple[np.ndarray, float]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    scale = float(np.max(np.abs(x))) / 127.0 or 1.0
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_int8(q: np.ndarray, scale: float) -> np.ndarray:
    return q.astype(np.float32) * scale


def quantize_model(tensors: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Quantize+dequantize: values snap to the int8 lattice so that exact
    and LSH dedup both see increased block collisions (Tab. 9 'dedup+quant')."""
    out = {}
    for k, v in tensors.items():
        q, s = quantize_int8(v)
        out[k] = dequantize_int8(q, s)
    return out


def prune_model(tensors: Dict[str, np.ndarray],
                sparsity: float) -> Dict[str, np.ndarray]:
    return {k: magnitude_prune(v, sparsity) for k, v in tensors.items()}


def nbytes_sparse(x: np.ndarray, itemsize: int = 4) -> int:
    """CSR-style cost model for a pruned tensor (values + column idx)."""
    nnz = int(np.count_nonzero(x))
    return nnz * (itemsize + 4) + x.shape[0] * 8 if x.ndim >= 1 else nnz * itemsize
