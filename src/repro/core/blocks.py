"""Tensor <-> block-grid partitioning.

The paper stores every parameter tensor as a set of equal-shape *tensor
blocks* (Sec. 3).  We canonicalize arbitrary-rank tensors to 2-D
``(dim0, prod(rest))`` — the same convention the paper uses for embedding
matrices and FFNN weights — then tile with a fixed ``block_shape``,
zero-padding the ragged edge.  Block metadata (grid position) is implicit
in the row-major block ordering, mirroring the paper's
``(tensorID, blockID)`` keys.

Host-side code is numpy; ``jnp`` arrays are accepted and converted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

DEFAULT_BLOCK_SHAPE: Tuple[int, int] = (256, 256)


@dataclasses.dataclass(frozen=True)
class BlockGrid:
    """Metadata required to reassemble a tensor from its blocks."""

    tensor_shape: Tuple[int, ...]   # original (arbitrary-rank) shape
    shape2d: Tuple[int, int]        # canonicalized 2-D shape
    block_shape: Tuple[int, int]    # (bh, bw)
    grid: Tuple[int, int]           # blocks per dim, (gh, gw)

    @property
    def num_blocks(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def padded2d(self) -> Tuple[int, int]:
        return (self.grid[0] * self.block_shape[0],
                self.grid[1] * self.block_shape[1])

    def block_position(self, block_id: int) -> Tuple[int, int]:
        """Row-major block id -> (row-block, col-block)."""
        return divmod(block_id, self.grid[1])


def _canonical2d(shape: Tuple[int, ...]) -> Tuple[int, int]:
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1, shape[0])
    return (shape[0], int(math.prod(shape[1:])))


def make_grid(tensor_shape: Tuple[int, ...],
              block_shape: Tuple[int, int] = DEFAULT_BLOCK_SHAPE) -> BlockGrid:
    s2 = _canonical2d(tuple(int(d) for d in tensor_shape))
    bh, bw = block_shape
    grid = (-(-s2[0] // bh), -(-s2[1] // bw))
    return BlockGrid(tuple(int(d) for d in tensor_shape), s2,
                     (int(bh), int(bw)), grid)


def block_tensor(x, block_shape: Tuple[int, int] = DEFAULT_BLOCK_SHAPE):
    """Partition ``x`` into blocks.

    Returns ``(blocks, grid)`` where ``blocks`` has shape
    ``[num_blocks, bh, bw]`` in row-major block order.
    """
    x = np.asarray(x)
    grid = make_grid(x.shape, block_shape)
    x2 = x.reshape(grid.shape2d)
    ph, pw = grid.padded2d
    if (ph, pw) != grid.shape2d:
        pad = np.zeros((ph, pw), dtype=x2.dtype)
        pad[: grid.shape2d[0], : grid.shape2d[1]] = x2
        x2 = pad
    bh, bw = grid.block_shape
    gh, gw = grid.grid
    blocks = (x2.reshape(gh, bh, gw, bw)
                .transpose(0, 2, 1, 3)
                .reshape(gh * gw, bh, bw))
    return blocks, grid


def unblock_tensor(blocks: np.ndarray, grid: BlockGrid) -> np.ndarray:
    """Inverse of :func:`block_tensor` (drops padding)."""
    blocks = np.asarray(blocks)
    bh, bw = grid.block_shape
    gh, gw = grid.grid
    x2 = (blocks.reshape(gh, gw, bh, bw)
                 .transpose(0, 2, 1, 3)
                 .reshape(gh * bh, gw * bw))
    x2 = x2[: grid.shape2d[0], : grid.shape2d[1]]
    return x2.reshape(grid.tensor_shape)


def gather_blocks(pool: np.ndarray, block_map: np.ndarray) -> np.ndarray:
    """Materialize logical blocks from a distinct-block ``pool``.

    ``block_map[i]`` is the distinct-block id backing logical block ``i``.
    """
    return pool[np.asarray(block_map)]


def materialize(pool: np.ndarray, block_map: np.ndarray,
                grid: BlockGrid) -> np.ndarray:
    """Reconstruct a full tensor from the pool + indirection map."""
    return unblock_tensor(gather_blocks(pool, block_map), grid)
