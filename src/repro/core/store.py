"""ModelStore: the end-to-end deduplicated model repository (paper Fig. 3).

register -> dedup (Sec. 4) -> pack pages (Sec. 5) -> serve via buffer pool
(Sec. 6).  Persistence goes through a pluggable
:class:`~repro.storage.PageBackend` (local dir / SQLite / object-store
sim): ``save(backend)`` writes content-addressed pages in the store's
native page dtype plus a relational manifest, and ``ModelStore.open``
returns a *live* store whose pages stay paged in the backend and are
faulted in grouped on demand — the serving tiers (buffer pool, HBM slab)
source pages straight through it (DESIGN.md §2/§4).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Mapping, Optional, Set, Tuple

import numpy as np

from .blocks import BlockGrid, make_grid
from .bufferpool import BufferPool, PoolConfig
from .dedup import (DedupConfig, DedupResult, Deduplicator, Evaluator,
                    TensorEntry)
from .lsh import LSHConfig
from .pagepack import PackResult, check_coverage, pack
# storage is a lower layer (numpy-only, never imports core):
# the manifest version and dtype resolution live there once
from ..obs import get_tracer
from ..storage.backend import MANIFEST_VERSION, resolve_dtype
from ..storage.crashpoints import crash_point, register_crash_points
from ..storage.faults import (CorruptPageError, FatalStorageError,
                              RecoveryStats, RetryPolicy, fault_layer,
                              maybe_wrap)
from ..storage.journal import Journal, recover_backend

TensorRef = Tuple[str, str]

register_crash_points({
    "store.save.journaled":
        "save intent durably journaled, no page written yet",
    "store.save.pages_put":
        "fresh pages stored, manifest not yet committed",
    "store.save.manifest_committed":
        "manifest committed, orphan prune not yet run (the leak window)",
    "store.save.pruned":
        "orphans pruned, save intent not yet marked done",
})


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    """Knobs for dedup, page packing and the persisted page dtype."""
    dedup: DedupConfig = dataclasses.field(default_factory=DedupConfig)
    blocks_per_page: int = 16           # page size limit "l"
    pack_strategy: str = "two_stage"
    # dtype pages are *persisted* in: "auto" = the common dtype of the
    # registered tensors when uniform (fp16 models round-trip bit-exact
    # through fp16 pages instead of a float32 detour), float32 otherwise.
    page_dtype: str = "auto"


@dataclasses.dataclass
class VirtualTensor:
    """Device-servable representation: indices into the shared page pool."""
    grid: BlockGrid
    dtype: np.dtype
    block_map: np.ndarray        # [num_blocks] -> slot in the flattened pool
    page_ids: List[int]          # pages this tensor needs resident


class ModelStore:
    """The relational model store: deduplicated tensor blocks packed
    into pages, plus the packing/caching state every serving tier
    (buffer pool, device slab, shards) hangs off.  ``pack_generation``
    names the packing epoch; all downstream caches key on it."""

    def __init__(self, cfg: Optional[StoreConfig] = None):
        self.cfg = cfg or StoreConfig()
        self.dedup = Deduplicator(self.cfg.dedup)
        self._pack: Optional[PackResult] = None
        self._slot_of_block: Dict[int, Tuple[int, int]] = {}  # did -> (page, slot)
        # Packing generation: bumped on every repack().  Downstream caches
        # (WeightServer._pool_arr, DevicePagePool remaps, Prefetcher page
        # sets) key their validity on this counter, so a model update can
        # never leave a consumer serving a stale pool array.
        self.pack_generation = 0
        self._stack: Optional[np.ndarray] = None          # distinct blocks
        self._vt_cache: Dict[TensorRef, VirtualTensor] = {}
        self._page_pool_cache: Dict[str, Tuple[int, np.ndarray]] = {}
        # Backend attachment (set by ModelStore.open / save): pages not
        # yet faulted from the backend, their content hashes, and whether
        # the LSH index still needs rebuilding before the next mutation.
        self._backend = None                     # Optional[PageBackend]
        self._page_hash: List[str] = []          # pid -> content hash
        self._unfetched: Set[int] = set()        # pids still in the backend
        self._persisted_page_dtype = np.dtype(np.float32)
        self._index_stale = False
        # Recovery layer (DESIGN.md §8): every backend round trip goes
        # through retry_policy; fault_stats accumulates what recovery
        # cost (serving tiers snapshot-diff it per batch).  verify_pages
        # None = auto: sha256-check fetched pages iff a fault-injecting
        # layer is attached (the paranoid mode costs a hash per page).
        self.retry_policy = RetryPolicy()
        self.fault_stats = RecoveryStats()
        self.verify_pages: Optional[bool] = None

    def _mutate(self) -> None:
        """Invalidate everything derived from dedup state / packing."""
        self._pack = None
        self._stack = None
        self._vt_cache.clear()
        self._page_pool_cache.clear()

    def _hydrate(self) -> None:
        """Make an opened store fully mutable: fault every remaining page
        out of the backend and rebuild the LSH index so incremental dedup
        (register/update/remove) sees the reloaded blocks.  Serving paths
        never need this — they stay lazily paged."""
        if self._backend is None:
            return
        self.fault_all()
        if self._index_stale:
            self.dedup.rebuild_index()
            self._index_stale = False

    # ------------------------------------------------------------ pipeline --
    def register(self, model: str, tensors: Mapping[str, np.ndarray],
                 evaluator: Optional[Evaluator] = None,
                 layers=None) -> DedupResult:
        self._hydrate()
        res = self.dedup.add_model(model, dict(tensors), evaluator, layers)
        self._mutate()                           # packing is now stale
        return res

    def remove(self, model: str) -> None:
        self._hydrate()
        self.dedup.remove_model(model)
        self._mutate()

    def update(self, model: str, tensors: Mapping[str, np.ndarray],
               evaluator: Optional[Evaluator] = None,
               approach: int = 2) -> DedupResult:
        self._hydrate()
        res = self.dedup.update_model(model, dict(tensors), evaluator, approach)
        self._mutate()
        return res

    def repack(self) -> PackResult:
        """(Re)run Sec.-5 page packing over the current distinct blocks."""
        self._hydrate()      # page ids are about to be renamed: the lazy
        self._page_hash = [] # backend mapping below dies with them
        tensor_sets = self.dedup.tensor_sets()
        seqs = {(m, t): self.dedup.models[m].tensors[t].block_map
                for m in self.dedup.models
                for t in self.dedup.models[m].tensors}
        pk = pack(tensor_sets, self.cfg.blocks_per_page,
                  self.cfg.pack_strategy, tensor_seqs=seqs)
        check_coverage(pk, tensor_sets, self.cfg.blocks_per_page)
        self._install_pack(pk)
        return self._pack

    def _install_pack(self, pk: PackResult) -> None:
        """Adopt a packing (freshly computed or loaded from a manifest)
        and invalidate every packing-derived cache."""
        self._pack = pk
        self._slot_of_block = {}
        for pid, page in enumerate(pk.pages):
            for slot, did in enumerate(page):
                # A block may appear in several pages (Alg. 3 copies); keep
                # the first placement as canonical.
                self._slot_of_block.setdefault(did, (pid, slot))
        self._vt_cache.clear()
        self._page_pool_cache.clear()
        self.pack_generation += 1

    @property
    def packing(self) -> PackResult:
        if self._pack is None:
            self.repack()
        return self._pack

    def packing_current(self, generation: int) -> bool:
        """True iff page ids minted under ``generation`` are still valid:
        the store is packed and has not been repacked since.  Consumers
        holding derived page sets (queued batches, model-switch caches)
        gate on this before trusting them."""
        return self._pack is not None and self.pack_generation == generation

    # ------------------------------------------------------ backend paging --
    @property
    def backend(self):
        """The attached :class:`~repro.storage.PageBackend` (None for a
        purely in-memory store)."""
        return self._backend

    def _verification_enabled(self) -> bool:
        if self.verify_pages is not None:
            return self.verify_pages
        return fault_layer(self._backend) is not None

    def _charged_run(self, fn, describe: str):
        """``retry_policy.run`` with the retry cost charged to
        ``fault_stats`` whether the call recovers OR exhausts its budget
        (a failed call's retries/backoff are real recovery work — the
        FatalStorageError carries them as ``.outcome``)."""
        tr = get_tracer()
        try:
            result, outcome = self.retry_policy.run(fn, describe=describe)
        except FatalStorageError as exc:
            oc = getattr(exc, "outcome", None)
            if oc is not None:
                self.fault_stats.retries += oc.retries
                self.fault_stats.backoff_seconds += oc.backoff_seconds
                if tr.enabled:
                    tr.event("retry", kind="storage", op=describe,
                             retries=oc.retries, fatal=True,
                             backoff_s=oc.backoff_seconds)
            raise
        self.fault_stats.retries += outcome.retries
        self.fault_stats.backoff_seconds += outcome.backoff_seconds
        if tr.enabled and outcome.retries:
            tr.event("retry", kind="storage", op=describe,
                     retries=outcome.retries, fatal=False,
                     backoff_s=outcome.backoff_seconds)
        return result

    def _backend_get(self, hashes: List[str]) -> Dict[str, np.ndarray]:
        """One grouped ``get_pages`` with bounded retries; retry cost is
        accumulated in ``fault_stats`` (virtual seconds, never slept)."""
        return self._charged_run(
            lambda: self._backend.get_pages(hashes), describe="get_pages")

    def _page_bytes_ok(self, pid: int, got: Dict[str, np.ndarray]) -> bool:
        """End-to-end integrity: the content address IS the checksum —
        re-derive ``save()``'s sha256 over the fetched bytes."""
        raw = np.ascontiguousarray(
            np.asarray(got[self._page_hash[pid]])).tobytes()
        return hashlib.sha256(raw).hexdigest()[:24] == self._page_hash[pid]

    def _verify_and_refetch(self, want: List[int],
                            got: Dict[str, np.ndarray]) -> None:
        """Quarantine pages whose bytes fail verification and re-fetch
        them as their own grouped call (the rest of the batch proceeds);
        bounded attempts, then :class:`CorruptPageError`."""
        bad = [p for p in want if not self._page_bytes_ok(p, got)]
        attempts = 0
        while bad:
            self.fault_stats.corrupt_detected += len(bad)
            attempts += 1
            if attempts > max(1, self.retry_policy.max_retries):
                raise CorruptPageError(
                    f"pages {bad} still fail sha256 verification after "
                    f"{attempts - 1} grouped refetches")
            got.update(self._backend_get([self._page_hash[p] for p in bad]))
            self.fault_stats.refetch_pages += len(bad)
            bad = [p for p in bad if not self._page_bytes_ok(p, got)]

    def _drain_injected_latency(self) -> None:
        fl = fault_layer(self._backend)
        if fl is not None:
            self.fault_stats.latency_seconds += fl.drain_injected_latency()

    def fault_pages(self, page_ids) -> int:
        """Fault not-yet-resident pages out of the attached backend with
        ONE grouped ``get_pages`` call (the serving miss path: a batch's
        misses share a single backend round trip).  No-op for in-memory
        stores and already-faulted pages.  Returns pages fetched.

        Recovery semantics (DESIGN.md §8): transient backend errors are
        retried with bounded virtual backoff; when verification is on,
        every fetched page is sha256-checked against its content address
        and corrupt pages are quarantined + re-fetched as their own
        grouped call instead of crashing the batch."""
        if self._backend is None or not self._unfetched:
            return 0
        want = sorted(p for p in set(int(p) for p in page_ids)
                      if p in self._unfetched)
        if not want:
            return 0
        with get_tracer().span("get_pages", kind="storage",
                               backend=type(self._backend).__name__,
                               pages=len(want)) as sp:
            got = self._backend_get([self._page_hash[p] for p in want])
            if self._verification_enabled():
                self._verify_and_refetch(want, got)
            self._drain_injected_latency()
            sp.set(verified=self._verification_enabled())
        for pid in want:
            page = np.asarray(got[self._page_hash[pid]])
            if page.dtype.kind == "V":
                # a backend that can't self-describe extension dtypes
                # (.npy files of bfloat16 pages come back as void bytes)
                # defers to the manifest's page_dtype for interpretation
                page = page.view(self._persisted_page_dtype)
            blocks = page.astype(np.float32)     # working copies are fp32
            for slot, did in enumerate(self._pack.pages[pid]):
                if self.dedup.distinct[did] is None:
                    self.dedup.distinct[did] = np.array(blocks[slot],
                                                        copy=True)
            self._unfetched.discard(pid)
        self._stack = None                       # stack is now stale
        return len(want)

    def fault_all(self) -> int:
        """Fault every remaining page (host-densification paths)."""
        if not self._unfetched:
            return 0
        return self.fault_pages(list(self._unfetched))

    def native_page_dtype(self) -> np.dtype:
        """The dtype pages are persisted in: ``cfg.page_dtype`` when set,
        else the registered tensors' common dtype when uniform and a
        narrow float (fp16/bf16/fp32 round-trip bit-exact), else fp32."""
        if self.cfg.page_dtype != "auto":
            return resolve_dtype(self.cfg.page_dtype)
        dts = {np.dtype(e.dtype) for res in self.dedup.models.values()
               for e in res.tensors.values()}
        if len(dts) == 1:
            dt = dts.pop()
            if dt.name in ("float16", "bfloat16", "float32"):
                return dt
        return np.dtype(np.float32)

    # ----------------------------------------------------------- accessors --
    def num_pages(self) -> int:
        return self.packing.num_pages

    def storage_bytes(self, dtype=np.float32) -> int:
        bh, bw = self.cfg.dedup.block_shape
        itemsize = np.dtype(dtype).itemsize
        return self.packing.num_pages * self.cfg.blocks_per_page * bh * bw * itemsize

    def dense_bytes(self, dtype=np.float32) -> int:
        """Storage without dedup: every model's logical blocks, paged."""
        bh, bw = self.cfg.dedup.block_shape
        itemsize = np.dtype(dtype).itemsize
        l = self.cfg.blocks_per_page
        pages = 0
        for m in self.dedup.models.values():
            for e in m.tensors.values():
                pages += -(-e.grid.num_blocks // l)
        return pages * l * bh * bw * itemsize

    def materialize(self, model: str, tensor: str) -> np.ndarray:
        if self._unfetched:
            # fault only this tensor's cover pages (stays paged per model)
            self.fault_pages(self.packing.tensor_pages[(model, tensor)])
        return self.dedup.materialize(model, tensor)

    def _distinct_stack(self) -> np.ndarray:
        """[len(distinct), bh, bw] float32 stack of the distinct blocks
        (tombstones as zeros), cached until the next register/update/remove.
        All the vectorized gathers below index into this one array.  On a
        backend-attached store this is the host-densification path, so it
        faults everything still paged (unfetched blocks must never be
        silently read as tombstone zeros)."""
        if self._unfetched:
            self.fault_all()
        if self._stack is None \
                or self._stack.shape[0] != len(self.dedup.distinct):
            self._stack = self.dedup.pool(np.float32)
        return self._stack

    def materialize_rows(self, model: str, tensor: str,
                         rows: np.ndarray) -> np.ndarray:
        """Gather only the requested rows (2-D tensors): the serving path's
        partial materialization — touches just the row blocks involved.
        Fully vectorized: one fancy-index gather pulls exactly the
        requested rows out of the stacked distinct-block array.

        On a backend-attached store only the pages covering the touched
        blocks are faulted (one grouped get), so the numpy serving path
        stays paged per batch instead of densifying the whole store on
        its first request."""
        e = self.dedup.models[model].tensors[tensor]
        bh, bw = e.grid.block_shape
        gw = e.grid.grid[1]
        width = e.grid.shape2d[1]
        rows = np.asarray(rows)
        rb = rows // bh
        off = rows % bh
        dids = e.block_map[rb[:, None] * gw + np.arange(gw)[None, :]]
        if self._unfetched:
            uniq = np.unique(dids)
            self.fault_pages({self._slot_of_block[int(d)][0] for d in uniq})
        if self._unfetched:
            # other pages still live in the backend: gather through a
            # small sub-stack of just the touched distinct blocks
            uniq = np.unique(dids)
            sub = np.stack([self.dedup.distinct[int(d)] for d in uniq])
            out = sub[np.searchsorted(uniq, dids), off[:, None], :]
        else:
            out = self._distinct_stack()[dids, off[:, None], :]
        return np.ascontiguousarray(            # [n, gw, bw] rows only
            out.reshape(len(rows), gw * bw)[:, :width], dtype=np.float32)

    def _page_slot_ids(self) -> np.ndarray:
        """[num_pages, blocks_per_page] distinct-id matrix of the packing
        (-1 marks an unfilled slot in a non-full page), cached per
        packing generation (page_pool and the grouped transfer staging
        path both gather through it)."""
        pk = self.packing
        hit = self._page_pool_cache.get("__slot_ids__")
        if hit is not None and hit[0] == self.pack_generation:
            return hit[1]
        l = self.cfg.blocks_per_page
        ids = np.full((pk.num_pages, l), -1, dtype=np.int64)
        for pid, page in enumerate(pk.pages):
            ids[pid, :len(page)] = page
        self._page_pool_cache["__slot_ids__"] = (self.pack_generation, ids)
        return ids

    def page_stack(self, page_ids, dtype=np.float32) -> np.ndarray:
        """[k, blocks_per_page, bh, bw] stack of the requested pages —
        the grouped transfer staging buffer.  One grouped backend fault
        (:meth:`fault_pages`) plus one vectorized gather, never k
        :meth:`page_array` calls (each of which would issue its own
        backend round trip on a freshly opened store)."""
        pids = [int(p) for p in page_ids]
        bh, bw = self.cfg.dedup.block_shape
        l = self.cfg.blocks_per_page
        if self._unfetched:
            self.fault_pages(pids)
        if self._unfetched:
            # other pages still live in the backend: assemble page by
            # page from already-faulted blocks, no full densification
            out = np.zeros((len(pids), l, bh, bw), dtype=dtype)
            for i, pid in enumerate(pids):
                page = self.packing.pages[pid]
                for slot, did in enumerate(page):
                    b = self.dedup.distinct[did]
                    if b is not None:
                        out[i, slot] = b
            return out
        ids = self._page_slot_ids()[np.asarray(pids, dtype=np.int64)]
        out = self._distinct_stack()[np.clip(ids, 0, None)].astype(
            dtype, copy=True)
        out[ids < 0] = 0
        return out

    def page_pool(self, dtype=np.float32) -> np.ndarray:
        """[num_pages, blocks_per_page, bh, bw] physical page array.

        Built by one vectorized gather from the distinct-block stack and
        cached per packing generation, so repeated callers (WeightServer,
        benchmarks) never re-run the old nested Python loops."""
        self.packing         # may repack: read before the generation
        key = np.dtype(dtype).str
        hit = self._page_pool_cache.get(key)
        if hit is not None and hit[0] == self.pack_generation:
            return hit[1]
        ids = self._page_slot_ids()
        pool = self._distinct_stack()[np.clip(ids, 0, None)].astype(
            dtype, copy=True)
        pool[ids < 0] = 0
        self._page_pool_cache[key] = (self.pack_generation, pool)
        return pool

    def page_array(self, pid: int, dtype=np.float32) -> np.ndarray:
        """One physical page [blocks_per_page, bh, bw] — what a device
        page pool transfers host->HBM on a buffer-pool miss, without
        building the whole pool array.  On a backend-attached store the
        page is faulted from the backend on first touch (the HBM slab
        sources its pages straight through the storage tier)."""
        bh, bw = self.cfg.dedup.block_shape
        page = self.packing.pages[pid]
        out = np.zeros((self.cfg.blocks_per_page, bh, bw), dtype=dtype)
        if self._unfetched:
            self.fault_pages([pid])
        if self._unfetched:
            # other pages still live in the backend: assemble this page
            # from its own blocks without densifying the whole stack
            for slot, did in enumerate(page):
                b = self.dedup.distinct[did]
                if b is not None:
                    out[slot] = b
            return out
        out[:len(page)] = self._distinct_stack()[np.asarray(page)]
        return out

    def virtual_tensor(self, model: str, tensor: str) -> VirtualTensor:
        """Indirection view used by the Pallas dedup kernels: block_map maps
        each logical block to a flat slot ``page * l + slot``.

        Slot-remap contract: every flat slot lies inside one of the
        tensor's *own* cover pages (``page_ids``), so a consumer that
        faults exactly ``page_ids`` resident (e.g. the device page pool)
        can always rewrite the map into its slot space.  The flat map is
        vectorized and cached per packing generation."""
        pk = self.packing
        key: TensorRef = (model, tensor)
        hit = self._vt_cache.get(key)
        if hit is not None:
            return hit
        e = self.dedup.models[model].tensors[tensor]
        l = self.cfg.blocks_per_page
        page_ids = sorted(set(pk.tensor_pages[key]))
        # did -> flat slot, restricted to this tensor's cover pages
        # (first placement in page-id order wins, matching _slot_of_block).
        slot_arr = np.full(len(self.dedup.distinct), -1, dtype=np.int64)
        for pid in reversed(page_ids):
            page = pk.pages[pid]
            slot_arr[np.asarray(page, dtype=np.int64)] = \
                pid * l + np.arange(len(page))
        flat = slot_arr[e.block_map].astype(np.int32)
        assert (flat >= 0).all(), \
            f"tensor {key}: block map escapes its cover pages"
        vt = VirtualTensor(e.grid, e.dtype, flat, page_ids)
        self._vt_cache[key] = vt
        return vt

    # ------------------------------------------------------------- serving --
    def page_sharers(self) -> Dict[int, frozenset]:
        """page id -> models whose tensors live (partly) on that page.
        This is the sharing structure Eq. 2 superposes rates over, and
        what the dedup-affinity scheduler co-schedules on."""
        sharers: Dict[int, set] = {}
        for (m, t), pids in self.packing.tensor_pages.items():
            for p in pids:
                sharers.setdefault(p, set()).add(m)
        return {p: frozenset(ms) for p, ms in sharers.items()}

    def page_sharer_counts(self) -> np.ndarray:
        """[num_pages] int64 sharer counts (|page_sharers()[p]|), cached
        per packing generation — the dedup statistic sharer-weighted
        shard placement keys on (hot shared pages replicate, singletons
        partition)."""
        hit = self._page_pool_cache.get("__sharer_counts__")
        if hit is not None and hit[0] == self.pack_generation:
            return hit[1]
        counts = np.zeros(self.packing.num_pages, dtype=np.int64)
        for p, ms in self.page_sharers().items():
            counts[p] = len(ms)
        self._page_pool_cache["__sharer_counts__"] = (self.pack_generation,
                                                      counts)
        return counts

    def model_pages(self, model: str) -> List[int]:
        """All pages the model's tensors touch (its page working set)."""
        pk = self.packing
        pages: set = set()
        for (m, t), pids in pk.tensor_pages.items():
            if m == model:
                pages.update(pids)
        return sorted(pages)

    def page_metadata(self) -> Tuple[Dict[int, frozenset],
                                     Dict[int, frozenset]]:
        """(page_sharers, page_locality) for the current packing — the
        Eq.-2 sharing structure and the locality-set (equivalence-class)
        grouping the pool policies consume."""
        pk = self.packing
        sharers = self.page_sharers()
        locality: Dict[int, frozenset] = {}
        owners: Dict[int, set] = {}
        for (m, t), pids in pk.tensor_pages.items():
            for p in pids:
                owners.setdefault(p, set()).add((m, t))
        for p, ts in owners.items():
            locality[p] = frozenset(ts)          # locality set = equivalence class
        return sharers, locality

    def make_buffer_pool(self, capacity_pages: int,
                         policy: str = "optimized_mru",
                         on_load=None, on_evict=None,
                         on_load_group=None, **kw) -> BufferPool:
        """``on_load``/``on_evict`` attach a backing tier (e.g. the device
        page pool's host->HBM transfers) to the policy simulator;
        ``on_load_group`` attaches the grouped transfer path (a batch's
        misses flush as one physical movement)."""
        sharers, locality = self.page_metadata()
        return BufferPool(PoolConfig(capacity_pages, policy, **kw),
                          page_sharers=sharers, page_locality=locality,
                          on_load=on_load, on_evict=on_evict,
                          on_load_group=on_load_group)

    # --------------------------------------------------------- persistence --
    def save(self, dest=None) -> Dict:
        """Persist the store through a :class:`~repro.storage.PageBackend`.

        ``dest`` may be a backend instance, a storage URL (``file://``,
        ``sqlite://``, ``objsim://``), a bare directory path (deprecated
        legacy spelling, resolved to a ``LocalDirBackend``), or None to
        reuse the backend the store was opened from.

        Pages are content-addressed (sha256 of the serialized bytes) in
        the store's :meth:`native_page_dtype`, so fp16/bf16 model sets
        round-trip bit-exact without a float32 detour.  The manifest
        commit is atomic/transactional, and pages orphaned by an earlier
        packing generation are pruned afterwards (``delete_pages`` on
        the diff).  The whole sequence is bracketed by a write-ahead
        intent journal: a crash at any seam leaves at worst
        unreferenced extra pages and staging files, which the journal
        replay on the next :meth:`open` garbage-collects (DESIGN.md §11).
        """
        from ..storage import PageBackend, open_backend
        if dest is None:
            if self._backend is None:
                raise ValueError("store has no attached backend; "
                                 "pass a backend, URL, or path to save()")
            backend = self._backend
        elif isinstance(dest, PageBackend):
            backend = dest
        else:
            # URL/path attach point: chaos mode (REPRO_FAULTS) wraps the
            # resolved backend; explicitly constructed instances above
            # are never wrapped (tests assert exact call counts on them)
            backend = maybe_wrap(open_backend(dest))
        pk = self.packing
        page_dtype = self.native_page_dtype()
        pool = self.page_pool().astype(page_dtype)
        page_hashes: List[str] = []
        payload: Dict[str, np.ndarray] = {}
        for pid in range(pk.num_pages):
            raw = np.ascontiguousarray(pool[pid]).tobytes()
            h = hashlib.sha256(raw).hexdigest()[:24]
            page_hashes.append(h)
            payload.setdefault(h, pool[pid])     # dedup in the backend too
        existing = set(backend.list_pages())
        fresh = {h: arr for h, arr in payload.items() if h not in existing}
        # Write-ahead intent (DESIGN.md §11): the keep-set names exactly
        # the pages the new manifest will reference, so recovery after a
        # crash at ANY point below reduces to one manifest-vs-stored GC.
        journal = Journal(backend)
        intent = journal.begin("save", keep=sorted(set(page_hashes)))
        crash_point("store.save.journaled")
        # content-addressed puts are idempotent, so transient write
        # failures (including torn acks) are safely retried
        self._charged_run(lambda: backend.put_pages(fresh),
                          describe="put_pages")
        crash_point("store.save.pages_put")
        manifest = {
            "version": MANIFEST_VERSION,
            "blocks_per_page": self.cfg.blocks_per_page,
            "block_shape": list(self.cfg.dedup.block_shape),
            "page_dtype": page_dtype.name,
            "pack_strategy": self.cfg.pack_strategy,
            "dedup_config": _dedup_config_dict(self.cfg.dedup),
            "pages": [{"hash": h, "blocks": [int(b) for b in pk.pages[i]]}
                      for i, h in enumerate(page_hashes)],
            "models": {
                m: {t: {"shape": list(e.grid.tensor_shape),
                        "dtype": np.dtype(e.dtype).name,
                        "block_map": e.block_map.tolist(),
                        "pages": [int(p) for p in pk.tensor_pages[(m, t)]]}
                    for t, e in res.tensors.items()}
                for m, res in self.dedup.models.items()},
        }
        # atomic commit point — retried on transient faults (a torn
        # commit re-commits idempotently: the version check passes after
        # the first, acked-or-not, success); ManifestConflictError stays
        # a hard conflict and propagates untouched
        self._charged_run(lambda: backend.commit_manifest(manifest),
                          describe="commit_manifest")
        crash_point("store.save.manifest_committed")
        orphans = existing - set(page_hashes)
        if orphans:                              # pages of older packings
            backend.delete_pages(sorted(orphans))
        crash_point("store.save.pruned")
        journal.commit(intent)
        if self._backend is None:
            self._backend = backend              # adopt for future save()
        return manifest

    @classmethod
    def open(cls, source, cfg: Optional[StoreConfig] = None) -> "ModelStore":
        """Open a saved store as a *live* ModelStore: pages stay paged in
        the backend and fault in lazily (grouped) as serving touches
        them — nothing is densified up front.

        ``source`` is a backend instance or storage URL.  ``cfg``
        overrides the persisted configuration (e.g. a different LSH
        seed); by default the manifest's own dedup/packing config is
        restored, so ``register``/``update`` after open dedup against
        the reloaded blocks exactly as before the restart.
        """
        from ..storage import PageBackend, open_backend
        if isinstance(source, PageBackend):
            backend = source
        else:
            backend = maybe_wrap(open_backend(source))
        # Journal replay (DESIGN.md §11): a crash mid-save leaves a
        # pending intent; recovery GCs orphan pages + temp debris before
        # anything reads the store.  Clean journals cost one read — no
        # page listing — so lazy-open call-count contracts are unchanged.
        recover_backend(backend)
        manifest, _ = RetryPolicy().run(backend.load_manifest,
                                        describe="load_manifest")
        version = manifest.get("version", 1)    # v1: pre-PageBackend saves
        if version > MANIFEST_VERSION:
            raise ValueError(
                f"manifest version {version} from {backend.url()} is newer "
                f"than this build understands ({MANIFEST_VERSION}); "
                "upgrade the reader instead of guessing at the format")
        bh, bw = manifest["block_shape"]
        if cfg is None:
            cfg = _config_from_manifest(manifest)
        store = cls(cfg)
        dd = store.dedup
        pages = manifest["pages"]
        n_distinct = 1 + max((int(b) for e in pages for b in e["blocks"]),
                             default=-1)
        dd.distinct = [None] * n_distinct
        dd.owners = [dict() for _ in range(n_distinct)]
        tensor_pages: Dict[TensorRef, List[int]] = {}
        for m, tensors in manifest["models"].items():
            res = DedupResult(model=m, tensors={})
            for t, spec in tensors.items():
                grid = make_grid(tuple(spec["shape"]), (bh, bw))
                bm = np.asarray(spec["block_map"], dtype=np.int64)
                res.tensors[t] = TensorEntry(t, grid,
                                             resolve_dtype(spec["dtype"]),
                                             bm)
                res.total_blocks += grid.num_blocks
                tensor_pages[(m, t)] = [int(p) for p in spec["pages"]]
                ref: TensorRef = (m, t)
                uniq, cnt = np.unique(bm, return_counts=True)
                for did, c in zip(uniq, cnt):
                    dd.owners[int(did)][ref] = \
                        dd.owners[int(did)].get(ref, 0) + int(c)
                res.deduped_blocks += int(grid.num_blocks - len(uniq))
            dd.models[m] = res
        store._install_pack(PackResult([list(map(int, e["blocks"]))
                                        for e in pages],
                                       tensor_pages, strategy="loaded"))
        store._backend = backend
        store._page_hash = [e["hash"] for e in pages]
        store._unfetched = set(range(len(pages)))
        store._persisted_page_dtype = resolve_dtype(
            manifest.get("page_dtype", "float32"))
        store._index_stale = True                # rebuilt on first mutation
        return store


def _dedup_config_dict(cfg: DedupConfig) -> Dict:
    lsh = cfg.lsh
    return {
        "magnitude_stat": cfg.magnitude_stat,
        "validate_every_k": cfg.validate_every_k,
        "accuracy_drop_threshold": cfg.accuracy_drop_threshold,
        "validate": cfg.validate,
        "lsh": {"num_bands": lsh.num_bands,
                "rows_per_band": lsh.rows_per_band,
                "r": lsh.r,
                "collision_threshold": lsh.collision_threshold,
                "seed": lsh.seed},
    }


def _config_from_manifest(manifest: Dict) -> StoreConfig:
    bh, bw = manifest["block_shape"]
    dc = manifest.get("dedup_config", {})
    lsh = dc.get("lsh", {})
    return StoreConfig(
        dedup=DedupConfig(
            block_shape=(bh, bw),
            lsh=LSHConfig(**lsh) if lsh else LSHConfig(),
            magnitude_stat=dc.get("magnitude_stat", "q3"),
            validate_every_k=dc.get("validate_every_k", 64),
            accuracy_drop_threshold=dc.get("accuracy_drop_threshold", 0.035),
            validate=dc.get("validate", True)),
        blocks_per_page=manifest["blocks_per_page"],
        pack_strategy=manifest.get("pack_strategy", "two_stage"),
        page_dtype=manifest.get("page_dtype", "auto"))


def load_store_tensors(source) -> Dict[str, Dict[str, np.ndarray]]:
    """Rehydrate every model's tensors from a saved store (DEPRECATED:
    densifies everything on the host — prefer ``ModelStore.open``, which
    keeps pages paged in the backend).  ``source`` is a directory path
    (the legacy call convention), storage URL, or backend."""
    store = ModelStore.open(source)
    return {m: {t: store.materialize(m, t)
                for t in store.dedup.models[m].tensors}
            for m in store.dedup.models}
