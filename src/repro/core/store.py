"""ModelStore: the end-to-end deduplicated model repository (paper Fig. 3).

register -> dedup (Sec. 4) -> pack pages (Sec. 5) -> serve via buffer pool
(Sec. 6).  The on-disk format doubles as the system's *checkpoint* format:
content-addressed pages + per-model block maps + a JSON manifest, so a new
model variant ships only its private pages (DESIGN.md §2, changed
assumption 4).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from .blocks import BlockGrid, unblock_tensor
from .bufferpool import BufferPool, PoolConfig
from .dedup import DedupConfig, DedupResult, Deduplicator, Evaluator
from .pagepack import PackResult, check_coverage, pack

TensorRef = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    dedup: DedupConfig = dataclasses.field(default_factory=DedupConfig)
    blocks_per_page: int = 16           # page size limit "l"
    pack_strategy: str = "two_stage"


@dataclasses.dataclass
class VirtualTensor:
    """Device-servable representation: indices into the shared page pool."""
    grid: BlockGrid
    dtype: np.dtype
    block_map: np.ndarray        # [num_blocks] -> slot in the flattened pool
    page_ids: List[int]          # pages this tensor needs resident


class ModelStore:
    def __init__(self, cfg: Optional[StoreConfig] = None):
        self.cfg = cfg or StoreConfig()
        self.dedup = Deduplicator(self.cfg.dedup)
        self._pack: Optional[PackResult] = None
        self._slot_of_block: Dict[int, Tuple[int, int]] = {}  # did -> (page, slot)
        # Packing generation: bumped on every repack().  Downstream caches
        # (WeightServer._pool_arr, DevicePagePool remaps, Prefetcher page
        # sets) key their validity on this counter, so a model update can
        # never leave a consumer serving a stale pool array.
        self.pack_generation = 0
        self._stack: Optional[np.ndarray] = None          # distinct blocks
        self._vt_cache: Dict[TensorRef, VirtualTensor] = {}
        self._page_pool_cache: Dict[str, Tuple[int, np.ndarray]] = {}

    def _mutate(self) -> None:
        """Invalidate everything derived from dedup state / packing."""
        self._pack = None
        self._stack = None
        self._vt_cache.clear()
        self._page_pool_cache.clear()

    # ------------------------------------------------------------ pipeline --
    def register(self, model: str, tensors: Mapping[str, np.ndarray],
                 evaluator: Optional[Evaluator] = None,
                 layers=None) -> DedupResult:
        res = self.dedup.add_model(model, dict(tensors), evaluator, layers)
        self._mutate()                           # packing is now stale
        return res

    def remove(self, model: str) -> None:
        self.dedup.remove_model(model)
        self._mutate()

    def update(self, model: str, tensors: Mapping[str, np.ndarray],
               evaluator: Optional[Evaluator] = None,
               approach: int = 2) -> DedupResult:
        res = self.dedup.update_model(model, dict(tensors), evaluator, approach)
        self._mutate()
        return res

    def repack(self) -> PackResult:
        """(Re)run Sec.-5 page packing over the current distinct blocks."""
        tensor_sets = self.dedup.tensor_sets()
        seqs = {(m, t): self.dedup.models[m].tensors[t].block_map
                for m in self.dedup.models
                for t in self.dedup.models[m].tensors}
        self._pack = pack(tensor_sets, self.cfg.blocks_per_page,
                          self.cfg.pack_strategy, tensor_seqs=seqs)
        check_coverage(self._pack, tensor_sets, self.cfg.blocks_per_page)
        self._slot_of_block = {}
        for pid, page in enumerate(self._pack.pages):
            for slot, did in enumerate(page):
                # A block may appear in several pages (Alg. 3 copies); keep
                # the first placement as canonical.
                self._slot_of_block.setdefault(did, (pid, slot))
        self._vt_cache.clear()
        self._page_pool_cache.clear()
        self.pack_generation += 1
        return self._pack

    @property
    def packing(self) -> PackResult:
        if self._pack is None:
            self.repack()
        return self._pack

    def packing_current(self, generation: int) -> bool:
        """True iff page ids minted under ``generation`` are still valid:
        the store is packed and has not been repacked since.  Consumers
        holding derived page sets (queued batches, model-switch caches)
        gate on this before trusting them."""
        return self._pack is not None and self.pack_generation == generation

    # ----------------------------------------------------------- accessors --
    def num_pages(self) -> int:
        return self.packing.num_pages

    def storage_bytes(self, dtype=np.float32) -> int:
        bh, bw = self.cfg.dedup.block_shape
        itemsize = np.dtype(dtype).itemsize
        return self.packing.num_pages * self.cfg.blocks_per_page * bh * bw * itemsize

    def dense_bytes(self, dtype=np.float32) -> int:
        """Storage without dedup: every model's logical blocks, paged."""
        bh, bw = self.cfg.dedup.block_shape
        itemsize = np.dtype(dtype).itemsize
        l = self.cfg.blocks_per_page
        pages = 0
        for m in self.dedup.models.values():
            for e in m.tensors.values():
                pages += -(-e.grid.num_blocks // l)
        return pages * l * bh * bw * itemsize

    def materialize(self, model: str, tensor: str) -> np.ndarray:
        return self.dedup.materialize(model, tensor)

    def _distinct_stack(self) -> np.ndarray:
        """[len(distinct), bh, bw] float32 stack of the distinct blocks
        (tombstones as zeros), cached until the next register/update/remove.
        All the vectorized gathers below index into this one array."""
        if self._stack is None \
                or self._stack.shape[0] != len(self.dedup.distinct):
            self._stack = self.dedup.pool(np.float32)
        return self._stack

    def materialize_rows(self, model: str, tensor: str,
                         rows: np.ndarray) -> np.ndarray:
        """Gather only the requested rows (2-D tensors): the serving path's
        partial materialization — touches just the row blocks involved.
        Fully vectorized: one fancy-index gather pulls exactly the
        requested rows out of the stacked distinct-block array."""
        e = self.dedup.models[model].tensors[tensor]
        bh, bw = e.grid.block_shape
        gw = e.grid.grid[1]
        width = e.grid.shape2d[1]
        rows = np.asarray(rows)
        rb = rows // bh
        off = rows % bh
        stack = self._distinct_stack()
        dids = e.block_map[rb[:, None] * gw + np.arange(gw)[None, :]]
        out = stack[dids, off[:, None], :]           # [n, gw, bw] rows only
        return np.ascontiguousarray(
            out.reshape(len(rows), gw * bw)[:, :width], dtype=np.float32)

    def _page_slot_ids(self) -> np.ndarray:
        """[num_pages, blocks_per_page] distinct-id matrix of the packing
        (-1 marks an unfilled slot in a non-full page)."""
        pk = self.packing
        l = self.cfg.blocks_per_page
        ids = np.full((pk.num_pages, l), -1, dtype=np.int64)
        for pid, page in enumerate(pk.pages):
            ids[pid, :len(page)] = page
        return ids

    def page_pool(self, dtype=np.float32) -> np.ndarray:
        """[num_pages, blocks_per_page, bh, bw] physical page array.

        Built by one vectorized gather from the distinct-block stack and
        cached per packing generation, so repeated callers (WeightServer,
        benchmarks) never re-run the old nested Python loops."""
        pk = self.packing
        key = np.dtype(dtype).str
        hit = self._page_pool_cache.get(key)
        if hit is not None and hit[0] == self.pack_generation:
            return hit[1]
        ids = self._page_slot_ids()
        pool = self._distinct_stack()[np.clip(ids, 0, None)].astype(
            dtype, copy=True)
        pool[ids < 0] = 0
        self._page_pool_cache[key] = (self.pack_generation, pool)
        return pool

    def page_array(self, pid: int, dtype=np.float32) -> np.ndarray:
        """One physical page [blocks_per_page, bh, bw] — what a device
        page pool transfers host->HBM on a buffer-pool miss, without
        building the whole pool array."""
        bh, bw = self.cfg.dedup.block_shape
        page = self.packing.pages[pid]
        out = np.zeros((self.cfg.blocks_per_page, bh, bw), dtype=dtype)
        out[:len(page)] = self._distinct_stack()[np.asarray(page)]
        return out

    def virtual_tensor(self, model: str, tensor: str) -> VirtualTensor:
        """Indirection view used by the Pallas dedup kernels: block_map maps
        each logical block to a flat slot ``page * l + slot``.

        Slot-remap contract: every flat slot lies inside one of the
        tensor's *own* cover pages (``page_ids``), so a consumer that
        faults exactly ``page_ids`` resident (e.g. the device page pool)
        can always rewrite the map into its slot space.  The flat map is
        vectorized and cached per packing generation."""
        pk = self.packing
        key: TensorRef = (model, tensor)
        hit = self._vt_cache.get(key)
        if hit is not None:
            return hit
        e = self.dedup.models[model].tensors[tensor]
        l = self.cfg.blocks_per_page
        page_ids = sorted(set(pk.tensor_pages[key]))
        # did -> flat slot, restricted to this tensor's cover pages
        # (first placement in page-id order wins, matching _slot_of_block).
        slot_arr = np.full(len(self.dedup.distinct), -1, dtype=np.int64)
        for pid in reversed(page_ids):
            page = pk.pages[pid]
            slot_arr[np.asarray(page, dtype=np.int64)] = \
                pid * l + np.arange(len(page))
        flat = slot_arr[e.block_map].astype(np.int32)
        assert (flat >= 0).all(), \
            f"tensor {key}: block map escapes its cover pages"
        vt = VirtualTensor(e.grid, e.dtype, flat, page_ids)
        self._vt_cache[key] = vt
        return vt

    # ------------------------------------------------------------- serving --
    def page_sharers(self) -> Dict[int, frozenset]:
        """page id -> models whose tensors live (partly) on that page.
        This is the sharing structure Eq. 2 superposes rates over, and
        what the dedup-affinity scheduler co-schedules on."""
        sharers: Dict[int, set] = {}
        for (m, t), pids in self.packing.tensor_pages.items():
            for p in pids:
                sharers.setdefault(p, set()).add(m)
        return {p: frozenset(ms) for p, ms in sharers.items()}

    def model_pages(self, model: str) -> List[int]:
        """All pages the model's tensors touch (its page working set)."""
        pk = self.packing
        pages: set = set()
        for (m, t), pids in pk.tensor_pages.items():
            if m == model:
                pages.update(pids)
        return sorted(pages)

    def page_metadata(self) -> Tuple[Dict[int, frozenset],
                                     Dict[int, frozenset]]:
        """(page_sharers, page_locality) for the current packing — the
        Eq.-2 sharing structure and the locality-set (equivalence-class)
        grouping the pool policies consume."""
        pk = self.packing
        sharers = self.page_sharers()
        locality: Dict[int, frozenset] = {}
        owners: Dict[int, set] = {}
        for (m, t), pids in pk.tensor_pages.items():
            for p in pids:
                owners.setdefault(p, set()).add((m, t))
        for p, ts in owners.items():
            locality[p] = frozenset(ts)          # locality set = equivalence class
        return sharers, locality

    def make_buffer_pool(self, capacity_pages: int,
                         policy: str = "optimized_mru",
                         on_load=None, on_evict=None, **kw) -> BufferPool:
        """``on_load``/``on_evict`` attach a backing tier (e.g. the device
        page pool's host->HBM transfers) to the policy simulator."""
        sharers, locality = self.page_metadata()
        return BufferPool(PoolConfig(capacity_pages, policy, **kw),
                          page_sharers=sharers, page_locality=locality,
                          on_load=on_load, on_evict=on_evict)

    # --------------------------------------------------------- persistence --
    def save(self, path: str) -> Dict:
        """Content-addressed save: page files named by sha256; manifest JSON
        committed atomically last (crash-safe restart point)."""
        os.makedirs(path, exist_ok=True)
        pk = self.packing
        pool = self.page_pool()
        page_hashes: List[str] = []
        for pid in range(pk.num_pages):
            raw = np.ascontiguousarray(pool[pid]).tobytes()
            h = hashlib.sha256(raw).hexdigest()[:24]
            page_hashes.append(h)
            fp = os.path.join(path, f"page-{h}.npy")
            if not os.path.exists(fp):           # dedup on disk too
                np.save(fp, pool[pid])
        manifest = {
            "blocks_per_page": self.cfg.blocks_per_page,
            "block_shape": list(self.cfg.dedup.block_shape),
            "pages": [{"hash": h, "blocks": pk.pages[i]}
                      for i, h in enumerate(page_hashes)],
            "models": {
                m: {t: {"shape": list(e.grid.tensor_shape),
                        "dtype": str(np.dtype(e.dtype)),
                        "block_map": e.block_map.tolist(),
                        "pages": pk.tensor_pages[(m, t)]}
                    for t, e in res.tensors.items()}
                for m, res in self.dedup.models.items()},
        }
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit
        return manifest


def load_store_tensors(path: str) -> Dict[str, Dict[str, np.ndarray]]:
    """Rehydrate every model's tensors from a saved store directory."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    l = manifest["blocks_per_page"]
    bh, bw = manifest["block_shape"]
    # did -> block array, via the page files
    block_of: Dict[int, np.ndarray] = {}
    for entry in manifest["pages"]:
        page = np.load(os.path.join(path, f"page-{entry['hash']}.npy"))
        for slot, did in enumerate(entry["blocks"]):
            block_of.setdefault(did, page[slot])
    out: Dict[str, Dict[str, np.ndarray]] = {}
    for m, tensors in manifest["models"].items():
        out[m] = {}
        for t, spec in tensors.items():
            from .blocks import make_grid
            grid = make_grid(tuple(spec["shape"]), (bh, bw))
            blocks = np.stack([block_of[d] for d in spec["block_map"]])
            out[m][t] = unblock_tensor(blocks, grid).astype(spec["dtype"])
    return out
