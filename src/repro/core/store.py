"""ModelStore: the end-to-end deduplicated model repository (paper Fig. 3).

register -> dedup (Sec. 4) -> pack pages (Sec. 5) -> serve via buffer pool
(Sec. 6).  The on-disk format doubles as the system's *checkpoint* format:
content-addressed pages + per-model block maps + a JSON manifest, so a new
model variant ships only its private pages (DESIGN.md §2, changed
assumption 4).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from .blocks import BlockGrid, unblock_tensor
from .bufferpool import BufferPool, PoolConfig
from .dedup import DedupConfig, DedupResult, Deduplicator, Evaluator
from .pagepack import PackResult, check_coverage, pack

TensorRef = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    dedup: DedupConfig = dataclasses.field(default_factory=DedupConfig)
    blocks_per_page: int = 16           # page size limit "l"
    pack_strategy: str = "two_stage"


@dataclasses.dataclass
class VirtualTensor:
    """Device-servable representation: indices into the shared page pool."""
    grid: BlockGrid
    dtype: np.dtype
    block_map: np.ndarray        # [num_blocks] -> slot in the flattened pool
    page_ids: List[int]          # pages this tensor needs resident


class ModelStore:
    def __init__(self, cfg: Optional[StoreConfig] = None):
        self.cfg = cfg or StoreConfig()
        self.dedup = Deduplicator(self.cfg.dedup)
        self._pack: Optional[PackResult] = None
        self._slot_of_block: Dict[int, Tuple[int, int]] = {}  # did -> (page, slot)

    # ------------------------------------------------------------ pipeline --
    def register(self, model: str, tensors: Mapping[str, np.ndarray],
                 evaluator: Optional[Evaluator] = None,
                 layers=None) -> DedupResult:
        res = self.dedup.add_model(model, dict(tensors), evaluator, layers)
        self._pack = None                        # packing is now stale
        return res

    def remove(self, model: str) -> None:
        self.dedup.remove_model(model)
        self._pack = None

    def update(self, model: str, tensors: Mapping[str, np.ndarray],
               evaluator: Optional[Evaluator] = None,
               approach: int = 2) -> DedupResult:
        res = self.dedup.update_model(model, dict(tensors), evaluator, approach)
        self._pack = None
        return res

    def repack(self) -> PackResult:
        """(Re)run Sec.-5 page packing over the current distinct blocks."""
        tensor_sets = self.dedup.tensor_sets()
        seqs = {(m, t): self.dedup.models[m].tensors[t].block_map
                for m in self.dedup.models
                for t in self.dedup.models[m].tensors}
        self._pack = pack(tensor_sets, self.cfg.blocks_per_page,
                          self.cfg.pack_strategy, tensor_seqs=seqs)
        check_coverage(self._pack, tensor_sets, self.cfg.blocks_per_page)
        self._slot_of_block = {}
        for pid, page in enumerate(self._pack.pages):
            for slot, did in enumerate(page):
                # A block may appear in several pages (Alg. 3 copies); keep
                # the first placement as canonical.
                self._slot_of_block.setdefault(did, (pid, slot))
        return self._pack

    @property
    def packing(self) -> PackResult:
        if self._pack is None:
            self.repack()
        return self._pack

    # ----------------------------------------------------------- accessors --
    def num_pages(self) -> int:
        return self.packing.num_pages

    def storage_bytes(self, dtype=np.float32) -> int:
        bh, bw = self.cfg.dedup.block_shape
        itemsize = np.dtype(dtype).itemsize
        return self.packing.num_pages * self.cfg.blocks_per_page * bh * bw * itemsize

    def dense_bytes(self, dtype=np.float32) -> int:
        """Storage without dedup: every model's logical blocks, paged."""
        bh, bw = self.cfg.dedup.block_shape
        itemsize = np.dtype(dtype).itemsize
        l = self.cfg.blocks_per_page
        pages = 0
        for m in self.dedup.models.values():
            for e in m.tensors.values():
                pages += -(-e.grid.num_blocks // l)
        return pages * l * bh * bw * itemsize

    def materialize(self, model: str, tensor: str) -> np.ndarray:
        return self.dedup.materialize(model, tensor)

    def materialize_rows(self, model: str, tensor: str,
                         rows: np.ndarray) -> np.ndarray:
        """Gather only the requested rows (2-D tensors): the serving path's
        partial materialization — touches just the row blocks involved."""
        e = self.dedup.models[model].tensors[tensor]
        bh, bw = e.grid.block_shape
        gw = e.grid.grid[1]
        rows = np.asarray(rows)
        rb = rows // bh
        off = rows % bh
        out = np.empty((len(rows), e.grid.shape2d[1]), np.float32)
        for j in range(gw):
            dids = e.block_map[rb * gw + j]
            cols = slice(j * bw, min((j + 1) * bw, e.grid.shape2d[1]))
            width = cols.stop - cols.start
            for i, (did, o) in enumerate(zip(dids, off)):
                out[i, cols] = self.dedup.distinct[int(did)][o, :width]
        return out

    def page_pool(self, dtype=np.float32) -> np.ndarray:
        """[num_pages, blocks_per_page, bh, bw] physical page array."""
        bh, bw = self.cfg.dedup.block_shape
        l = self.cfg.blocks_per_page
        pool = np.zeros((self.packing.num_pages, l, bh, bw), dtype=dtype)
        for pid, page in enumerate(self.packing.pages):
            for slot, did in enumerate(page):
                pool[pid, slot] = self.dedup.distinct[did]
        return pool

    def virtual_tensor(self, model: str, tensor: str) -> VirtualTensor:
        """Indirection view used by the Pallas dedup kernels: block_map maps
        each logical block to a flat slot ``page * l + slot``."""
        pk = self.packing
        e = self.dedup.models[model].tensors[tensor]
        l = self.cfg.blocks_per_page
        flat = np.array([self._slot_of_block[int(d)][0] * l
                         + self._slot_of_block[int(d)][1]
                         for d in e.block_map], dtype=np.int32)
        return VirtualTensor(e.grid, e.dtype, flat,
                             sorted(set(pk.tensor_pages[(model, tensor)])))

    # ------------------------------------------------------------- serving --
    def page_sharers(self) -> Dict[int, frozenset]:
        """page id -> models whose tensors live (partly) on that page.
        This is the sharing structure Eq. 2 superposes rates over, and
        what the dedup-affinity scheduler co-schedules on."""
        sharers: Dict[int, set] = {}
        for (m, t), pids in self.packing.tensor_pages.items():
            for p in pids:
                sharers.setdefault(p, set()).add(m)
        return {p: frozenset(ms) for p, ms in sharers.items()}

    def model_pages(self, model: str) -> List[int]:
        """All pages the model's tensors touch (its page working set)."""
        pk = self.packing
        pages: set = set()
        for (m, t), pids in pk.tensor_pages.items():
            if m == model:
                pages.update(pids)
        return sorted(pages)

    def make_buffer_pool(self, capacity_pages: int,
                         policy: str = "optimized_mru", **kw) -> BufferPool:
        pk = self.packing
        sharers = self.page_sharers()
        locality: Dict[int, frozenset] = {}
        owners: Dict[int, set] = {}
        for (m, t), pids in pk.tensor_pages.items():
            for p in pids:
                owners.setdefault(p, set()).add((m, t))
        for p, ts in owners.items():
            locality[p] = frozenset(ts)          # locality set = equivalence class
        return BufferPool(PoolConfig(capacity_pages, policy, **kw),
                          page_sharers=sharers, page_locality=locality)

    # --------------------------------------------------------- persistence --
    def save(self, path: str) -> Dict:
        """Content-addressed save: page files named by sha256; manifest JSON
        committed atomically last (crash-safe restart point)."""
        os.makedirs(path, exist_ok=True)
        pk = self.packing
        pool = self.page_pool()
        page_hashes: List[str] = []
        for pid in range(pk.num_pages):
            raw = np.ascontiguousarray(pool[pid]).tobytes()
            h = hashlib.sha256(raw).hexdigest()[:24]
            page_hashes.append(h)
            fp = os.path.join(path, f"page-{h}.npy")
            if not os.path.exists(fp):           # dedup on disk too
                np.save(fp, pool[pid])
        manifest = {
            "blocks_per_page": self.cfg.blocks_per_page,
            "block_shape": list(self.cfg.dedup.block_shape),
            "pages": [{"hash": h, "blocks": pk.pages[i]}
                      for i, h in enumerate(page_hashes)],
            "models": {
                m: {t: {"shape": list(e.grid.tensor_shape),
                        "dtype": str(np.dtype(e.dtype)),
                        "block_map": e.block_map.tolist(),
                        "pages": pk.tensor_pages[(m, t)]}
                    for t, e in res.tensors.items()}
                for m, res in self.dedup.models.items()},
        }
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit
        return manifest


def load_store_tensors(path: str) -> Dict[str, Dict[str, np.ndarray]]:
    """Rehydrate every model's tensors from a saved store directory."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    l = manifest["blocks_per_page"]
    bh, bw = manifest["block_shape"]
    # did -> block array, via the page files
    block_of: Dict[int, np.ndarray] = {}
    for entry in manifest["pages"]:
        page = np.load(os.path.join(path, f"page-{entry['hash']}.npy"))
        for slot, did in enumerate(entry["blocks"]):
            block_of.setdefault(did, page[slot])
    out: Dict[str, Dict[str, np.ndarray]] = {}
    for m, tensors in manifest["models"].items():
        out[m] = {}
        for t, spec in tensors.items():
            from .blocks import make_grid
            grid = make_grid(tuple(spec["shape"]), (bh, bw))
            blocks = np.stack([block_of[d] for d in spec["block_map"]])
            out[m][t] = unblock_tensor(blocks, grid).astype(spec["dtype"])
    return out
