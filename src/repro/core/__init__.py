"""Core library: the paper's contribution (dedup + paging + caching)."""
from .blocks import (BlockGrid, DEFAULT_BLOCK_SHAPE, block_tensor,
                     gather_blocks, make_grid, materialize, unblock_tensor)
from .bufferpool import POLICIES, BufferPool, PoolConfig, run_trace
from .dedup import (DedupConfig, DedupResult, Deduplicator, exact_dedup,
                    minhash_dedup, pairwise_dedup)
from .lsh import L2LSH, LSHConfig, LSHIndex
from .magnitude import block_magnitudes
from .pagepack import (PackResult, alg2_bound, check_coverage,
                       equivalent_classes, pack, pack_dedup_base,
                       pack_greedy1, pack_greedy2, pack_two_stage)
from .store import ModelStore, StoreConfig, VirtualTensor, load_store_tensors

__all__ = [
    "BlockGrid", "DEFAULT_BLOCK_SHAPE", "block_tensor", "gather_blocks",
    "make_grid", "materialize", "unblock_tensor",
    "POLICIES", "BufferPool", "PoolConfig", "run_trace",
    "DedupConfig", "DedupResult", "Deduplicator", "exact_dedup",
    "minhash_dedup", "pairwise_dedup",
    "L2LSH", "LSHConfig", "LSHIndex", "block_magnitudes",
    "PackResult", "alg2_bound", "check_coverage", "equivalent_classes",
    "pack", "pack_dedup_base", "pack_greedy1", "pack_greedy2",
    "pack_two_stage",
    "ModelStore", "StoreConfig", "VirtualTensor", "load_store_tensors",
]
