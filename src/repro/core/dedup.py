"""Duplicate-block detection (paper Sec. 4, Alg. 1) + baselines (Tab. 5).

The central object is :class:`Deduplicator`, which owns the incremental
LSH index (``idx`` in Alg. 1), the list of distinct physical blocks
(``L``), and per-model mappings ``F_T`` from logical block positions to
distinct-block ids.

Faithfulness notes:
  * Blocks are processed per layer, layers ordered by tensor size
    descending (Sec. 4.3); within a layer, ascending magnitude (q3).
  * Every ``k`` blocks the model is re-validated; once the accuracy drop
    exceeds ``t`` the model *stops deduplicating*: remaining blocks are
    inserted as their own new groups (Alg. 1 lines 23–27; the prose in
    Step 4 says "added to the corresponding group but not replaced" — we
    follow the algorithm listing, which keeps group⇄distinct 1:1).
  * No rollback of the last over-threshold batch (Sec. 7.3: "we do not
    roll back").
  * The validation-free variant (Sec. 4.3 "Alternative Approach") is
    ``validate=False`` + the LSH ``collision_threshold`` knob (Tab. 6).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .blocks import (BlockGrid, DEFAULT_BLOCK_SHAPE, block_tensor,
                     unblock_tensor)
from .lsh import LSHConfig, LSHIndex
from .magnitude import block_magnitudes

Evaluator = Callable[[Dict[str, np.ndarray]], float]
TensorRef = Tuple[str, str]  # (model, tensor)


@dataclasses.dataclass(frozen=True)
class DedupConfig:
    block_shape: Tuple[int, int] = DEFAULT_BLOCK_SHAPE
    lsh: LSHConfig = dataclasses.field(default_factory=LSHConfig)
    magnitude_stat: str = "q3"
    validate_every_k: int = 64          # "k" in Alg. 1
    accuracy_drop_threshold: float = 0.035  # "t" (paper uses 3.5%)
    validate: bool = True               # False => Tab. 6 threshold-only variant


@dataclasses.dataclass
class TensorEntry:
    name: str
    grid: BlockGrid
    dtype: np.dtype
    block_map: np.ndarray               # [num_blocks] -> distinct id (f_i in Alg. 1)


@dataclasses.dataclass
class DedupResult:
    model: str
    tensors: Dict[str, TensorEntry]
    total_blocks: int = 0
    deduped_blocks: int = 0             # logical blocks replaced by a pre-existing rep
    stopped: bool = False               # accuracy budget exhausted
    accuracy_before: Optional[float] = None
    accuracy_after: Optional[float] = None
    num_validations: int = 0
    index_query_seconds: float = 0.0


class Deduplicator:
    """Incremental cross-model block deduplication (the paper's Fig. 3)."""

    def __init__(self, cfg: Optional[DedupConfig] = None):
        self.cfg = cfg or DedupConfig()
        bh, bw = self.cfg.block_shape
        self.index = LSHIndex(bh * bw, self.cfg.lsh)
        # Distinct physical blocks ("L"); tombstoned with None on removal.
        self.distinct: List[Optional[np.ndarray]] = []
        # distinct id -> {(model, tensor): ref count}
        self.owners: List[Dict[TensorRef, int]] = []
        self._gid_to_did: Dict[int, int] = {}
        self._did_to_gid: Dict[int, int] = {}
        self.models: Dict[str, DedupResult] = {}

    # ------------------------------------------------------------------ utils
    @property
    def num_distinct(self) -> int:
        return sum(1 for b in self.distinct if b is not None)

    def pool(self, dtype=None) -> np.ndarray:
        """Stack live distinct blocks into ``[n, bh, bw]`` (tombstones kept
        as zero blocks so ids remain stable)."""
        bh, bw = self.cfg.block_shape
        out = np.zeros((len(self.distinct), bh, bw),
                       dtype=dtype or np.float32)
        for i, b in enumerate(self.distinct):
            if b is not None:
                out[i] = b
        return out

    def tensor_distinct_ids(self, model: str, tensor: str) -> np.ndarray:
        return np.unique(self.models[model].tensors[tensor].block_map)

    def materialize(self, model: str, tensor: str) -> np.ndarray:
        e = self.models[model].tensors[tensor]
        blocks = np.stack([self.distinct[d] for d in e.block_map])
        return unblock_tensor(blocks, e.grid).astype(e.dtype)

    def materialize_all(self, model: str) -> Dict[str, np.ndarray]:
        return {t: self.materialize(model, t)
                for t in self.models[model].tensors}

    def _new_distinct(self, block: np.ndarray, ref: TensorRef,
                      sig: np.ndarray, member) -> int:
        gid = self.index.insert_group(sig, member)
        did = len(self.distinct)
        self.distinct.append(np.array(block, copy=True))
        self.owners.append({ref: 1})
        self._gid_to_did[gid] = did
        self._did_to_gid[did] = gid
        return did

    def _add_ref(self, did: int, ref: TensorRef) -> None:
        self.owners[did][ref] = self.owners[did].get(ref, 0) + 1

    # ------------------------------------------------------------- Alg. 1 ---
    def add_model(self, model: str, tensors: Dict[str, np.ndarray],
                  evaluator: Optional[Evaluator] = None,
                  layers: Optional[Sequence[Sequence[str]]] = None
                  ) -> DedupResult:
        """Run Alg. 1 over every layer of ``model``; updates the shared index."""
        cfg = self.cfg
        if model in self.models:
            raise ValueError(f"model {model!r} already registered")
        res = DedupResult(model=model, tensors={})
        self.models[model] = res

        # Blocked working copies (mutated as blocks get replaced) so the
        # periodic evaluator sees the *deduplicated* model.
        blocked: Dict[str, np.ndarray] = {}
        for name, x in tensors.items():
            x = np.asarray(x)
            blk, grid = block_tensor(x, cfg.block_shape)
            blocked[name] = blk.astype(np.float32)
            res.tensors[name] = TensorEntry(
                name, grid, x.dtype,
                np.full(grid.num_blocks, -1, dtype=np.int64))
            res.total_blocks += grid.num_blocks

        def current_tensors() -> Dict[str, np.ndarray]:
            return {n: unblock_tensor(blocked[n], res.tensors[n].grid)
                    .astype(res.tensors[n].dtype)
                    for n in blocked}

        do_validate = cfg.validate and evaluator is not None
        if do_validate:
            res.accuracy_before = float(evaluator(current_tensors()))

        if layers is None:
            layers = [[n] for n in tensors]
        # Layers ordered by total tensor size descending (Sec. 4.3).
        layers = sorted(layers,
                        key=lambda ns: -sum(np.asarray(tensors[n]).size
                                            for n in ns))
        stopped = False
        for layer in layers:
            # Gather (tensor, block_id) for the whole layer, magnitude-sorted.
            names, bids, mags = [], [], []
            for n in layer:
                m = block_magnitudes(blocked[n], cfg.magnitude_stat)
                names.extend([n] * len(m))
                bids.extend(range(len(m)))
                mags.append(m)
            order = np.argsort(np.concatenate(mags), kind="stable")
            seq = [(names[i], bids[i]) for i in order]

            i = 0
            while i < len(seq):
                if stopped:
                    # Remaining blocks stay distinct (Alg. 1 lines 23–27).
                    for n, b in seq[i:]:
                        self._index_as_distinct(model, res, blocked, n, b)
                    break
                batch = seq[i: i + cfg.validate_every_k]
                for n, b in batch:
                    self._dedup_one(model, res, blocked, n, b)
                i += len(batch)
                if do_validate and i < len(seq):
                    res.num_validations += 1
                    acc = float(evaluator(current_tensors()))
                    if res.accuracy_before - acc > cfg.accuracy_drop_threshold:
                        stopped = True
            if stopped:
                # Stop applies to the whole model: remaining layers too.
                continue

        if do_validate:
            res.accuracy_after = float(evaluator(current_tensors()))
        res.stopped = stopped
        return res

    def _dedup_one(self, model: str, res: DedupResult,
                   blocked: Dict[str, np.ndarray], name: str, bid: int) -> None:
        block = blocked[name][bid]
        t0 = time.perf_counter()
        sig = self.index.lsh.signatures(block[None])[0]
        gid = self.index.query(sig)
        res.index_query_seconds += time.perf_counter() - t0
        ref: TensorRef = (model, name)
        member = (model, name, bid)
        if gid is not None:
            did = self._gid_to_did[gid]
            self.index.add_member(gid, member)
            self._add_ref(did, ref)
            blocked[name][bid] = self.distinct[did]      # replace by rep
            res.tensors[name].block_map[bid] = did
            res.deduped_blocks += 1
        else:
            res.tensors[name].block_map[bid] = \
                self._new_distinct(block, ref, sig, member)

    def _index_as_distinct(self, model: str, res: DedupResult,
                           blocked: Dict[str, np.ndarray],
                           name: str, bid: int) -> None:
        block = blocked[name][bid]
        sig = self.index.lsh.signatures(block[None])[0]
        res.tensors[name].block_map[bid] = self._new_distinct(
            block, (model, name), sig, (model, name, bid))

    # ------------------------------------------------- updates (Sec. 7.6.1) --
    def remove_model(self, model: str) -> None:
        """Approach-1: drop all refs; empty groups/tombstoned blocks removed."""
        res = self.models.pop(model)
        for name, e in res.tensors.items():
            ref: TensorRef = (model, name)
            for bid, did in enumerate(e.block_map):
                did = int(did)
                cnt = self.owners[did]
                cnt[ref] -= 1
                if cnt[ref] == 0:
                    del cnt[ref]
                gid = self._did_to_gid[did]
                dropped = self.index.remove_member(gid, (model, name, bid))
                if dropped:
                    self.distinct[did] = None            # tombstone
                    del self._did_to_gid[did]
                    del self._gid_to_did[gid]

    def update_model(self, model: str, tensors: Dict[str, np.ndarray],
                     evaluator: Optional[Evaluator] = None,
                     approach: int = 2) -> DedupResult:
        """Approach-1 (remove + re-insert) or Approach-2 (LSH delta)."""
        if approach == 1:
            self.remove_model(model)
            return self.add_model(model, tensors, evaluator)

        # Approach-2: only blocks whose LSH signature changed are
        # reprocessed (index query + validation skipped for the rest).
        old = self.models[model]
        plans = {}
        for name, x in tensors.items():
            blk, grid = block_tensor(np.asarray(x), self.cfg.block_shape)
            blk = blk.astype(np.float32)
            sigs = self.index.lsh.signatures(blk)
            olde = old.tensors.get(name)
            if olde is None or olde.grid != grid:
                mask = np.ones(len(blk), dtype=bool)
                old_map = None
            else:
                old_sigs = np.stack([
                    self.index.groups[self._did_to_gid[int(d)]].rep_signature
                    for d in olde.block_map])
                mask = np.any(sigs != old_sigs, axis=1)
                old_map = olde.block_map.copy()
            plans[name] = (blk, grid, sigs, mask, old_map,
                           np.asarray(x).dtype)

        self.remove_model(model)
        res = DedupResult(model=model, tensors={})
        self.models[model] = res
        blocked: Dict[str, np.ndarray] = {}
        for name, (blk, grid, sigs, mask, old_map, dtype) in plans.items():
            blocked[name] = blk
            res.tensors[name] = TensorEntry(
                name, grid, dtype,
                np.full(grid.num_blocks, -1, dtype=np.int64))
            res.total_blocks += grid.num_blocks
            for bid in range(grid.num_blocks):
                unchanged = (old_map is not None and not mask[bid])
                if unchanged:
                    did = int(old_map[bid])
                    # the old distinct block may have been tombstoned by
                    # remove_model if this model was its sole owner
                    if self.distinct[did] is not None \
                            and did in self._did_to_gid:
                        gid = self._did_to_gid[did]
                        self.index.add_member(gid, (model, name, bid))
                        self._add_ref(did, (model, name))
                        blocked[name][bid] = self.distinct[did]
                        res.tensors[name].block_map[bid] = did
                        if did != bid:
                            res.deduped_blocks += 1
                        continue
                # changed (or tombstoned): full Alg.-1 path for this block
                self._dedup_one(model, res, blocked, name, bid)
        n_changed = int(sum(m.sum() for _, _, _, m, om, _ in plans.values()
                            if om is not None)
                        + sum(len(m) for _, _, _, m, om, _ in plans.values()
                              if om is None))
        res.num_validations = max(
            1, n_changed // max(1, self.cfg.validate_every_k))
        if evaluator is not None:
            res.accuracy_after = float(evaluator(self.materialize_all(model)))
        return res

    # ------------------------------------------- reopened-store hydration --
    def rebuild_index(self) -> None:
        """Reconstruct the LSH index + group bookkeeping from the current
        distinct blocks and block maps (a store reopened from a
        :mod:`repro.storage` backend persists blocks and maps, not the
        index).  Signatures are recomputed vectorized under the *current*
        LSH config, so subsequent ``add_model``/``update_model`` calls
        dedup incrementally against the reloaded blocks exactly as if
        the store had never left memory."""
        bh, bw = self.cfg.block_shape
        self.index = LSHIndex(bh * bw, self.cfg.lsh)
        self._gid_to_did.clear()
        self._did_to_gid.clear()
        self.owners = [dict() for _ in self.distinct]
        live = [did for did, b in enumerate(self.distinct) if b is not None]
        if not live:
            return
        members_of: Dict[int, List[Tuple[str, str, int]]] = \
            {did: [] for did in live}
        for m, res in self.models.items():
            for name, e in res.tensors.items():
                for bid, did in enumerate(e.block_map):
                    did = int(did)
                    members_of[did].append((m, name, bid))
                    ref = (m, name)
                    self.owners[did][ref] = self.owners[did].get(ref, 0) + 1
        sigs = self.index.lsh.signatures(
            np.stack([self.distinct[did] for did in live]))
        for sig, did in zip(sigs, live):
            members = members_of[did] or [("__orphan__", "__orphan__", did)]
            gid = self.index.insert_group(sig, members[0])
            for ref in members[1:]:
                self.index.add_member(gid, ref)
            self._gid_to_did[gid] = did
            self._did_to_gid[did] = gid

    # ---------------------------------------------------- pagepack interface --
    def tensor_sets(self) -> Dict[TensorRef, frozenset]:
        """(model, tensor) -> frozenset of distinct ids (input to Sec. 5)."""
        out: Dict[TensorRef, frozenset] = {}
        for m, res in self.models.items():
            for name, e in res.tensors.items():
                out[(m, name)] = frozenset(int(d) for d in np.unique(e.block_map))
        return out

    def block_owners(self) -> Dict[int, frozenset]:
        """distinct id -> frozenset of owning (model, tensor) refs."""
        return {did: frozenset(cnt.keys())
                for did, cnt in enumerate(self.owners)
                if self.distinct[did] is not None and cnt}


# ===================================================================== baselines
def exact_dedup(blocks: np.ndarray) -> Tuple[np.ndarray, int, float]:
    """Mistique exact dedup: byte-identical blocks share one copy.

    Returns (block_map, num_distinct, seconds_per_query).
    """
    t0 = time.perf_counter()
    seen: Dict[bytes, int] = {}
    bmap = np.zeros(len(blocks), dtype=np.int64)
    nxt = 0
    for i, b in enumerate(np.asarray(blocks, dtype=np.float32)):
        h = hashlib.sha1(b.tobytes()).digest()
        if h in seen:
            bmap[i] = seen[h]
        else:
            seen[h] = nxt
            bmap[i] = nxt
            nxt += 1
    dt = (time.perf_counter() - t0) / max(1, len(blocks))
    return bmap, nxt, dt


def minhash_dedup(blocks: np.ndarray, num_perm: int = 32,
                  bins: int = 64, threshold: float = 0.7
                  ) -> Tuple[np.ndarray, int, float]:
    """Mistique *approximate* dedup: discretize values into bins, then
    MinHash the set of (position-bucket, value-bin) features.  Inherently
    slow (paper Tab. 5: 10+ s/block) — kept small-scale for benchmarks."""
    t0 = time.perf_counter()
    flat = np.asarray(blocks, dtype=np.float32).reshape(len(blocks), -1)
    lo, hi = flat.min(), flat.max() + 1e-9
    digit = ((flat - lo) / (hi - lo) * (bins - 1)).astype(np.int64)
    feats = digit + bins * np.arange(flat.shape[1])[None, :]   # (pos, bin) feature
    rng = np.random.default_rng(0)
    # Universal hashing h_i(x) = (a_i x + b_i) mod p
    p = (1 << 61) - 1
    a = rng.integers(1, p, size=num_perm, dtype=np.int64)
    b = rng.integers(0, p, size=num_perm, dtype=np.int64)
    reps: List[np.ndarray] = []
    bmap = np.zeros(len(blocks), dtype=np.int64)
    for i in range(len(blocks)):
        f = feats[i].astype(object)
        sig = np.array([int(min((int(ai) * f + int(bi)) % p))
                        for ai, bi in zip(a, b)], dtype=np.int64)
        match = -1
        for j, r in enumerate(reps):
            if (sig == r).mean() >= threshold:
                match = j
                break
        if match < 0:
            reps.append(sig)
            match = len(reps) - 1
        bmap[i] = match
    dt = (time.perf_counter() - t0) / max(1, len(blocks))
    return bmap, len(reps), dt


def pairwise_dedup(blocks: np.ndarray, dist_threshold: float,
                   magnitude_stat: str = "q3"
                   ) -> Tuple[np.ndarray, int, float]:
    """Enhanced pairwise comparison with magnitude ordering (Tab. 5 row 3):
    linear scan of representatives by Euclidean distance."""
    t0 = time.perf_counter()
    flat = np.asarray(blocks, dtype=np.float32).reshape(len(blocks), -1)
    order = np.argsort(block_magnitudes(np.asarray(blocks), magnitude_stat),
                       kind="stable")
    reps: List[int] = []
    bmap = np.zeros(len(blocks), dtype=np.int64)
    for i in order:
        match = -1
        if reps:
            d = np.linalg.norm(flat[np.array(reps)] - flat[i], axis=1)
            j = int(np.argmin(d))
            if d[j] <= dist_threshold:
                match = reps[j]
        if match < 0:
            reps.append(int(i))
            match = int(i)
        bmap[i] = match
    # renumber to dense ids
    uniq, dense = np.unique(bmap, return_inverse=True)
    dt = (time.perf_counter() - t0) / max(1, len(blocks))
    return dense, len(uniq), dt
