"""Dedup-aware fine-tuning (paper Sec. 4.3 "Fine-Tuning").

After deduplication, shared blocks are frozen and only blocks private to
one model are tuned.  We realize the freeze as a *gradient mask* over the
block grid: 1 where a block is private to the model, 0 where shared.
Works with any JAX optimizer (mask multiplies the gradient pytree).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from .dedup import Deduplicator


def private_block_mask(dedup: Deduplicator, model: str,
                       tensor: str) -> np.ndarray:
    """[num_blocks] float mask: 1.0 for blocks only this model references."""
    e = dedup.models[model].tensors[tensor]
    mask = np.zeros(e.grid.num_blocks, dtype=np.float32)
    for bid, did in enumerate(e.block_map):
        owners = dedup.owners[int(did)]
        models = {m for (m, _t) in owners}
        mask[bid] = 1.0 if models == {model} else 0.0
    return mask


def gradient_mask(dedup: Deduplicator, model: str,
                  tensor: str) -> np.ndarray:
    """Full-tensor-shape gradient mask (blocks expanded, padding cropped)."""
    e = dedup.models[model].tensors[tensor]
    bm = private_block_mask(dedup, model, tensor)
    bh, bw = e.grid.block_shape
    blocks = np.repeat(np.repeat(bm[:, None, None], bh, 1), bw, 2)
    from .blocks import unblock_tensor
    return unblock_tensor(blocks, e.grid)


def gradient_masks(dedup: Deduplicator, model: str) -> Dict[str, np.ndarray]:
    return {t: gradient_mask(dedup, model, t)
            for t in dedup.models[model].tensors}


def apply_masks(grads: Dict[str, np.ndarray],
                masks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    return {k: g * masks[k] if k in masks else g for k, g in grads.items()}
