"""Block magnitude statistics (Sec. 4.3, Step 1 / Fig. 4).

The paper deduplicates blocks in *ascending* order of an aggregated
magnitude statistic, defaulting to the 3rd quartile of ``|w|`` because it
reflects both the magnitude and the quantity of large weights in a block.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

MagnitudeFn = Callable[[np.ndarray], np.ndarray]


def _flat_abs(blocks: np.ndarray) -> np.ndarray:
    return np.abs(np.asarray(blocks, dtype=np.float32)).reshape(len(blocks), -1)


def q3(blocks: np.ndarray) -> np.ndarray:
    return np.quantile(_flat_abs(blocks), 0.75, axis=1)


def q1(blocks: np.ndarray) -> np.ndarray:
    return np.quantile(_flat_abs(blocks), 0.25, axis=1)


def median(blocks: np.ndarray) -> np.ndarray:
    return np.median(_flat_abs(blocks), axis=1)


def mean(blocks: np.ndarray) -> np.ndarray:
    return _flat_abs(blocks).mean(axis=1)


def l2(blocks: np.ndarray) -> np.ndarray:
    return np.sqrt((_flat_abs(blocks) ** 2).sum(axis=1))


MAGNITUDE_FNS: Dict[str, MagnitudeFn] = {
    "q3": q3,
    "q1": q1,
    "median": median,
    "mean": mean,
    "l2": l2,
}


def block_magnitudes(blocks: np.ndarray, stat: str = "q3") -> np.ndarray:
    """[n, bh, bw] -> [n] magnitude scores (ascending order = dedup first)."""
    try:
        fn = MAGNITUDE_FNS[stat]
    except KeyError:
        raise ValueError(f"unknown magnitude stat {stat!r}; "
                         f"choose from {sorted(MAGNITUDE_FNS)}") from None
    return fn(blocks)
