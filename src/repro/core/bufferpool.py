"""Dedup-aware buffer-pool management (paper Sec. 6).

The pool holds a bounded number of pages.  Baseline policies: LRU / MRU /
LFU.  Locality-set policies (Pangea, refs [82, 83]) group pages into
locality sets, each with its own internal policy; the victim *set* is the
one whose next page-to-evict has the lowest expected eviction cost

    cost = c_w + p_reuse * c_r                                     (Eq. 1)

The paper's contribution ("Optimized-M/L"): model page accesses as
superposed Poisson processes of the models *sharing* the page, so

    p_reuse = 1 - exp(-sum_{m_i in sharers} lambda_i * t)          (Eq. 2)

giving shared pages higher retention priority.  ``lambda_i`` is estimated
online from each model's request stream (EMA of instantaneous rate) — in
the serving engine these are the per-model queue rates.

The pool is a policy simulator by default; ``on_load``/``on_evict``
callbacks let the serving engine attach real host<->HBM page movement
(the TPU adaptation of disk<->DRAM paging, see DESIGN.md §2).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from collections import OrderedDict, defaultdict
from typing import Callable, Dict, Hashable, Iterable, List, Optional, Set

from ..obs import get_tracer

PageId = Hashable
ModelId = Hashable

POLICIES = ("lru", "mru", "lfu",
            "locality_lru", "locality_mru",
            "optimized_lru", "optimized_mru")


@dataclasses.dataclass
class PoolConfig:
    """Eviction-policy parameters (paper Eq. 1/Eq. 2 constants)."""
    capacity_pages: int
    policy: str = "optimized_mru"
    c_w: float = 0.0        # weights are read-only -> no write-back cost
    c_r: float = 1.0
    horizon_t: float = 8.0  # "t time ticks" in Eq. 2
    rate_ema: float = 0.2   # EMA factor for lambda estimation

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")


@dataclasses.dataclass
class _PageMeta:
    last_tick: int = -1
    freq: int = 0
    locality_set: Hashable = None
    sharers: frozenset = frozenset()


class BufferPool:
    """Page residency policy simulator: tracks hits/misses, arrival
    rates and eviction order (Eq. 1/Eq. 2), driving the physical tiers
    through ``on_load`` / ``on_evict`` / ``on_load_group`` callbacks."""

    def __init__(self, cfg: PoolConfig,
                 page_sharers: Optional[Dict[PageId, Iterable[ModelId]]] = None,
                 page_locality: Optional[Dict[PageId, Hashable]] = None,
                 on_load: Optional[Callable[[PageId], None]] = None,
                 on_evict: Optional[Callable[[PageId], None]] = None,
                 on_load_group: Optional[Callable[[List[PageId]],
                                                  None]] = None):
        self.cfg = cfg
        self.meta: Dict[PageId, _PageMeta] = {}
        self.resident: "OrderedDict[PageId, None]" = OrderedDict()
        self.page_sharers = {p: frozenset(ms)
                             for p, ms in (page_sharers or {}).items()}
        self.page_locality = dict(page_locality or {})
        self.on_load = on_load
        self.on_evict = on_evict
        # Grouped backing-tier attachment: inside a deferred_loads()
        # window every miss's physical load is collected and flushed as
        # ONE on_load_group call (e.g. a single batched host->HBM
        # transfer) instead of per-page on_load round trips.  When only
        # on_load is attached the flush falls back to per-page calls, so
        # the per-page path is always preserved.
        self.on_load_group = on_load_group
        self._load_batch: Optional[List[PageId]] = None
        self.tick = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.prefetches = 0          # pages admitted by prefetch()
        self.prefetch_declined = 0   # prefetch offers the policy refused
        self._lambda: Dict[ModelId, float] = defaultdict(float)
        self._last_access: Dict[ModelId, int] = {}
        self._set_lambda: Dict[Hashable, float] = defaultdict(float)
        self._set_last: Dict[Hashable, int] = {}
        self._pinned: Set[PageId] = set()

    # ------------------------------------------------------------- metrics --
    @property
    def hit_ratio(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
        self.prefetches = self.prefetch_declined = 0

    def model_rates(self) -> Dict[ModelId, float]:
        """Per-model arrival-rate estimates (the lambda_i of Eq. 2), as
        maintained online from the demand access stream.  The serving
        prefetcher keys its model-hotness ranking off these."""
        return dict(self._lambda)

    def resident_pages(self) -> Set[PageId]:
        return set(self.resident)

    def invalidate_resident(self) -> None:
        """Drop every resident page *without* charging evictions: the
        backing store was repacked, so page ids no longer name the same
        bytes.  ``on_evict`` still fires per page so an attached device
        slab frees its slots."""
        for page in list(self.resident):
            del self.resident[page]
            if self.on_evict:
                self.on_evict(page)

    # -------------------------------------------------------------- access --
    def _ensure_meta(self, model: ModelId, page: PageId) -> _PageMeta:
        m = self.meta.get(page)
        if m is None:
            m = self.meta[page] = _PageMeta(
                locality_set=self.page_locality.get(page, page),
                sharers=self.page_sharers.get(page, frozenset([model])))
        return m

    def access(self, model: ModelId, page: PageId) -> bool:
        """Record an access; returns True on hit.  Loads the page on miss,
        evicting per policy when over capacity."""
        self.tick += 1
        self._update_rate(model)
        m = self._ensure_meta(model, page)
        self._update_set_rate(m.locality_set)
        m.last_tick = self.tick
        m.freq += 1

        if page in self.resident:
            self.hits += 1
            self.resident.move_to_end(page)      # LRU order maintenance
            return True
        self.misses += 1
        while len(self.resident) >= self.cfg.capacity_pages:
            self._evict_one()
        self.resident[page] = None
        try:
            self._note_load(page)
        except BaseException:
            # failed physical load: un-admit (no on_evict — no slot held)
            self.resident.pop(page, None)
            raise
        return False

    def _note_load(self, page: PageId) -> None:
        """Fire (or defer) the physical load for a freshly admitted page:
        inside a deferred_loads() window the page joins the batch flushed
        as one on_load_group; otherwise the per-page on_load fires."""
        if self._load_batch is not None:
            self._load_batch.append(page)
        elif self.on_load:
            self.on_load(page)

    def _flush_loads(self, batch: List[PageId]) -> None:
        # A page admitted and then evicted inside the same deferred
        # window must NOT be physically loaded: its eviction already
        # fired on_evict (a no-op slot free on an attached slab, since
        # the deferred load never claimed one), and loading it anyway
        # would create a ghost slab resident — or exhaust the slab's
        # free slots outright.  Flush only what is still resident.
        batch = [p for p in batch if p in self.resident]
        if not batch:
            return
        # Exception safety: if the physical load throws (e.g. a storage
        # fault past its retry budget), every page whose load did not
        # complete must be UN-admitted — it is resident in the policy's
        # books but holds no slab slot, a ghost that would serve garbage.
        # on_evict is deliberately not fired: the failed load never
        # claimed a slot, so there is nothing to free.
        if self.on_load_group is not None:
            try:
                self.on_load_group(list(batch))
            except BaseException:
                for page in batch:
                    self.resident.pop(page, None)
                raise
        elif self.on_load:
            for i, page in enumerate(batch):
                try:
                    self.on_load(page)
                except BaseException:
                    for p in batch[i:]:
                        self.resident.pop(p, None)
                    raise

    @contextlib.contextmanager
    def deferred_loads(self):
        """Collect every physical page load admitted inside the window
        and flush them as ONE grouped backing-tier transfer on exit
        (``on_load_group``; per-page ``on_load`` fallback preserved).
        Policy bookkeeping — hits/misses, evictions, recency — stays
        per-page and immediate; only the *physical* movement batches.
        Reentrant: a nested window joins the outer batch.  The flush
        runs even if the body raises, so the residency bookkeeping and
        the backing tier can never diverge."""
        if self._load_batch is not None:         # nested: join outer batch
            yield
            return
        self._load_batch = []
        try:
            yield
        finally:
            batch, self._load_batch = self._load_batch, None
            self._flush_loads(batch)

    def access_group(self, model: ModelId, pages: Iterable[PageId]
                     ) -> List[bool]:
        """Touch a batch's whole page working set atomically: the group is
        *pinned* for the duration, so a later miss in the same group can
        never evict an earlier member (which would tear a device-resident
        working set mid-batch), and the group's misses flush as ONE
        physical load (``deferred_loads``).  Raises ValueError when the
        group cannot possibly co-reside — callers fall back to unpinned
        access.  Returns the per-page hit flags."""
        pages = list(pages)
        if len(set(pages)) > self.cfg.capacity_pages:
            raise ValueError(
                f"group of {len(set(pages))} pages exceeds pool capacity "
                f"{self.cfg.capacity_pages}")
        self._pinned = set(pages)
        try:
            with get_tracer().span("pool_group", kind="pool", model=model,
                                   pages=len(pages)) as sp:
                with self.deferred_loads():
                    hits = [self.access(model, p) for p in pages]
                sp.set(hits=sum(hits))
                return hits
        finally:
            self._pinned = set()

    def _update_rate(self, model: ModelId) -> None:
        last = self._last_access.get(model)
        if last is not None:
            inst = 1.0 / max(1, self.tick - last)
            a = self.cfg.rate_ema
            self._lambda[model] = (1 - a) * self._lambda[model] + a * inst
        else:
            self._lambda[model] = self.cfg.rate_ema
        self._last_access[model] = self.tick

    def _update_set_rate(self, ls: Hashable) -> None:
        last = self._set_last.get(ls)
        if last is not None:
            inst = 1.0 / max(1, self.tick - last)
            a = self.cfg.rate_ema
            self._set_lambda[ls] = (1 - a) * self._set_lambda[ls] + a * inst
        else:
            self._set_lambda[ls] = self.cfg.rate_ema
        self._set_last[ls] = self.tick

    # ------------------------------------------------------------ eviction --
    def _p_reuse_eq2(self, page: PageId) -> float:
        """Eq. 2: superposed Poisson over the models sharing the page."""
        lam = sum(self._lambda.get(mid, 0.0)
                  for mid in self.meta[page].sharers)
        return 1.0 - math.exp(-lam * self.cfg.horizon_t)

    def _p_reuse_set(self, ls: Hashable) -> float:
        lam = self._set_lambda.get(ls, 0.0)
        return 1.0 - math.exp(-lam * self.cfg.horizon_t)

    def _cost(self, p_reuse: float) -> float:
        return self.cfg.c_w + p_reuse * self.cfg.c_r   # Eq. 1

    def _victim_in_set(self, pages, inner: str) -> PageId:
        # Recency order within the set, using resident OrderedDict order.
        ordered = [p for p in self.resident if p in pages]
        return ordered[-1] if inner == "mru" else ordered[0]

    def _pick_victim(self) -> PageId:
        pol = self.cfg.policy
        evictable = [p for p in self.resident if p not in self._pinned]
        if not evictable:
            raise RuntimeError("every resident page is pinned; "
                               "group exceeds usable capacity")
        if pol == "lru":
            return evictable[0]
        if pol == "mru":
            return evictable[-1]
        if pol == "lfu":
            return min(evictable, key=lambda p: (self.meta[p].freq,
                                                 self.meta[p].last_tick))
        inner = "mru" if pol.endswith("mru") else "lru"
        by_set: Dict[Hashable, Set[PageId]] = defaultdict(set)
        for p in evictable:
            by_set[self.meta[p].locality_set].add(p)
        best, best_cost = None, None
        for ls, pages in by_set.items():
            cand = self._victim_in_set(pages, inner)
            if pol.startswith("optimized"):
                pr = self._p_reuse_eq2(cand)     # Eq. 2 (shared-page aware)
            else:
                pr = self._p_reuse_set(ls)       # original locality-set
            cost = self._cost(pr)
            if best_cost is None or cost < best_cost:
                best, best_cost = cand, cost
        return best

    def _evict_one(self) -> None:
        victim = self._pick_victim()
        del self.resident[victim]
        self.evictions += 1
        if self.on_evict:
            self.on_evict(victim)

    # ----------------------------------------------------------- prefetch --
    def prefetch(self, model: ModelId, page: PageId) -> bool:
        """Speculatively bring ``page`` resident for ``model``.

        Prefetch-aware admission: unlike :meth:`access`, this records no
        hit/miss (those stats measure demand traffic only), does not
        advance the virtual clock, and does not bump the lambda_i
        estimates — a prefetch is the pool acting on its own prediction,
        not a model arrival.  When the pool is full, the page is admitted
        only if the policy's would-be victim has a *lower* Eq.-1 eviction
        cost than the prefetched page — prefetching must never displace a
        page the policy believes is hotter.

        Returns True iff the page was actually loaded (caller charges the
        storage fetch time); False if already resident or declined.
        """
        if page in self.resident:
            return False
        m = self._ensure_meta(model, page)
        while len(self.resident) >= self.cfg.capacity_pages:
            victim = self._pick_victim()
            if self._cost(self._p_reuse_eq2(victim)) \
                    >= self._cost(self._p_reuse_eq2(page)):
                self.prefetch_declined += 1
                return False
            self._evict_one()
        # Insert where the policy's victim selection looks FIRST (the MRU
        # end for *mru policies, the LRU end otherwise): a prefetched page
        # has not been *used* yet, so until a demand access promotes it,
        # it must stay the most evictable page — not the most protected.
        self.resident[page] = None
        self.resident.move_to_end(page,
                                  last=self.cfg.policy.endswith("mru"))
        m.last_tick = max(m.last_tick, 0)
        self.prefetches += 1
        try:
            self._note_load(page)
        except BaseException:
            self.resident.pop(page, None)
            raise
        return True


def run_trace(pool: BufferPool, trace) -> float:
    """Feed an iterable of (model, page) accesses; return hit ratio."""
    for model, page in trace:
        pool.access(model, page)
    return pool.hit_ratio
