"""L2 (p-stable) Locality Sensitive Hashing for tensor blocks (Sec. 4.2.2).

``h(x) = floor((a . x + b) / r)`` with ``a ~ N(0, 1)``, ``b ~ U[0, r)``
(Datar et al. 2004).  Signatures are split into *bands* of ``rows_per_band``
hashes; two signatures *match* when at least ``collision_threshold`` bands
are identical (the knob evaluated in paper Tab. 6).

The index is incremental (paper Fig. 3): groups of approximately-equal
blocks, each with a representative (the first-indexed block).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    num_bands: int = 16
    rows_per_band: int = 4
    r: float = 4.0                      # bucket width (absolute, in block-L2 units)
    collision_threshold: int = 12       # min matching bands for a match
    seed: int = 0

    @property
    def num_hashes(self) -> int:
        return self.num_bands * self.rows_per_band


class L2LSH:
    """Vectorized signature computation for flattened blocks."""

    def __init__(self, dim: int, cfg: LSHConfig):
        self.cfg = cfg
        self.dim = int(dim)
        rng = np.random.default_rng(cfg.seed)
        # Projections kept fp32: blocks may be bf16/fp16 on device.
        self.proj = rng.standard_normal((self.dim, cfg.num_hashes)).astype(np.float32)
        self.bias = (rng.random(cfg.num_hashes) * cfg.r).astype(np.float32)

    def signatures(self, blocks: np.ndarray) -> np.ndarray:
        """``blocks``: [n, *block_shape] -> int32 signatures [n, num_hashes]."""
        flat = np.asarray(blocks, dtype=np.float32).reshape(len(blocks), -1)
        if flat.shape[1] != self.dim:
            raise ValueError(f"block dim {flat.shape[1]} != LSH dim {self.dim}")
        h = np.floor((flat @ self.proj + self.bias) / self.cfg.r)
        return h.astype(np.int32)

    def band_keys(self, sig: np.ndarray) -> List[bytes]:
        """Signature [num_hashes] -> one hashable key per band."""
        b = self.cfg.num_bands
        rows = self.cfg.rows_per_band
        s = np.ascontiguousarray(sig.reshape(b, rows))
        return [s[i].tobytes() for i in range(b)]


def estimate_r(blocks: np.ndarray, quantile: float = 0.1,
               sample: int = 256, seed: int = 0) -> float:
    """Suggest a bucket width from data: the ``quantile`` of sampled
    pairwise block distances.  Blocks closer than ~r tend to collide on
    most bands; the paper tunes this trade-off via the collision
    threshold (Tab. 6), but r must sit near the intra-variant noise scale
    for the threshold knob to be meaningful."""
    flat = np.asarray(blocks, dtype=np.float32).reshape(len(blocks), -1)
    rng = np.random.default_rng(seed)
    n = len(flat)
    i = rng.integers(0, n, size=min(sample, n * n))
    j = rng.integers(0, n, size=len(i))
    keep = i != j
    if not keep.any():
        return 1.0
    d = np.linalg.norm(flat[i[keep]] - flat[j[keep]], axis=1)
    return float(max(np.quantile(d, quantile), 1e-6))


@dataclasses.dataclass
class Group:
    """A cluster of approximately-equal blocks."""

    gid: int
    rep_signature: np.ndarray           # signature of the representative
    members: List[Tuple[str, str, int]] = dataclasses.field(default_factory=list)
    # members: (model, tensor, block_id) refs — paper's (tensorID, blockID)


class LSHIndex:
    """Banded LSH index over block groups (incremental across models)."""

    def __init__(self, dim: int, cfg: Optional[LSHConfig] = None):
        self.cfg = cfg or LSHConfig()
        self.lsh = L2LSH(dim, self.cfg)
        self.groups: Dict[int, Group] = {}
        self._buckets: List[Dict[bytes, List[int]]] = [
            dict() for _ in range(self.cfg.num_bands)
        ]
        self._next_gid = 0

    def __len__(self) -> int:
        return len(self.groups)

    # -- queries ------------------------------------------------------------
    def query(self, sig: np.ndarray) -> Optional[int]:
        """Best-matching group id (>= collision_threshold bands) or None."""
        keys = self.lsh.band_keys(sig)
        cand: Counter = Counter()
        for band, key in enumerate(keys):
            for gid in self._buckets[band].get(key, ()):  # bucket collision
                cand[gid] += 1
        if not cand:
            return None
        gid, nbands = max(cand.items(), key=lambda kv: (kv[1], -kv[0]))
        if nbands >= self.cfg.collision_threshold:
            return gid
        return None

    # -- updates ------------------------------------------------------------
    def insert_group(self, sig: np.ndarray,
                     ref: Tuple[str, str, int]) -> int:
        gid = self._next_gid
        self._next_gid += 1
        self.groups[gid] = Group(gid, np.array(sig, copy=True), [ref])
        for band, key in enumerate(self.lsh.band_keys(sig)):
            self._buckets[band].setdefault(key, []).append(gid)
        return gid

    def add_member(self, gid: int, ref: Tuple[str, str, int]) -> None:
        self.groups[gid].members.append(ref)

    def remove_member(self, gid: int, ref: Tuple[str, str, int]) -> bool:
        """Remove a member ref.  Returns True if the group became empty and
        was dropped (paper Sec. 7.6.1 Approach-1)."""
        g = self.groups.get(gid)
        if g is None:
            return False
        try:
            g.members.remove(ref)
        except ValueError:
            pass
        if not g.members:
            for band, key in enumerate(self.lsh.band_keys(g.rep_signature)):
                bucket = self._buckets[band].get(key)
                if bucket and gid in bucket:
                    bucket.remove(gid)
            del self.groups[gid]
            return True
        return False

    def stats(self) -> Dict[str, float]:
        sizes = [len(g.members) for g in self.groups.values()]
        return {
            "num_groups": len(self.groups),
            "num_members": int(sum(sizes)),
            "max_group": int(max(sizes, default=0)),
        }
