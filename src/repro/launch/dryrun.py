import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init).  REPRO_DRYRUN_DEVICES overrides for mini CI runs.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh from ShapeDtypeStruct inputs only (no allocation), and
record memory_analysis / cost_analysis / collective schedule for the
roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --all --spawn          # every cell, isolated
  python -m repro.launch.dryrun --all --multi-pod      # 2x16x16 pass
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, get_config, list_archs, shape_supported
from ..distributed.sharding import (ShardingRecipe, cache_specs, make_recipe,
                                    param_specs, use_recipe)
from ..models import build, input_specs, param_shapes
from ..optim import make_optimizer
from ..roofline.analysis import collective_bytes_from_hlo, roofline_terms
from .mesh import make_mini_mesh, make_production_mesh, set_mesh_compat
from .steps import make_serve_step, make_train_step

DEFAULT_OUT = "experiments/dryrun"


# --------------------------------------------------------------- variants ---
# §Perf hillclimb variants: name -> fn(cfg, spec, recipe) -> (cfg, recipe).
def _baseline(cfg, spec, recipe):
    return cfg, recipe


def _no_seq_parallel(cfg, spec, recipe):
    """Prefill without sequence sharding (activations batch-sharded only)."""
    import dataclasses
    sites = {k: P(recipe.dp, None, None) for k in ("residual",)}
    sites["act_ff"] = P(recipe.dp, None, recipe.tp)
    sites["logits"] = P(recipe.dp, None, recipe.tp)
    sites["moe_disp"] = P(recipe.tp, None, None)
    return cfg, dataclasses.replace(recipe, seq=None, sites=sites)


def _no_remat(cfg, spec, recipe):
    import dataclasses
    return dataclasses.replace(cfg, remat=False), recipe


def _fp32_params(cfg, spec, recipe):
    import dataclasses
    return dataclasses.replace(cfg, dtype="float32"), recipe


DEDUP_NUM_VARIANTS = 6       # resident model variants (paper Tab. 1)
DEDUP_BLOCK = (256, 256)     # storage block (DESIGN.md §2)


def _pool_params(params_sds, cfg, ratio: float):
    """Replace every >=1 MiB 2-D-blockable weight with (pool, block_map):
    the pool holds the distinct blocks of DEDUP_NUM_VARIANTS variants at
    the given distinct fraction; the map belongs to the served variant.

    Returns (pooled ShapeDtypeStructs, unpool_fn).
    """
    import numpy as np
    from ..core.blocks import make_grid
    bh, bw = DEDUP_BLOCK

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_sds)
    pooled = {}
    plans = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        size = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        if len(leaf.shape) >= 2 and size >= (1 << 20):
            shape2d = (int(np.prod(leaf.shape[:-1])), int(leaf.shape[-1]))
            grid = make_grid(shape2d, (bh, bw))
            n_blocks = grid.num_blocks
            n_distinct = max(1, int(n_blocks * DEDUP_NUM_VARIANTS * ratio))
            n_distinct = -(-n_distinct // 512) * 512   # shardable on any mesh
            pooled[key + "#pool"] = jax.ShapeDtypeStruct(
                (n_distinct, bh, bw), leaf.dtype)
            pooled[key + "#map"] = jax.ShapeDtypeStruct(
                (n_blocks,), jnp.int32)
            plans[key] = (leaf.shape, shape2d, grid)
        else:
            pooled[key] = leaf

    def unpool(pooled_vals):
        out = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", p)) for p in path)
            if key in plans:
                shape, shape2d, grid = plans[key]
                pool = pooled_vals[key + "#pool"]
                bmap = pooled_vals[key + "#map"]
                blocks = jnp.take(pool, bmap, axis=0)
                gh, gw = grid.grid
                w = (blocks.reshape(gh, gw, bh, bw)
                           .transpose(0, 2, 1, 3)
                           .reshape(gh * bh, gw * bw))
                w = w[: shape2d[0], : shape2d[1]].reshape(shape)
                out.append(w)
            else:
                out.append(pooled_vals[key])
        return jax.tree_util.tree_unflatten(treedef, out)

    return pooled, unpool


def _unrolled(cfg, spec, recipe):
    """Accounting mode: unroll layer scans so cost_analysis counts every
    layer (XLA counts while-loop bodies once; see EXPERIMENTS.md §Dry-run
    methodology).  Semantically identical program, bigger HLO."""
    import dataclasses
    return dataclasses.replace(cfg, scan_unroll=True), recipe


def _nsp_unrolled(cfg, spec, recipe):
    cfg, recipe = _no_seq_parallel(cfg, spec, recipe)
    return _unrolled(cfg, spec, recipe)


def _train_sp_unrolled(cfg, spec, recipe):
    """Sequence-parallel training activations: the scan carry (the per-
    layer residual stream kept live by remat) shards over `model`,
    dividing the dominant activation temp by the TP width."""
    import dataclasses
    sites = {
        "residual": P(recipe.dp, recipe.tp, None),
        "act_ff":   P(recipe.dp, recipe.tp, None),
        "logits":   P(recipe.dp, recipe.tp, None),
        "moe_disp": P(recipe.tp, None, None),
    }
    recipe = dataclasses.replace(recipe, seq=recipe.tp, sites=sites)
    return dataclasses.replace(cfg, scan_unroll=True), recipe


VARIANTS = {
    "baseline": _baseline,
    "unrolled": _unrolled,
    "no_seq_parallel": _no_seq_parallel,
    "nsp_unrolled": _nsp_unrolled,
    "train_sp_unrolled": _train_sp_unrolled,
    "no_remat": _no_remat,
    "fp32_params": _fp32_params,
    # dedup_serving handled specially in lower_cell (wraps the step and
    # re-shapes the weight inputs into pool+map form); list for CLI.
    "dedup_serving": _unrolled,
    "dedup_serving_dense_ref": _unrolled,
    # sharded page-pool serving (serving/shard_pool.py at pod scale):
    # the block maps shard with the pool instead of replicating, so the
    # lowering also schedules the map-distribution collectives.
    "dedup_serving_sharded": _unrolled,
}


# ---------------------------------------------------------------- helpers ---
def _tree_bytes(tree) -> int:
    import math
    return sum(math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def _shard_sds(tree, spec_tree, mesh):
    from ..distributed.sharding import sanitize_spec

    def f(sds, spec):
        spec = sanitize_spec(spec, sds.shape, mesh)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(f, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_specs(batch_sds, recipe: ShardingRecipe, cfg) -> Dict:
    dp = recipe.dp

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        leaf = path.split("/")[-1]
        nd = len(tree.shape)
        if leaf in ("tokens", "labels"):
            if nd == 2 and tree.shape[1] > 1 and not cfg.encdec:
                return P(dp, recipe.seq)
            return P(dp, None)
        if leaf == "frames":
            return P(dp, recipe.seq, None)
        if leaf == "image_embeds":
            return P(dp, None, None)
        return P(*([None] * nd))

    out = {}
    for k, v in batch_sds.items():
        if k == "cache":
            out[k] = cache_specs(v, recipe)
        else:
            out[k] = walk(v, k)
    return out


def model_flops_estimate(cfg, spec) -> float:
    n_act = cfg.active_param_count()
    B, S = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        return 6.0 * n_act * B * S
    if spec.kind == "prefill":
        return 2.0 * n_act * B * S
    return 2.0 * n_act * B           # decode: one token per sequence


# ------------------------------------------------------------------- cell ---
def lower_cell(arch: str, shape: str, multi_pod: bool = False,
               variant: str = "baseline", mini: bool = False,
               keep_hlo: bool = False) -> Dict:
    cfg = get_config(arch)
    spec = SHAPES[shape]
    meta = {"arch": arch, "shape": shape, "kind": spec.kind,
            "multi_pod": multi_pod, "variant": variant,
            "params_total": cfg.param_count(),
            "params_active": cfg.active_param_count(),
            "model_flops": model_flops_estimate(cfg, spec)}
    ok, reason = shape_supported(cfg, shape)
    if not ok:
        return {"meta": meta, "status": "skipped", "reason": reason}

    mesh = (make_mini_mesh(multi_pod=multi_pod) if mini
            else make_production_mesh(multi_pod=multi_pod))
    meta["mesh"] = "x".join(str(s) for s in mesh.devices.shape)
    meta["devices"] = mesh.devices.size
    recipe = make_recipe(spec.kind, multi_pod)
    cfg, recipe = VARIANTS[variant](cfg, spec, recipe)

    api = build(cfg)
    record: Dict = {"meta": meta, "status": "ok"}
    t0 = time.perf_counter()
    with set_mesh_compat(mesh), use_recipe(recipe):
        params_sds = param_shapes(cfg, spec)
        pspecs = param_specs(params_sds, recipe)
        params_in = _shard_sds(params_sds, pspecs, mesh)
        meta["param_bytes_global"] = _tree_bytes(params_sds)

        batch_sds = input_specs(cfg, spec)
        bspecs = _batch_specs(batch_sds, recipe, cfg)
        batch_in = _shard_sds(batch_sds, bspecs, mesh)
        if "cache" in batch_sds:
            meta["cache_bytes_global"] = _tree_bytes(batch_sds["cache"])

        if spec.kind == "train":
            opt = make_optimizer(cfg.optimizer)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            ospecs = opt.state_specs(params_sds, pspecs)
            opt_in = _shard_sds(opt_sds, ospecs, mesh)
            meta["opt_bytes_global"] = _tree_bytes(opt_sds)
            step = make_train_step(api, opt)
            jfn = jax.jit(step, donate_argnums=(0, 1))
            lowered = jfn.lower(params_in, opt_in, batch_in)
        elif spec.kind == "prefill":
            def prefill_step(params, batch):
                return api.prefill(params, batch, None)
            jfn = jax.jit(prefill_step)
            lowered = jfn.lower(params_in, batch_in)
        elif variant.startswith("dedup_serving"):
            # The paper's technique as a pod-scale serving feature:
            # DEDUP_NUM_VARIANTS model variants resident as one distinct-
            # block pool + per-variant block maps.  "dedup_serving" uses
            # cfg.dedup_ratio (measured cross-variant distinct fraction);
            # "..._dense_ref" is the no-dedup reference (6 full copies).
            from ..distributed.sharding import param_spec
            ratio = 1.0 if variant == "dedup_serving_dense_ref" \
                else cfg.dedup_ratio
            pooled_sds, unpool = _pool_params(params_sds, cfg, ratio)
            axes = (("pod", "data", "model") if multi_pod
                    else ("data", "model"))
            # "_sharded": the remapped block maps partition with the pool
            # (serving/shard_pool.py's per-shard remaps at pod scale)
            # instead of replicating — the lowering then also schedules
            # the map-distribution collectives.
            map_spec = P(axes) if variant.endswith("_sharded") else P()
            pspecs2 = {}
            for k, s in pooled_sds.items():
                if k.endswith("#pool"):
                    pspecs2[k] = P(axes, None, None)
                elif k.endswith("#map"):
                    pspecs2[k] = map_spec
                else:
                    pspecs2[k] = param_spec(k, len(s.shape), recipe)
            params_in = _shard_sds(pooled_sds, pspecs2, mesh)
            meta["param_bytes_global"] = _tree_bytes(pooled_sds)
            meta["dedup_ratio"] = ratio
            meta["dedup_variants"] = DEDUP_NUM_VARIANTS

            def dedup_step(pooled, batch):
                params = unpool(pooled)
                return api.decode(params, batch["cache"], batch["tokens"])

            jfn = jax.jit(dedup_step, donate_argnums=(1,))
            lowered = jfn.lower(params_in, batch_in)
        else:
            step = make_serve_step(api)
            jfn = jax.jit(step, donate_argnums=(1,))
            lowered = jfn.lower(params_in, batch_in)
        record["lower_seconds"] = time.perf_counter() - t0

        t1 = time.perf_counter()
        compiled = lowered.compile()
        record["compile_seconds"] = time.perf_counter() - t1

    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in dir(mem)
            if k.endswith("_in_bytes") and not k.startswith("host_")}
    except Exception as e:                       # pragma: no cover
        record["memory_analysis"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):     # jax 0.4.x: [dict]
            cost = cost[0] if cost else {}
        record["cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if k in ("flops", "transcendentals", "bytes accessed")
            or k.startswith("bytes accessed")}
    except Exception as e:                       # pragma: no cover
        record["cost_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    record["collectives"] = collective_bytes_from_hlo(hlo)
    record["hlo_bytes"] = len(hlo)
    if keep_hlo:
        record["hlo_head"] = hlo[:20000]
    cost = record.get("cost_analysis", {})
    record["roofline"] = roofline_terms(
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(record["collectives"].get("weighted_total", 0.0)))
    # cost_analysis is the per-device SPMD program -> compare against the
    # per-device share of MODEL_FLOPS = 6·N·D (or 2·N·D for inference).
    record["roofline"]["useful_flops_ratio"] = (
        meta["model_flops"] / meta["devices"] / float(cost["flops"])
        if cost.get("flops") else None)
    return record


def cell_path(out_dir: str, arch: str, shape: str, multi_pod: bool,
              variant: str) -> str:
    mesh = "multi" if multi_pod else "single"
    v = "" if variant == "baseline" else f"__{variant}"
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}{v}.json")


def run_cell_and_save(arch, shape, multi_pod, variant, out_dir,
                      mini=False) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    path = cell_path(out_dir, arch, shape, multi_pod, variant)
    try:
        rec = lower_cell(arch, shape, multi_pod, variant, mini=mini)
    except Exception as e:
        rec = {"meta": {"arch": arch, "shape": shape,
                        "multi_pod": multi_pod, "variant": variant},
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--spawn", action="store_true",
                    help="one subprocess per cell (isolates XLA state)")
    ap.add_argument("--mini", action="store_true",
                    help="mini mesh (set REPRO_DRYRUN_DEVICES=8)")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape in (None, "all")) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    cells = [(a, s, mp) for a in archs for s in shapes for mp in meshes]
    for arch, shape, mp in cells:
        path = cell_path(args.out, arch, shape, mp, args.variant)
        if args.skip_existing and os.path.exists(path):
            print(f"[skip existing] {path}")
            continue
        label = f"{arch} x {shape} ({'multi' if mp else 'single'}-pod, " \
                f"{args.variant})"
        if args.spawn:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape,
                   "--variant", args.variant, "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            if args.mini:
                cmd.append("--mini")
            t0 = time.perf_counter()
            r = subprocess.run(cmd, capture_output=True, text=True)
            status = "ok" if r.returncode == 0 else "proc-error"
            if r.returncode != 0:
                with open(cell_path(args.out, arch, shape, mp,
                                    args.variant), "w") as f:
                    json.dump({"meta": {"arch": arch, "shape": shape,
                                        "multi_pod": mp},
                               "status": "error",
                               "error": r.stderr[-4000:]}, f, indent=1)
            print(f"[{status}] {label} ({time.perf_counter()-t0:.1f}s)")
        else:
            t0 = time.perf_counter()
            rec = run_cell_and_save(arch, shape, mp, args.variant, args.out,
                                    mini=args.mini)
            rl = rec.get("roofline", {})
            print(f"[{rec['status']}] {label} ({time.perf_counter()-t0:.1f}s) "
                  f"dominant={rl.get('dominant')} "
                  f"compute={rl.get('compute_s', 0):.2e}s "
                  f"memory={rl.get('memory_s', 0):.2e}s "
                  f"collective={rl.get('collective_s', 0):.2e}s "
                  + ("" if rec["status"] != "error"
                     else rec.get("error", "")[:200]))


if __name__ == "__main__":
    main()
