"""Training launcher: real steps on CPU (reduced configs) and the same
code path that the dry-run lowers at production scale.

Fault-tolerance features exercised here:
  * ``--resume auto``: restart from the newest complete checkpoint.
  * host-sharded deterministic data: (seed, step, host) -> batch, so
    elastic re-mesh (``--hosts`` change across restarts) replays cleanly.
  * ``--compress-grads``: int8 error-feedback gradient compression.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \\
      --reduced --steps 20 --batch 8 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_config, reduced
from ..data.pipeline import token_batches
from ..distributed.compression import (compress_with_feedback,
                                       init_error_state)
from ..models import build
from ..optim import cosine_schedule, make_optimizer
from .steps import make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", default=None, choices=[None, "auto"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--hosts", type=int, default=1)
    ap.add_argument("--host-index", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--overfit", action="store_true",
                    help="repeat the step-0 batch (optimizer smoke test: "
                         "uniform-random streams are at the entropy floor)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    api = build(cfg)
    opt = make_optimizer(cfg.optimizer, lr=args.lr,
                         schedule=cosine_schedule(args.lr, warmup=5,
                                                  total=args.steps))

    params = api.init(jax.random.PRNGKey(args.seed), args.seq * 2)
    opt_state = opt.init(params)
    err_state = init_error_state(params) if args.compress_grads else None

    if args.compress_grads:
        def step_fn(params, opt_state, err, batch):
            def loss_fn(p):
                return api.loss(p, batch)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            grads, err = compress_with_feedback(grads, err)
            params, opt_state, gnorm = opt.update(grads, opt_state, params)
            return params, opt_state, err, {"loss": loss,
                                            "grad_norm": gnorm}
        jstep = jax.jit(step_fn, donate_argnums=(0, 1, 2))
    else:
        base = make_train_step(api, opt)
        jstep = jax.jit(base, donate_argnums=(0, 1))

    start = 0
    mgr = CheckpointManager(args.ckpt) if args.ckpt else None
    if mgr and args.resume == "auto":
        got = mgr.restore_latest(params, opt_state)
        if got:
            start, params, opt_state, manifest = got
            print(f"[resume] restored step {start} from {args.ckpt}")

    data = token_batches(cfg.vocab, args.batch, args.seq, seed=args.seed,
                         host_index=args.host_index, host_count=args.hosts)
    # Fast-forward the deterministic stream to the resume point.
    for _ in range(start):
        next(data)

    losses = []
    t0 = time.perf_counter()
    fixed = {k: jnp.asarray(v) for k, v in next(data).items()} \
        if args.overfit else None
    for step in range(start, args.steps):
        batch = fixed if args.overfit \
            else {k: jnp.asarray(v) for k, v in next(data).items()}
        if args.compress_grads:
            params, opt_state, err_state, metrics = jstep(
                params, opt_state, err_state, batch)
        else:
            params, opt_state, metrics = jstep(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.perf_counter()-t0:.1f}s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, params, opt_state,
                     extra={"arch": cfg.name, "loss": loss})
    if mgr:
        mgr.save(args.steps, params, opt_state,
                 extra={"arch": cfg.name, "loss": losses[-1]})
    return {"losses": losses, "params": params}


if __name__ == "__main__":
    main()
