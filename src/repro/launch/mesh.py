"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).

Version compat: ``jax.sharding.AxisType`` and ``jax.make_mesh``'s
``axis_types=`` kwarg only exist on newer jax; on 0.4.x we fall back to a
plain mesh (all axes behave as the old default, which is what Auto means).
"""
from __future__ import annotations

import contextlib

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if _AXIS_TYPE is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(_AXIS_TYPE.Auto,) * len(axes))
        except TypeError:        # jax with AxisType but older make_mesh
            pass
    return jax.make_mesh(shape, axes)


def set_mesh_compat(mesh):
    """``jax.set_mesh`` context where available; on older jax the Mesh
    object itself is the context manager that activates it."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        ctx = set_mesh(mesh)
        # jax.set_mesh is a context manager in recent releases; guard in
        # case a version makes it a plain setter returning None.
        return ctx if hasattr(ctx, "__enter__") else contextlib.nullcontext()
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mini_mesh(*, multi_pod: bool = False, devices_per_axis: int = 2):
    """Reduced mesh for CI-scale dry-run tests (8 host devices)."""
    d = devices_per_axis
    shape = (2, d, d) if multi_pod else (d, d)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)
