"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except TypeError:            # older jax without axis_types kwarg
        return jax.make_mesh(shape, axes)


def make_mini_mesh(*, multi_pod: bool = False, devices_per_axis: int = 2):
    """Reduced mesh for CI-scale dry-run tests (8 host devices)."""
    d = devices_per_axis
    shape = (2, d, d) if multi_pod else (d, d)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except TypeError:
        return jax.make_mesh(shape, axes)
