"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).

Version compat: ``jax.sharding.AxisType`` and ``jax.make_mesh``'s
``axis_types=`` kwarg only exist on newer jax; on 0.4.x we fall back to a
plain mesh (all axes behave as the old default, which is what Auto means).
"""
from __future__ import annotations

import contextlib

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if _AXIS_TYPE is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(_AXIS_TYPE.Auto,) * len(axes))
        except TypeError:        # jax with AxisType but older make_mesh
            pass
    return jax.make_mesh(shape, axes)


def set_mesh_compat(mesh):
    """``jax.set_mesh`` context where available; on older jax the Mesh
    object itself is the context manager that activates it."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        ctx = set_mesh(mesh)
        # jax.set_mesh is a context manager in recent releases; guard in
        # case a version makes it a plain setter returning None.
        return ctx if hasattr(ctx, "__enter__") else contextlib.nullcontext()
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mini_mesh(*, multi_pod: bool = False, devices_per_axis: int = 2):
    """Reduced mesh for CI-scale dry-run tests (8 host devices)."""
    d = devices_per_axis
    shape = (2, d, d) if multi_pod else (d, d)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


# ------------------------------------------------------------ serving mesh --
def shard_devices(num_shards: int):
    """Device assignment for a sharded page pool: shard i's slab lives on
    local device i.  With fewer devices than shards (one CPU, mini TPU
    slices) devices are reused round-robin — the placement/routing logic
    is identical, only the physical spread shrinks."""
    local = jax.local_devices()
    return [local[i % len(local)] for i in range(int(num_shards))]


def make_shard_mesh(num_shards: int):
    """1-D ``("shard",)`` mesh for sharded page-pool serving.  The axis
    is clamped to the local device count (a 4-shard pool on one CPU is a
    1-device mesh with all four slabs co-located); the per-shard
    DevicePagePools still pin to :func:`shard_devices`, so on a real
    slice each shard's slab lands on its own chip."""
    n = min(int(num_shards), len(jax.local_devices()))
    return make_mesh_compat((max(1, n),), ("shard",))
