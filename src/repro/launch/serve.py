"""Serving launcher: the paper's multi-model word2vec scenario end to end.

Builds N fine-tuned embedding variants, registers them in the dedup
ModelStore (Alg. 1 -> two-stage packing), then serves mixed-model request
traffic through the Eq.-2 buffer pool, reporting storage reduction, cache
hit ratio, and latency — the same quantities as paper Figs. 8/9 + Tab. 1.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --models 6 --batches 60
"""
from __future__ import annotations

import argparse

import numpy as np

from ..core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from ..core.lsh import estimate_r
from ..data.pipeline import SyntheticTextTask
from ..serving.engine import (EmbeddingServingEngine, ServeStats,
                              StorageModel, WeightServer)
from ..serving.prefetch import Prefetcher
from ..serving.scheduler import SCHEDULERS


def build_store(task: SyntheticTextTask, num_models: int,
                block_shape=(64, 64), blocks_per_page: int = 8,
                pack_strategy: str = "two_stage"):
    from ..core.blocks import block_tensor
    base_blocks, _ = block_tensor(task.base_embed, block_shape)
    r = estimate_r(base_blocks, quantile=0.5)
    cfg = StoreConfig(
        dedup=DedupConfig(
            block_shape=block_shape,
            lsh=LSHConfig(num_bands=16, rows_per_band=4, r=r,
                          collision_threshold=8),
            validate=False),
        blocks_per_page=blocks_per_page,
        pack_strategy=pack_strategy)
    store = ModelStore(cfg)
    heads = {}
    for v in range(num_models):
        name = f"word2vec-v{v}"
        emb = task.variant_embedding(v)
        store.register(name, {"embedding": emb})
        heads[name] = task.train_head(emb, variant=v)
    return store, heads


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", type=int, default=6)
    ap.add_argument("--batches", type=int, default=60)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--capacity-pages", type=int, default=24)
    ap.add_argument("--policy", default="optimized_mru")
    ap.add_argument("--storage", default="ssd",
                    choices=list(("ssd", "hdd", "nvme", "dram")))
    ap.add_argument("--scheduler", default="round_robin",
                    choices=sorted(SCHEDULERS))
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "device"),
                    help="numpy: host materialization (policy simulator); "
                         "device: serve through the HBM page slab via the "
                         "Pallas dedup kernels (DESIGN.md §3)")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffer grouped fetches against compute")
    ap.add_argument("--prefetch", action="store_true",
                    help="lambda-driven page prefetching (implies --overlap:"
                         " speculation only pays off hidden under compute)")
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.prefetch:
        args.overlap = True

    task = SyntheticTextTask(vocab=args.vocab, seed=args.seed)
    store, heads = build_store(task, args.models)
    dedup_bytes = store.storage_bytes()
    dense_bytes = store.dense_bytes()
    print(f"[store] models={args.models} pages={store.num_pages()} "
          f"dense={dense_bytes/2**20:.1f}MiB dedup={dedup_bytes/2**20:.1f}MiB "
          f"reduction={dense_bytes/max(1, dedup_bytes):.2f}x")

    server = WeightServer(store, args.capacity_pages, args.policy,
                          StorageModel(args.storage), backend=args.backend)
    engine = EmbeddingServingEngine(
        server, heads, scheduler=args.scheduler,
        prefetcher=Prefetcher(server) if args.prefetch else None,
        overlap=args.overlap)
    rng = np.random.default_rng(args.seed + 9)
    correct = total = 0
    for b in range(args.batches):
        v = int(rng.integers(0, args.models))
        name = f"word2vec-v{v}"
        docs, labels = task.sample(args.batch_size, variant=v,
                                   seed=args.seed + 100 + b)
        engine.submit(name, docs)
    stats: ServeStats = engine.run()
    if args.backend == "device":
        print(f"[device] slab={server.device_pool.capacity} pages "
              f"loads={server.device_pool.loads} "
              f"evicts={server.device_pool.evicts} "
              f"device_batches={stats.device_batches} "
              f"dense_fallbacks={stats.dense_fallbacks}")
    print(f"[serve] batches={stats.batches} requests={stats.requests} "
          f"scheduler={args.scheduler} overlap={args.overlap} "
          f"backend={args.backend} "
          f"hit_ratio={server.pool.hit_ratio:.3f} "
          f"fetch={stats.fetch_seconds*1e3:.1f}ms "
          f"prefetch={stats.prefetch_seconds*1e3:.1f}ms "
          f"compute={stats.compute_seconds*1e3:.1f}ms "
          f"makespan={stats.makespan_seconds*1e3:.1f}ms "
          f"p50={stats.percentile(50)*1e3:.2f}ms "
          f"p99={stats.percentile(99)*1e3:.2f}ms")
    return stats, server


if __name__ == "__main__":
    main()
