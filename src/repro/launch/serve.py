"""Serving launcher: the paper's multi-model scenarios end to end.

Builds N fine-tuned variants, registers them in the dedup ModelStore
(Alg. 1 -> two-stage packing), then serves mixed-model request traffic
through the Eq.-2 buffer pool, reporting storage reduction, cache hit
ratio, and latency — the same quantities as paper Figs. 8/9 + Tab. 1.

With ``--store-url`` the store is committed to a pluggable storage
backend (``file://`` dir, ``sqlite://`` database — the paper's native
habitat — or ``objsim://`` simulated object store) and served back
*live* through ``repro.db.DedupDB``: pages fault in grouped from the
backend, and miss costs are charged from a ``microbench()``-calibrated
StorageModel instead of the ``--storage`` preset.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --models 6 --batches 60
  PYTHONPATH=src python -m repro.launch.serve --store-url sqlite:////tmp/m.db
  PYTHONPATH=src python -m repro.launch.serve --engine lm --store-url \
      sqlite:////tmp/lm.db --batches 4
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses

import numpy as np

from ..core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from ..core.lsh import estimate_r
from ..data.pipeline import SyntheticTextTask
from ..serving.engine import (EmbeddingServingEngine, ServeStats,
                              StorageModel, WeightServer)
from ..serving.frontend import ServingFrontend
from ..serving.prefetch import Prefetcher
from ..serving.scheduler import SCHEDULERS
from ..serving.traffic import OpenLoopTraffic, TrafficSpec


def build_store(task: SyntheticTextTask, num_models: int,
                block_shape=(64, 64), blocks_per_page: int = 8,
                pack_strategy: str = "two_stage"):
    from ..core.blocks import block_tensor
    base_blocks, _ = block_tensor(task.base_embed, block_shape)
    r = estimate_r(base_blocks, quantile=0.5)
    cfg = StoreConfig(
        dedup=DedupConfig(
            block_shape=block_shape,
            lsh=LSHConfig(num_bands=16, rows_per_band=4, r=r,
                          collision_threshold=8),
            validate=False),
        blocks_per_page=blocks_per_page,
        pack_strategy=pack_strategy)
    store = ModelStore(cfg)
    heads = {}
    for v in range(num_models):
        name = f"word2vec-v{v}"
        emb = task.variant_embedding(v)
        store.register(name, {"embedding": emb})
        heads[name] = task.train_head(emb, variant=v)
    return store, heads


# Audit map: every ServeStats field -> (report tag, key on that line).
# tests/test_obs.py pins this map against dataclasses.fields(ServeStats),
# so growing a counter without deciding its report line fails CI, and no
# field is ever printed from two lines at once.
REPORT_FIELDS = {
    "requests": ("serve", "requests="),
    "batches": ("serve", "batches="),
    "fetch_seconds": ("serve", "fetch="),
    "compute_seconds": ("serve", "compute="),
    "prefetch_seconds": ("serve", "prefetch="),
    "pages_fetched": ("serve", "pages="),
    "timeline_seconds": ("serve", "makespan="),
    "overlapped": ("serve", "overlap="),
    "latencies": ("serve", "p50=/p99="),
    "fetch_latencies": ("serve", "fetch_p99="),
    "device_batches": ("device", "device_batches="),
    "dense_fallbacks": ("device", "dense_fallbacks="),
    "transfer_seconds": ("transfer", "moved="),
    "transfer_pages": ("transfer", "pages="),
    "transfer_groups": ("transfer", "ops="),
    "transfer_bytes": ("transfer", "bytes="),
    "transfer_overlapped_bytes": ("transfer", "overlap="),
    "group_sizes": ("transfer", "mean_group="),
    "prefetch_pages": ("prefetch", "pages="),
    "borrow_pages": ("shards", "borrows="),
    "borrow_seconds": ("shards", "borrow="),
    "borrow_mirror_hits": ("shards", "mirror="),
    "borrow_store_faults": ("shards", "owner_faults="),
    "borrow_coalesced": ("shards", "coalesced="),
    "shard_batches": ("shards", "batches_per_shard="),
    "retries": ("faults", "retries="),
    "corrupt_detected": ("faults", "corrupt="),
    "refetch_pages": ("faults", "refetch="),
    "failovers": ("faults", "failovers="),
    "degraded_batches": ("faults", "degraded="),
    "fault_backoff_seconds": ("faults", "backoff="),
    "offered_requests": ("traffic", "offered="),
    "shed_requests": ("traffic", "shed="),
    "slo_misses": ("traffic", "slo_miss="),
    "queue_latencies": ("traffic", "queue_p50="),
    "service_latencies": ("traffic", "service_p50="),
    "request_latencies": ("traffic", "served=/p50=/p99="),
    "readmitted_requests": ("traffic", "readmitted="),
}


def _print_stats(args, stats: ServeStats, server: WeightServer,
                 engine=None) -> None:
    if args.backend == "device":
        print(f"[device] slab={server.device_pool.capacity} pages "
              f"loads={server.device_pool.loads} "
              f"evicts={server.device_pool.evicts} "
              f"device_batches={stats.device_batches} "
              f"dense_fallbacks={stats.dense_fallbacks}")
        hbm = server._hbm()
        print(f"[transfer] mode={args.transfer} "
              f"pages={stats.transfer_pages} ops={stats.transfer_groups} "
              f"mean_group={stats.mean_group_size:.1f} "
              f"bytes={stats.transfer_bytes} "
              f"moved={stats.transfer_seconds*1e3:.2f}ms "
              f"overlap={stats.overlap_fraction:.2f} "
              f"hbm_bw={hbm.bw/1e6:.0f}MB/s hbm_seek={hbm.seek*1e6:.0f}us")
    pf = getattr(engine, "prefetcher", None)
    if pf is not None:
        print(f"[prefetch] pages={stats.prefetch_pages} "
              f"time={pf.stats.seconds*1e3:.2f}ms "
              f"issued={pf.stats.issued} declined={pf.stats.declined} "
              f"lookahead_issued={pf.stats.lookahead_issued} "
              f"lookahead_hits={pf.stats.lookahead_hits}")
    if getattr(args, "shards", 1) > 1:
        s = server.stats                 # borrow/routing live on the server
        print(f"[shards] n={args.shards} placement={args.placement} "
              f"batches_per_shard={dict(sorted(s.shard_batches.items()))} "
              f"borrows={s.borrow_pages} "
              f"(mirror={s.borrow_mirror_hits} "
              f"owner_faults={s.borrow_store_faults} "
              f"coalesced={s.borrow_coalesced}) "
              f"rebalanced={server.router.rebalanced} "
              f"borrow={s.borrow_seconds*1e3:.2f}ms")
    if getattr(args, "faults", None):
        # recovery counters accumulate on the server's stats (where the
        # access-path accounting lives); degradation is an engine event
        fs = server.stats
        print(f"[faults] retries={fs.retries} "
              f"corrupt={fs.corrupt_detected} "
              f"refetch={fs.refetch_pages} "
              f"failovers={fs.failovers} "
              f"degraded={stats.degraded_batches} "
              f"backoff={fs.fault_backoff_seconds*1e3:.2f}ms")
    # percentile() raises on an empty run (a silent 0.0 would read as an
    # impossibly fast tail); an empty run prints n/a instead
    lat = (f"p50={stats.percentile(50)*1e3:.2f}ms "
           f"p99={stats.percentile(99)*1e3:.2f}ms") if stats.latencies \
        else "p50=n/a p99=n/a"
    fl = stats.fetch_latencies
    fetch_p99 = (f"fetch_p99="
                 f"{float(np.percentile(fl, 99))*1e3:.2f}ms") if fl \
        else "fetch_p99=n/a"
    # overlap= reports what the engine DID (stats.overlapped), not what
    # the CLI asked for — the two differ when a flag implies overlap
    print(f"[serve] batches={stats.batches} requests={stats.requests} "
          f"scheduler={args.scheduler} overlap={stats.overlapped} "
          f"backend={args.backend} "
          f"hit_ratio={server.pool.hit_ratio:.3f} "
          f"pages={stats.pages_fetched} "
          f"fetch={stats.fetch_seconds*1e3:.1f}ms " + fetch_p99 +
          f" prefetch={stats.prefetch_seconds*1e3:.1f}ms "
          f"compute={stats.compute_seconds*1e3:.1f}ms "
          f"makespan={stats.makespan_seconds*1e3:.1f}ms " + lat)


def _print_traffic(spec: TrafficSpec, fe: ServingFrontend,
                   stats: ServeStats) -> None:
    """The ``[traffic]`` report line: request-level latency/goodput for
    an open-loop run (virtual-clock quantities throughout)."""
    served = len(stats.request_latencies)
    lat = (f"p50={stats.request_percentile(50)*1e3:.2f}ms "
           f"p99={stats.request_percentile(99)*1e3:.2f}ms") if served \
        else "p50=n/a p99=n/a"
    if served:
        q50 = float(np.percentile(stats.queue_latencies, 50)) * 1e3
        s50 = float(np.percentile(stats.service_latencies, 50)) * 1e3
        qs = f"queue_p50={q50:.2f}ms service_p50={s50:.2f}ms "
    else:
        qs = "queue_p50=n/a service_p50=n/a "
    print(f"[traffic] policy={fe.policy} rate={spec.rate:g}/s "
          f"zipf={spec.zipf:g} slo={spec.slo_ms:g}ms seed={spec.seed} "
          f"offered={stats.offered_requests} served={served} "
          f"shed={stats.shed_requests} slo_miss={stats.slo_misses} "
          f"readmitted={stats.readmitted_requests} "
          f"goodput={stats.goodput:.3f} " + qs + lat +
          f" clock={fe.clock.now*1e3:.1f}ms "
          f"idle={fe.clock.spent('idle')*1e3:.1f}ms")


def _make_tracer(args, clock=None):
    """(tracer, activation-CM) for --trace; (None, no-op CM) otherwise.
    Binding the frontend's virtual clock lets the exporter carry the
    per-channel conservation proof in ``otherData``."""
    if not getattr(args, "trace", None):
        return None, contextlib.nullcontext()
    from ..obs import Tracer, use_tracer
    tr = Tracer(clock=clock)
    return tr, use_tracer(tr)


def _run_traffic(args, engine, gen: OpenLoopTraffic, spec: TrafficSpec):
    """One open-loop traffic run through the ServingFrontend, honouring
    the warm-restart flags (DESIGN.md §11).

    With ``--snapshot PATH`` the frontend persists its clock / ledger /
    queues around every dispatch; if PATH already exists the run RESUMES
    from it — the seeded generator reproduces the same request stream,
    the ledger keeps served ids served (at-most-once), and queued plus
    in-flight ids are re-admitted for deterministic recompute.
    ``--kill-after N`` stops after N dispatched batches so a follow-up
    invocation of the same command exercises the resume path.

    Returns ``(fe, stats, tracer, clock)``; ``clock`` is ``None`` on a
    resumed run because the restored ledger carries pre-crash channel
    time no span of this process witnessed, so the tracer's exact
    clock-conservation cross-check cannot apply.
    """
    import json
    import os
    snap_path = getattr(args, "snapshot", None)
    reqs = gen.generate(spec.requests)
    resumed = False
    if snap_path and os.path.exists(snap_path):
        with open(snap_path) as f:
            snap = json.load(f)
        fe = ServingFrontend.restore(engine, snap, reqs,
                                     snapshot_path=snap_path)
        resumed = True
        print(f"[restart] resumed from {snap_path}: "
              f"readmitted={fe.ledger.readmitted} "
              f"served_before={len(fe.ledger.served)} "
              f"clock={fe.clock.now*1e3:.1f}ms")
    else:
        fe = ServingFrontend(engine, max_batch=spec.max_batch,
                             snapshot_path=snap_path)
    clock = None if resumed else fe.clock
    tracer, activate = _make_tracer(args, clock)
    with activate:
        stats: ServeStats = fe.run(reqs,
                                   max_dispatches=args.kill_after)
    if args.kill_after is not None and fe.pending_requests():
        print(f"[restart] stopped after {args.kill_after} dispatches: "
              f"pending={fe.pending_requests()} snapshot -> {snap_path}; "
              f"rerun the same command to resume")
    _print_traffic(spec, fe, stats)
    return fe, stats, tracer, clock


def _build_registry(stats: ServeStats, server, engine, clock):
    """One MetricsRegistry over every live stats surface of this run:
    engine counters (``serve.``), the server's access-path counters
    (``server.`` — a distinct ServeStats when the engine wraps a
    WeightServer), recovery, prefetch, and the virtual clock."""
    from ..obs import MetricsRegistry
    reg = MetricsRegistry()
    stats.register_into(reg, namespace="serve")
    srv_stats = getattr(server, "stats", None)
    if srv_stats is not None and srv_stats is not stats:
        srv_stats.register_into(reg, namespace="server")
    fault_stats = getattr(getattr(server, "store", None),
                          "fault_stats", None)
    if fault_stats is not None:
        fault_stats.register_into(reg, namespace="recovery")
    pf = getattr(engine, "prefetcher", None)
    if pf is not None:
        reg.register_object(
            "prefetch", pf.stats,
            [f.name for f in dataclasses.fields(pf.stats)])
    if clock is not None:
        reg.gauge("clock.now", lambda c=clock: c.now)
        reg.gauge("clock.channels", lambda c=clock: dict(c.channels))
    return reg


def _export_obs(args, tracer, stats: ServeStats, server, engine,
                clock=None) -> None:
    """--trace / --report-json outputs, after the run completed."""
    if tracer is not None:
        from ..obs import write_trace
        if clock is not None:
            tracer.assert_matches_clock(clock)   # conservation proof
        write_trace(args.trace, tracer, clock=clock)
        print(f"[trace] spans={len(tracer.spans())} "
              f"dropped={tracer.dropped} -> {args.trace}")
    if getattr(args, "report_json", None):
        import json
        reg = _build_registry(stats, server, engine, clock)
        snap = reg.snapshot()
        with open(args.report_json, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[report-json] metrics={len(snap)} -> {args.report_json}")


def _open_db(args, store: ModelStore):
    """Commit the freshly built store to --store-url and reopen it live:
    serving then faults pages from the backend with miss costs charged
    from the backend's own microbenchmark calibration."""
    from ..db import DedupDB
    from ..storage import open_backend
    from ..storage.faults import FaultInjectingBackend, FaultSpec
    # resolve the URL ONCE: a memory-backed objsim:// URL names a fresh
    # store per open_backend() call, so save and reopen must share it
    backend = open_backend(args.store_url)
    if getattr(args, "faults", None):
        backend = FaultInjectingBackend(backend,
                                        FaultSpec.parse(args.faults))
        print(f"[faults] injecting: {backend.spec}")
    store.save(backend)
    db = DedupDB.open(backend)
    storage = db.storage_model()
    print(f"[store-url] {args.store_url} models={len(db.models())} "
          f"pages={db.store.num_pages()} "
          f"calibrated bw={storage.bw/1e6:.0f}MB/s "
          f"seek={storage.seek*1e6:.0f}us")
    return db, storage


def _make_server(args, store: ModelStore, capacity_pages: int,
                 storage: StorageModel = None) -> WeightServer:
    """A (possibly sharded) weight server per the CLI flags.  --shards
    N>1 partitions the page pool across N per-shard slabs with the
    selected placement policy; capacity is then PER SHARD (one
    accelerator's slab)."""
    storage = storage or StorageModel(args.storage)
    if args.shards > 1:
        if args.backend != "device":
            raise SystemExit("--shards > 1 requires --backend device "
                             "(the numpy path has no slabs to partition)")
        from ..serving.shard_pool import ShardedWeightServer
        from .mesh import shard_devices
        return ShardedWeightServer(store, capacity_pages, args.policy,
                                   storage, shards=args.shards,
                                   placement=args.placement,
                                   devices=shard_devices(args.shards),
                                   transfer=args.transfer)
    return WeightServer(store, capacity_pages, args.policy, storage,
                        backend=args.backend, transfer=args.transfer)


def serve_embedding(args) -> tuple:
    task = SyntheticTextTask(vocab=args.vocab, seed=args.seed)
    store, heads = build_store(task, args.models)
    dedup_bytes = store.storage_bytes()
    dense_bytes = store.dense_bytes()
    print(f"[store] models={args.models} pages={store.num_pages()} "
          f"dense={dense_bytes/2**20:.1f}MiB dedup={dedup_bytes/2**20:.1f}MiB "
          f"reduction={dense_bytes/max(1, dedup_bytes):.2f}x")

    if args.store_url:
        db, storage = _open_db(args, store)
        engine = db.serve_embedding(
            heads, capacity_pages=args.capacity_pages, policy=args.policy,
            scheduler=args.scheduler, overlap=args.overlap,
            prefetch=args.prefetch, compute_backend=args.backend,
            shards=args.shards, placement=args.placement,
            transfer=args.transfer)
        server = engine.server
    else:
        server = _make_server(args, store, args.capacity_pages)
        engine = EmbeddingServingEngine(
            server, heads, scheduler=args.scheduler,
            prefetcher=Prefetcher(server) if args.prefetch else None,
            overlap=args.overlap)
    if args.traffic:
        spec = TrafficSpec.parse(args.traffic)
        docs_per_req = max(1, args.batch_size // spec.max_batch)
        names = [f"word2vec-v{v}" for v in range(args.models)]

        def _payload(model, rid, rng):
            v = int(model.rsplit("-v", 1)[1])
            docs, _ = task.sample(docs_per_req, variant=v,
                                  seed=args.seed + 100 + rid)
            return docs

        gen = OpenLoopTraffic(names, rate=spec.rate, zipf_alpha=spec.zipf,
                              slo_s=spec.slo_ms * 1e-3, seed=spec.seed,
                              payload_fn=_payload)
        fe, stats, tracer, clock = _run_traffic(args, engine, gen, spec)
    else:
        rng = np.random.default_rng(args.seed + 9)
        for b in range(args.batches):
            v = int(rng.integers(0, args.models))
            name = f"word2vec-v{v}"
            docs, labels = task.sample(args.batch_size, variant=v,
                                       seed=args.seed + 100 + b)
            engine.submit(name, docs)
        clock = None
        tracer, activate = _make_tracer(args)
        with activate:
            stats = engine.run()
    _print_stats(args, stats, server, engine)
    _export_obs(args, tracer, stats, server, engine, clock)
    return stats, server


def serve_lm(args) -> tuple:
    """Reduced-LM variants served with prefill/decode; weights fault in
    through the dedup page pool (and, with --store-url, the backend) at
    every model switch."""
    import jax

    from ..configs import get_config, reduced
    from ..models import build
    from ..serving.engine import LMServingEngine

    cfg = reduced(get_config("deepseek-7b"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(args.seed), 64)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    def key_of(path):
        return "/".join(str(getattr(p, "key", p)) for p in path)

    tensors = {key_of(p): np.asarray(l, np.float32).reshape(l.shape[0], -1)
               if l.ndim > 2 else np.asarray(l, np.float32)
               for p, l in flat}
    shapes = {key_of(p): l.shape for p, l in flat}
    dtypes = {key_of(p): l.dtype for p, l in flat}

    def rebuild(ts):
        import jax.numpy as jnp
        leaves = [jnp.asarray(np.asarray(ts[key_of(p)])
                              .reshape(shapes[key_of(p)]),
                              dtypes[key_of(p)]) for p, _ in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    num_models = max(2, min(args.models, 3))
    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(32, 32),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=4.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=8))
    rng = np.random.default_rng(args.seed)
    names = []
    for v in range(num_models):
        name = f"lm-v{v}"
        names.append(name)
        delta = 0.0 if v == 0 else 1e-5 * v
        store.register(name, {k: t + delta for k, t in tensors.items()})
    print(f"[store] lm models={num_models} pages={store.num_pages()} "
          f"reduction={store.dense_bytes()/max(1, store.storage_bytes()):.2f}x")

    apis = {name: api for name in names}
    templates = {name: {"rebuild": rebuild} for name in names}
    cap = args.capacity_pages or max(2, store.num_pages() // 2)
    if args.store_url:
        db, storage = _open_db(args, store)
        engine = db.serve_lm(apis, templates, capacity_pages=cap,
                             policy=args.policy, scheduler=args.scheduler,
                             overlap=args.overlap, prefetch=args.prefetch,
                             compute_backend=args.backend,
                             shards=args.shards, placement=args.placement,
                             transfer=args.transfer)
        server = engine.server
    else:
        server = _make_server(args, store, cap)
        engine = LMServingEngine(server, apis, templates,
                                 scheduler=args.scheduler,
                                 overlap=args.overlap)
    if args.traffic:
        spec = TrafficSpec.parse(args.traffic)

        def _payload(model, rid, prng):
            prompts = prng.integers(1, 64, size=(1, 8)).astype(np.int32)
            return prompts, args.lm_steps

        gen = OpenLoopTraffic(names, rate=spec.rate, zipf_alpha=spec.zipf,
                              slo_s=spec.slo_ms * 1e-3, seed=spec.seed,
                              payload_fn=_payload)
        fe, stats, tracer, clock = _run_traffic(args, engine, gen, spec)
    else:
        for b in range(args.batches):
            name = names[int(rng.integers(0, num_models))]
            prompts = rng.integers(1, 64, size=(2, 8)).astype(np.int32)
            engine.submit(name, prompts, steps=args.lm_steps)
        clock = None
        tracer, activate = _make_tracer(args)
        with activate:
            stats = engine.run()
    _print_stats(args, stats, server, engine)
    _export_obs(args, tracer, stats, server, engine, clock)
    return stats, server


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="embedding",
                    choices=("embedding", "lm"),
                    help="embedding: the word2vec multi-model scenario; "
                         "lm: reduced-LM variants with prefill/decode")
    ap.add_argument("--models", type=int, default=6)
    ap.add_argument("--batches", type=int, default=60)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--capacity-pages", type=int, default=24)
    ap.add_argument("--policy", default="optimized_mru")
    ap.add_argument("--storage", default="ssd",
                    choices=list(("ssd", "hdd", "nvme", "dram")))
    ap.add_argument("--store-url", default=None,
                    help="storage backend URL (file:// | sqlite:// | "
                         "objsim://): commit the store there, reopen it "
                         "live, and serve with a microbench-calibrated "
                         "StorageModel instead of the --storage preset")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="chaos mode (requires --store-url): wrap the "
                         "backend in a FaultInjectingBackend with this "
                         "seeded spec, e.g. "
                         "'transient=0.05,corrupt=0.02,seed=7' — the "
                         "recovery layer retries/verifies/re-fetches and "
                         "serving stays bit-exact (DESIGN.md §8)")
    ap.add_argument("--traffic", default=None, metavar="SPEC",
                    help="open-loop request traffic instead of pre-built "
                         "batches: 'rate=200,zipf=1.1,slo_ms=50,seed=0,"
                         "requests=200,max_batch=8' — Poisson arrivals, "
                         "Zipf model popularity, SLO-driven continuous "
                         "batching + cost-based admission through the "
                         "ServingFrontend; prints a [traffic] report "
                         "line (p50/p99/goodput on the virtual clock)")
    ap.add_argument("--snapshot", default=None, metavar="PATH",
                    help="warm-restart snapshot (requires --traffic): "
                         "persist the frontend's clock/ledger/queues "
                         "around every dispatch; if PATH exists the run "
                         "RESUMES from it — served requests stay served "
                         "(at-most-once), queued and in-flight ones are "
                         "re-admitted for deterministic recompute "
                         "(DESIGN.md §11)")
    ap.add_argument("--kill-after", type=int, default=None, metavar="N",
                    help="stop after N dispatched batches (requires "
                         "--snapshot): pending work stays in the "
                         "snapshot; rerun the same command to resume")
    ap.add_argument("--scheduler", default="round_robin",
                    choices=sorted(SCHEDULERS))
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "device"),
                    help="numpy: host materialization (policy simulator); "
                         "device: serve through the HBM page slab via the "
                         "Pallas dedup kernels (DESIGN.md §3)")
    ap.add_argument("--transfer", default="grouped",
                    choices=("per_page", "grouped"),
                    help="host->HBM page movement: per_page (one "
                         "device_put + slab update per miss) or grouped "
                         "(a batch's misses coalesce into ONE staged "
                         "stack, one device_put, one scatter, one remap "
                         "generation bump; DESIGN.md §6)")
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the device page pool across N shards "
                         "(per-shard slabs + majority-cover routing + "
                         "cross-shard borrowing; capacity is per shard)")
    ap.add_argument("--placement", default="sharers",
                    choices=("hash", "sharers"),
                    help="page->shard placement: hash-mod baseline, or "
                         "sharer-weighted (replicate hot shared pages, "
                         "partition singletons by model affinity)")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffer grouped fetches against compute")
    ap.add_argument("--prefetch", action="store_true",
                    help="lambda-driven page prefetching (implies --overlap:"
                         " speculation only pays off hidden under compute)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a request-path trace and write it here: "
                         "'.json' = Chrome-trace/Perfetto (load in "
                         "chrome://tracing or ui.perfetto.dev), '.jsonl' "
                         "= one flat span dict per line (feed to "
                         "scripts/trace_report.py).  Timestamps are "
                         "virtual-clock microseconds; with --traffic the "
                         "per-channel span time is asserted equal to the "
                         "clock's channel ledger before writing")
    ap.add_argument("--report-json", default=None, metavar="PATH",
                    help="dump a MetricsRegistry snapshot of every stats "
                         "surface (serve/server/recovery/prefetch/clock "
                         "namespaces) as JSON")
    ap.add_argument("--lm-steps", type=int, default=4,
                    help="decode steps per LM batch (--engine lm)")
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.prefetch:
        args.overlap = True
    if args.faults and not args.store_url:
        raise SystemExit("--faults requires --store-url (faults inject "
                         "at the storage backend; the in-process store "
                         "has no backend to wrap)")
    if args.snapshot and not args.traffic:
        raise SystemExit("--snapshot requires --traffic (only the "
                         "request-level frontend has restartable state)")
    if args.kill_after is not None and not args.snapshot:
        raise SystemExit("--kill-after requires --snapshot (stopping "
                         "mid-run without a snapshot just loses work)")

    if args.engine == "lm":
        return serve_lm(args)
    return serve_embedding(args)


if __name__ == "__main__":
    main()
