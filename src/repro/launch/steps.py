"""Step builders shared by the trainer, serving engine, and dry-run."""
from __future__ import annotations

from typing import Callable, Optional

import jax

from ..models.registry import ModelAPI
from ..optim.optimizers import Optimizer


def make_train_step(api: ModelAPI, opt: Optimizer,
                    grad_transform: Optional[Callable] = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_transform`` hooks in gradient compression / dedup-finetune
    masks (applied before the optimizer).
    """
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss(p, batch))(params)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_prefill_step(api: ModelAPI, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        return api.prefill(params, batch, max_len)
    return prefill_step


def make_decode_step(api: ModelAPI):
    def decode_step(params, cache, tokens):
        return api.decode(params, cache, tokens)
    return decode_step


def make_serve_step(api: ModelAPI):
    """decode_32k / long_500k cell entry point: one new token against a
    filled cache (batch = {"tokens", "cache"})."""
    def serve_step(params, batch):
        logits, cache = api.decode(params, batch["cache"], batch["tokens"])
        return logits, cache
    return serve_step
