"""Fault-tolerant checkpointing.

* **Atomic commit**: a checkpoint directory is staged as ``tmp-<step>``
  and ``os.replace``d to ``step-<n>`` only after every leaf + manifest is
  on disk; a crash mid-save never corrupts the latest checkpoint.
* **Auto-resume**: ``restore_latest`` scans for the newest *complete*
  step (manifest present), so ``train.py --resume auto`` restarts after
  node failure with zero operator input.
* **Content-addressed page store interop**: model weights can also be
  committed through ``core.store.ModelStore.save`` (the paper's dedup
  format) — unchanged shared pages are not rewritten, which is the
  dedup-aware incremental checkpoint path used for fine-tuned variants.
* **Elastic re-mesh**: checkpoints store unsharded (host) arrays; on
  restore the trainer re-shards onto whatever mesh exists, so resuming
  with fewer/more hosts only changes the data-parallel slice mapping
  (the data pipeline is (step, host)-deterministic, see data/pipeline.py).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(like, flat: Dict[str, np.ndarray]):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            if arr.dtype.kind == "V":      # bf16 saved as raw void bytes
                arr = arr.view(leaf.dtype)
            out.append(arr.astype(leaf.dtype).reshape(leaf.shape))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save --
    def save(self, step: int, params, opt_state=None,
             extra: Optional[Dict[str, Any]] = None) -> str:
        stage = os.path.join(self.dir, f"tmp-{step}")
        final = os.path.join(self.dir, f"step-{step}")
        if os.path.exists(stage):
            shutil.rmtree(stage)
        os.makedirs(stage)
        np.savez(os.path.join(stage, "params.npz"), **_flatten(params))
        if opt_state is not None:
            np.savez(os.path.join(stage, "opt_state.npz"),
                     **_flatten(opt_state))
        manifest = {"step": step, "extra": extra or {},
                    "has_opt": opt_state is not None}
        with open(os.path.join(stage, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(stage, final)                 # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore --
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step-(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_params, like_opt=None
                ) -> Tuple[Any, Any, Dict]:
        d = os.path.join(self.dir, f"step-{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        pz = np.load(os.path.join(d, "params.npz"))
        params = _unflatten(like_params, dict(pz))
        opt = None
        if like_opt is not None and manifest.get("has_opt"):
            oz = np.load(os.path.join(d, "opt_state.npz"))
            opt = _unflatten(like_opt, dict(oz))
        return params, opt, manifest

    def restore_latest(self, like_params, like_opt=None):
        step = self.latest_step()
        if step is None:
            return None
        params, opt, manifest = self.restore(step, like_params, like_opt)
        return step, params, opt, manifest
