"""Write-ahead intent journal + startup recovery (DESIGN.md §11).

A ModelStore save is a multi-step mutation — put pages, commit the
manifest, prune orphans — and only the manifest commit is atomic on its
own.  A crash anywhere else strands state: fresh pages with no
referencing manifest (undo work), or a committed manifest whose prune
never ran (redo work), plus ``*.tmp`` staging debris.  The journal makes
the whole sequence atomic-on-recovery:

  1. ``Journal.begin(op, keep=[...])`` durably appends an **intent**
     record *before* the first page is touched and returns its ``seq``.
  2. The operation runs, crossing its registered crash points.
  3. ``Journal.commit(seq)`` appends a **done** marker and compacts the
     journal (resolved intent/done pairs drop out; other writers'
     pending intents survive).

Record format (one JSON object per record)::

    {"v": 1, "phase": "intent", "op": "save"|"gc", "seq": N,
     "keep": [page hashes the op's manifest will reference]}
    {"v": 1, "phase": "done", "seq": N}

Recovery (:func:`recover_backend`, called by ``open_backend`` /
``ModelStore.open``) is intentionally dumb: *any* journal record —
pending intent or a resolved pair stranded by a crash mid-compaction —
marks the store dirty.  The committed manifest is the sole source of
truth for which pages deserve to live; everything recovery does reduces
to one idempotent, itself-journaled GC:

  * delete every stored page the committed manifest does not reference
    (undoes a crashed save's fresh pages; finishes a crashed save's
    prune — which of the two happened is recorded in the report by
    comparing each pending intent's keep-set against the manifest);
  * sweep temp staging files;
  * clear the journal (the GC's own commit).

A crash *during* recovery re-runs the same GC on the next open — the
proof obligation is idempotence, not ordering, and the crash-point
sweep (``storage/crashpoints.py``) kills recovery at its own seams to
hold it to that.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .crashpoints import crash_point, register_crash_points

RECORD_VERSION = 1

register_crash_points({
    "recover.gc_journaled":
        "recovery's own gc intent journaled, nothing deleted yet",
    "recover.gc_done":
        "orphans deleted and temps swept, journal not yet cleared",
})


class Journal:
    """Intent journal over one backend's durable journal primitives."""

    def __init__(self, backend):
        self.backend = backend

    def begin(self, op: str, **payload) -> int:
        """Durably record the intent BEFORE the first mutation; returns
        the intent's seq for :meth:`commit`."""
        return self.backend.journal_append(
            {"v": RECORD_VERSION, "phase": "intent", "op": op, **payload})

    def commit(self, seq: int) -> None:
        """Mark intent ``seq`` done, then compact the journal."""
        self.backend.journal_append(
            {"v": RECORD_VERSION, "phase": "done", "seq": int(seq)})
        self.compact()

    def records(self) -> List[Dict]:
        return self.backend.journal_records()

    def pending(self) -> List[Dict]:
        """Intents with no matching done marker — the crash windows."""
        recs = self.records()
        done = {int(r["seq"]) for r in recs if r.get("phase") == "done"}
        return [r for r in recs
                if r.get("phase") == "intent" and int(r["seq"]) not in done]

    def compact(self) -> None:
        """Atomically drop resolved intent/done pairs; pending intents
        (e.g. a concurrent writer mid-save) survive verbatim."""
        self.backend.journal_rewrite(self.pending())

    def clear(self) -> None:
        self.backend.journal_rewrite([])


@dataclasses.dataclass
class RecoveryReport:
    """What one :func:`recover_backend` pass found and fixed."""
    recovered: bool = False           # False: journal was clean, no-op
    pending_intents: int = 0          # intents with no done marker
    redo: int = 0                     # intents whose commit had landed
    undo: int = 0                     # intents rolled back by the GC
    orphan_pages_deleted: int = 0
    temp_files_swept: int = 0

    def summary(self) -> str:
        if not self.recovered:
            return "clean (journal empty)"
        return (f"{self.pending_intents} pending intent(s) "
                f"({self.redo} redo / {self.undo} undo), "
                f"{self.orphan_pages_deleted} orphan page(s) deleted, "
                f"{self.temp_files_swept} temp file(s) swept")


def needs_recovery(backend) -> bool:
    """True iff the journal holds ANY record — pending intents, or a
    resolved pair stranded by a crash mid-compaction."""
    return bool(backend.journal_records())


def recover_backend(backend) -> RecoveryReport:
    """Replay the journal on a just-opened backend (idempotent).

    No-op when the journal is empty — a clean open costs exactly one
    journal read, never a page listing.  Otherwise runs the journaled
    GC described in the module docstring and returns the report.
    """
    jr = Journal(backend)
    recs = jr.records()
    if not recs:
        return RecoveryReport()
    report = RecoveryReport(recovered=True)
    try:
        manifest = backend.load_manifest()
        keep = {p["hash"] for p in manifest["pages"]}
    except FileNotFoundError:
        keep = set()                  # nothing ever committed: all garbage
    pend = jr.pending()
    report.pending_intents = len(pend)
    for r in pend:
        intent_keep = set(r.get("keep", []))
        # the intent's manifest landed iff the committed refs are exactly
        # what it promised to keep: finish its cleanup (redo); otherwise
        # the commit never happened and its fresh pages roll back (undo)
        if intent_keep and intent_keep == keep:
            report.redo += 1
        else:
            report.undo += 1
    if pend:
        jr.begin("gc", keep=sorted(keep))
        crash_point("recover.gc_journaled")
        stray = [h for h in backend.list_pages() if h not in keep]
        if stray:
            report.orphan_pages_deleted = int(backend.delete_pages(stray))
        report.temp_files_swept = int(backend.sweep_temp())
        crash_point("recover.gc_done")
    else:
        # resolved pairs stranded by a crash mid-compaction: no intent is
        # open, so pages are consistent — only staging debris can remain
        report.temp_files_swept = int(backend.sweep_temp())
    jr.clear()
    return report
