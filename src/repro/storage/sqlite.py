"""Relational (SQLite) page backend — the paper's native habitat.

Pages are BLOB rows keyed by content hash; the manifest is *relational*:
``models`` / ``tensors`` / ``manifest_pages`` / ``tensor_pages`` tables
rewritten in ONE transaction per commit, so a crash mid-commit rolls
back to the previous manifest (the database's atomicity doing the job
``os.replace`` does for the directory backend).  Stdlib-only.

Schema (DESIGN.md "Storage backends")::

    pages(hash TEXT PK, dtype TEXT, shape TEXT, data BLOB)
    meta(key TEXT PK, json TEXT)              -- store config + version
    models(model TEXT PK)
    tensors(model, tensor, shape TEXT, dtype TEXT, block_map BLOB,
            PK(model, tensor))                -- block_map: int64 LE bytes
    manifest_pages(page_idx INTEGER PK, hash TEXT, blocks TEXT)
    tensor_pages(model, tensor, seq INTEGER, page_idx INTEGER,
                 PK(model, tensor, seq))      -- exact per-tensor cover

``load_manifest`` reconstructs the ModelStore manifest dict from these
tables (they are load-bearing, not a cache of a JSON blob).
"""
from __future__ import annotations

import json
import os
import sqlite3
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from .backend import PageBackend, resolve_dtype

_SCHEMA = """
CREATE TABLE IF NOT EXISTS pages(
    hash  TEXT PRIMARY KEY,
    dtype TEXT NOT NULL,
    shape TEXT NOT NULL,
    data  BLOB NOT NULL);
CREATE TABLE IF NOT EXISTS meta(
    key  TEXT PRIMARY KEY,
    json TEXT NOT NULL);
CREATE TABLE IF NOT EXISTS models(
    model TEXT PRIMARY KEY);
CREATE TABLE IF NOT EXISTS tensors(
    model     TEXT NOT NULL,
    tensor    TEXT NOT NULL,
    shape     TEXT NOT NULL,
    dtype     TEXT NOT NULL,
    block_map BLOB NOT NULL,
    PRIMARY KEY (model, tensor));
CREATE TABLE IF NOT EXISTS manifest_pages(
    page_idx INTEGER PRIMARY KEY,
    hash     TEXT NOT NULL,
    blocks   TEXT NOT NULL);
CREATE TABLE IF NOT EXISTS tensor_pages(
    model    TEXT NOT NULL,
    tensor   TEXT NOT NULL,
    seq      INTEGER NOT NULL,
    page_idx INTEGER NOT NULL,
    PRIMARY KEY (model, tensor, seq));
"""

#: manifest keys that live in ``meta`` rather than the relational tables
_META_KEYS = ("version", "blocks_per_page", "block_shape", "page_dtype",
              "pack_strategy", "dedup_config")


class SQLiteBackend(PageBackend):
    scheme = "sqlite"

    def __init__(self, path: str):
        self.path = str(path)
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._con = sqlite3.connect(self.path)
        self._con.executescript(_SCHEMA)
        self._con.commit()
        # Test seam: invoked after the manifest rows are written but
        # before COMMIT — raising here simulates a crash mid-commit and
        # must leave the previous manifest readable (transaction rollback).
        self._pre_commit_hook: Optional[Callable[[], None]] = None

    def url(self) -> str:
        return f"sqlite:///{os.path.abspath(self.path)}"

    def close(self) -> None:
        self._con.close()

    # ------------------------------------------------------------- pages --
    def put_pages(self, pages: Mapping[str, np.ndarray]) -> int:
        cur = self._con.cursor()
        new = 0
        for h, arr in pages.items():
            arr = np.ascontiguousarray(arr)
            cur.execute(
                "INSERT OR IGNORE INTO pages(hash, dtype, shape, data) "
                "VALUES (?, ?, ?, ?)",
                (h, arr.dtype.name, json.dumps(list(arr.shape)),
                 sqlite3.Binary(arr.tobytes())))
            new += cur.rowcount
        self._con.commit()
        return new

    def get_pages(self, hashes: Sequence[str]) -> Dict[str, np.ndarray]:
        hashes = list(hashes)
        if not hashes:
            return {}
        # ONE grouped query for the whole miss set — the per-request
        # overhead (parse/plan/seek) is paid once per batch, which is
        # exactly what StorageModel.fetch_group_seconds models.
        uniq = sorted(set(hashes))
        marks = ",".join("?" * len(uniq))
        rows = self._con.execute(
            f"SELECT hash, dtype, shape, data FROM pages "
            f"WHERE hash IN ({marks})", uniq).fetchall()
        got = {h: np.frombuffer(data, dtype=resolve_dtype(dt))
               .reshape(json.loads(shape)).copy()
               for h, dt, shape, data in rows}
        for h in uniq:
            if h not in got:
                raise KeyError(f"page {h!r} not in {self.path}")
        return {h: got[h] for h in hashes}

    def list_pages(self) -> List[str]:
        return [r[0] for r in self._con.execute(
            "SELECT hash FROM pages ORDER BY hash")]

    def delete_pages(self, hashes: Sequence[str]) -> int:
        hashes = list(hashes)
        if not hashes:
            return 0
        marks = ",".join("?" * len(hashes))
        cur = self._con.execute(
            f"DELETE FROM pages WHERE hash IN ({marks})", hashes)
        self._con.commit()
        return cur.rowcount

    # ---------------------------------------------------------- manifest --
    def commit_manifest(self, manifest: Dict) -> None:
        con = self._con
        try:
            cur = con.cursor()
            for t in ("models", "tensors", "manifest_pages", "tensor_pages"):
                cur.execute(f"DELETE FROM {t}")
            cur.execute("DELETE FROM meta")
            for key in _META_KEYS:
                if key in manifest:
                    cur.execute("INSERT INTO meta(key, json) VALUES (?, ?)",
                                (key, json.dumps(manifest[key])))
            for idx, entry in enumerate(manifest["pages"]):
                cur.execute(
                    "INSERT INTO manifest_pages(page_idx, hash, blocks) "
                    "VALUES (?, ?, ?)",
                    (idx, entry["hash"],
                     json.dumps([int(b) for b in entry["blocks"]])))
            for model, tensors in manifest["models"].items():
                cur.execute("INSERT INTO models(model) VALUES (?)", (model,))
                for tensor, spec in tensors.items():
                    bm = np.asarray(spec["block_map"],
                                    dtype="<i8").tobytes()
                    cur.execute(
                        "INSERT INTO tensors(model, tensor, shape, dtype, "
                        "block_map) VALUES (?, ?, ?, ?, ?)",
                        (model, tensor, json.dumps(list(spec["shape"])),
                         spec["dtype"], sqlite3.Binary(bm)))
                    cur.executemany(
                        "INSERT INTO tensor_pages(model, tensor, seq, "
                        "page_idx) VALUES (?, ?, ?, ?)",
                        [(model, tensor, seq, int(pid))
                         for seq, pid in enumerate(spec["pages"])])
            if self._pre_commit_hook is not None:
                self._pre_commit_hook()
            con.commit()                          # the atomic commit point
        except BaseException:
            con.rollback()
            raise

    def load_manifest(self) -> Dict:
        con = self._con
        meta = {k: json.loads(v)
                for k, v in con.execute("SELECT key, json FROM meta")}
        page_rows = con.execute(
            "SELECT page_idx, hash, blocks FROM manifest_pages "
            "ORDER BY page_idx").fetchall()
        if not meta or not page_rows:
            raise FileNotFoundError(f"no manifest committed in {self.path}")
        manifest: Dict = dict(meta)
        manifest["pages"] = [{"hash": h, "blocks": json.loads(blocks)}
                             for _, h, blocks in page_rows]
        models: Dict[str, Dict] = {
            m: {} for (m,) in con.execute("SELECT model FROM models")}
        cover: Dict = {}
        for model, tensor, pid in con.execute(
                "SELECT model, tensor, page_idx FROM tensor_pages "
                "ORDER BY model, tensor, seq"):
            cover.setdefault((model, tensor), []).append(int(pid))
        for model, tensor, shape, dtype, bm in con.execute(
                "SELECT model, tensor, shape, dtype, block_map FROM tensors"):
            models[model][tensor] = {
                "shape": json.loads(shape),
                "dtype": dtype,
                "block_map": np.frombuffer(bm, dtype="<i8").tolist(),
                "pages": cover.get((model, tensor), []),
            }
        manifest["models"] = models
        return manifest
