"""Relational (SQLite) page backend — the paper's native habitat.

Pages are BLOB rows keyed by content hash; the manifest is *relational*:
``models`` / ``tensors`` / ``manifest_pages`` / ``tensor_pages`` tables
rewritten in ONE transaction per commit, so a crash mid-commit rolls
back to the previous manifest (the database's atomicity doing the job
``os.replace`` does for the directory backend).  Stdlib-only.

Concurrent writers: commits are optimistically locked on a
``commit_version`` counter in ``meta``.  Each handle remembers the
version it last observed (at open / ``load_manifest`` / its own
commit); ``commit_manifest`` takes the write lock (``BEGIN IMMEDIATE``,
so version check and rewrite are one critical section), compares the
stored counter against the observed one, and raises
:class:`~repro.storage.backend.ManifestConflictError` on mismatch — the
stale writer rolls back, reloads, and retries on top of the winner's
manifest instead of silently clobbering it.

Schema (DESIGN.md "Storage backends")::

    pages(hash TEXT PK, dtype TEXT, shape TEXT, data BLOB)
    meta(key TEXT PK, json TEXT)              -- store config + version
    models(model TEXT PK)
    tensors(model, tensor, shape TEXT, dtype TEXT, block_map BLOB,
            PK(model, tensor))                -- block_map: int64 LE bytes
    manifest_pages(page_idx INTEGER PK, hash TEXT, blocks TEXT)
    tensor_pages(model, tensor, seq INTEGER, page_idx INTEGER,
                 PK(model, tensor, seq))      -- exact per-tensor cover

``load_manifest`` reconstructs the ModelStore manifest dict from these
tables (they are load-bearing, not a cache of a JSON blob).
"""
from __future__ import annotations

import json
import os
import sqlite3
import time
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from .backend import ManifestConflictError, PageBackend, resolve_dtype
from .crashpoints import crash_point, register_crash_points
from .faults import TransientStorageError, is_transient

register_crash_points({
    "sqlite.put_pages.staged":
        "page rows inserted in the open transaction, COMMIT not issued",
    "sqlite.commit_manifest.staged":
        "manifest rows rewritten inside BEGIN IMMEDIATE, COMMIT not issued",
    "sqlite.commit_manifest.committed":
        "immediately after the manifest transaction COMMIT",
    "sqlite.delete_pages.staged":
        "orphan rows deleted in the open transaction, COMMIT not issued",
    "sqlite.journal.appended":
        "after the journal-intent transaction COMMIT",
    "sqlite.journal.rewrite_staged":
        "journal compacted inside BEGIN IMMEDIATE, COMMIT not issued",
})

_SCHEMA = """
CREATE TABLE IF NOT EXISTS pages(
    hash  TEXT PRIMARY KEY,
    dtype TEXT NOT NULL,
    shape TEXT NOT NULL,
    data  BLOB NOT NULL);
CREATE TABLE IF NOT EXISTS meta(
    key  TEXT PRIMARY KEY,
    json TEXT NOT NULL);
CREATE TABLE IF NOT EXISTS models(
    model TEXT PRIMARY KEY);
CREATE TABLE IF NOT EXISTS tensors(
    model     TEXT NOT NULL,
    tensor    TEXT NOT NULL,
    shape     TEXT NOT NULL,
    dtype     TEXT NOT NULL,
    block_map BLOB NOT NULL,
    PRIMARY KEY (model, tensor));
CREATE TABLE IF NOT EXISTS manifest_pages(
    page_idx INTEGER PRIMARY KEY,
    hash     TEXT NOT NULL,
    blocks   TEXT NOT NULL);
CREATE TABLE IF NOT EXISTS tensor_pages(
    model    TEXT NOT NULL,
    tensor   TEXT NOT NULL,
    seq      INTEGER NOT NULL,
    page_idx INTEGER NOT NULL,
    PRIMARY KEY (model, tensor, seq));
CREATE TABLE IF NOT EXISTS journal(
    id   INTEGER PRIMARY KEY AUTOINCREMENT,
    seq  INTEGER NOT NULL,
    json TEXT NOT NULL);
"""

#: manifest keys that live in ``meta`` rather than the relational tables
_META_KEYS = ("version", "blocks_per_page", "block_shape", "page_dtype",
              "pack_strategy", "dedup_config")

#: meta key of the optimistic-locking commit counter (never part of the
#: manifest dict itself)
_COMMIT_VERSION = "commit_version"


class SQLiteBackend(PageBackend):
    """Pages as BLOB rows in a single-file SQLite database — the
    paper's models-in-the-RDBMS storage tier."""
    scheme = "sqlite"

    def __init__(self, path: str, timeout: float = 5.0,
                 lock_retries: int = 4, lock_backoff: float = 0.01):
        self.path = str(path)
        # explicit busy timeout: sqlite3's own lock wait, BEFORE the
        # bounded retry loop in commit_manifest gets involved
        self.timeout = float(timeout)
        self.lock_retries = int(lock_retries)
        self.lock_backoff = float(lock_backoff)
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._con = sqlite3.connect(self.path, timeout=self.timeout)
        self._con.executescript(_SCHEMA)
        # idempotent DDL bootstrap: CREATE IF NOT EXISTS at any crash
        # instant converges to the same schema on reopen
        self._con.commit()  # repro: allow-unjournaled
        # Test seam: invoked after the manifest rows are written but
        # before COMMIT — raising here simulates a crash mid-commit and
        # must leave the previous manifest readable (transaction rollback).
        self._pre_commit_hook: Optional[Callable[[], None]] = None
        # Optimistic locking: the commit counter this handle last saw
        # (0 = no manifest yet); refreshed by load_manifest and by our
        # own successful commits.
        self._seen_version = self._db_version()

    def url(self) -> str:
        return f"sqlite:///{os.path.abspath(self.path)}"

    def close(self) -> None:
        self._con.close()

    # ------------------------------------------------------------- pages --
    def put_pages(self, pages: Mapping[str, np.ndarray]) -> int:
        cur = self._con.cursor()
        new = 0
        for h, arr in pages.items():
            arr = np.ascontiguousarray(arr)
            cur.execute(
                "INSERT OR IGNORE INTO pages(hash, dtype, shape, data) "
                "VALUES (?, ?, ?, ?)",
                (h, arr.dtype.name, json.dumps(list(arr.shape)),
                 sqlite3.Binary(arr.tobytes())))
            new += cur.rowcount
        crash_point("sqlite.put_pages.staged")
        self._con.commit()
        return new

    def get_pages(self, hashes: Sequence[str]) -> Dict[str, np.ndarray]:
        hashes = list(hashes)
        if not hashes:
            return {}
        # ONE grouped query for the whole miss set — the per-request
        # overhead (parse/plan/seek) is paid once per batch, which is
        # exactly what StorageModel.fetch_group_seconds models.
        uniq = sorted(set(hashes))
        marks = ",".join("?" * len(uniq))
        rows = self._con.execute(
            f"SELECT hash, dtype, shape, data FROM pages "
            f"WHERE hash IN ({marks})", uniq).fetchall()
        got = {h: np.frombuffer(data, dtype=resolve_dtype(dt))
               .reshape(json.loads(shape)).copy()
               for h, dt, shape, data in rows}
        for h in uniq:
            if h not in got:
                raise KeyError(f"page {h!r} not in {self.path}")
        return {h: got[h] for h in hashes}

    def list_pages(self) -> List[str]:
        return [r[0] for r in self._con.execute(
            "SELECT hash FROM pages ORDER BY hash")]

    def delete_pages(self, hashes: Sequence[str]) -> int:
        hashes = list(hashes)
        if not hashes:
            return 0
        marks = ",".join("?" * len(hashes))
        cur = self._con.execute(
            f"DELETE FROM pages WHERE hash IN ({marks})", hashes)
        crash_point("sqlite.delete_pages.staged")
        self._con.commit()
        return cur.rowcount

    # ---------------------------------------------------------- manifest --
    def _db_version(self, cur=None) -> int:
        """Current commit counter in the database (0: never committed)."""
        row = (cur or self._con).execute(
            "SELECT json FROM meta WHERE key = ?",
            (_COMMIT_VERSION,)).fetchone()
        return int(json.loads(row[0])) if row else 0

    def commit_manifest(self, manifest: Dict) -> None:
        """Commit with bounded retry on lock contention.

        A concurrent writer holding the reservation surfaces as
        ``sqlite3.OperationalError: database is locked`` — a *transient*
        condition (the winner commits and releases), classified via
        :func:`~repro.storage.faults.is_transient` and retried with
        bounded exponential backoff on top of the connection's own
        ``timeout``.  :class:`ManifestConflictError` is the opposite — a
        hard optimistic-locking conflict that must NOT be retried
        blindly (the caller reloads and re-applies) — and propagates on
        the first occurrence."""
        attempt = 0
        while True:
            try:
                return self._commit_manifest_once(manifest)
            except ManifestConflictError:
                raise
            except sqlite3.OperationalError as exc:
                if not is_transient(exc):
                    raise
                attempt += 1
                if attempt > self.lock_retries:
                    raise TransientStorageError(
                        f"commit_manifest on {self.path}: lock still "
                        f"contended after {self.lock_retries} retries"
                    ) from exc
                time.sleep(self.lock_backoff * 2 ** (attempt - 1))

    def _commit_manifest_once(self, manifest: Dict) -> None:
        con = self._con
        con.commit()                   # close any implicit transaction
        try:
            cur = con.cursor()
            # BEGIN IMMEDIATE takes the write lock NOW, making the
            # version check + rewrite one critical section: a concurrent
            # writer blocks here until we commit, then sees our counter.
            cur.execute("BEGIN IMMEDIATE")
            current = self._db_version(cur)
            if current != self._seen_version:
                raise ManifestConflictError(
                    f"manifest in {self.path} is at commit version "
                    f"{current}, this handle last observed "
                    f"{self._seen_version}: another writer committed "
                    f"first — load_manifest() and retry on top of it")
            for t in ("models", "tensors", "manifest_pages", "tensor_pages"):
                cur.execute(f"DELETE FROM {t}")
            cur.execute("DELETE FROM meta")
            cur.execute("INSERT INTO meta(key, json) VALUES (?, ?)",
                        (_COMMIT_VERSION, json.dumps(current + 1)))
            for key in _META_KEYS:
                if key in manifest:
                    cur.execute("INSERT INTO meta(key, json) VALUES (?, ?)",
                                (key, json.dumps(manifest[key])))
            for idx, entry in enumerate(manifest["pages"]):
                cur.execute(
                    "INSERT INTO manifest_pages(page_idx, hash, blocks) "
                    "VALUES (?, ?, ?)",
                    (idx, entry["hash"],
                     json.dumps([int(b) for b in entry["blocks"]])))
            for model, tensors in manifest["models"].items():
                cur.execute("INSERT INTO models(model) VALUES (?)", (model,))
                for tensor, spec in tensors.items():
                    bm = np.asarray(spec["block_map"],
                                    dtype="<i8").tobytes()
                    cur.execute(
                        "INSERT INTO tensors(model, tensor, shape, dtype, "
                        "block_map) VALUES (?, ?, ?, ?, ?)",
                        (model, tensor, json.dumps(list(spec["shape"])),
                         spec["dtype"], sqlite3.Binary(bm)))
                    cur.executemany(
                        "INSERT INTO tensor_pages(model, tensor, seq, "
                        "page_idx) VALUES (?, ?, ?, ?)",
                        [(model, tensor, seq, int(pid))
                         for seq, pid in enumerate(spec["pages"])])
            if self._pre_commit_hook is not None:
                self._pre_commit_hook()
            crash_point("sqlite.commit_manifest.staged")
            con.commit()                          # the atomic commit point
            self._seen_version = current + 1
            crash_point("sqlite.commit_manifest.committed")
        except BaseException:
            con.rollback()
            raise

    def load_manifest(self) -> Dict:
        con = self._con
        meta = {k: json.loads(v)
                for k, v in con.execute("SELECT key, json FROM meta")}
        commit_version = int(meta.pop(_COMMIT_VERSION, 0))
        page_rows = con.execute(
            "SELECT page_idx, hash, blocks FROM manifest_pages "
            "ORDER BY page_idx").fetchall()
        if not meta or not page_rows:
            raise FileNotFoundError(f"no manifest committed in {self.path}")
        # reading the manifest adopts its version: a subsequent commit
        # from this handle builds on what it just observed
        self._seen_version = commit_version
        manifest: Dict = dict(meta)
        manifest["pages"] = [{"hash": h, "blocks": json.loads(blocks)}
                             for _, h, blocks in page_rows]
        models: Dict[str, Dict] = {
            m: {} for (m,) in con.execute("SELECT model FROM models")}
        cover: Dict = {}
        for model, tensor, pid in con.execute(
                "SELECT model, tensor, page_idx FROM tensor_pages "
                "ORDER BY model, tensor, seq"):
            cover.setdefault((model, tensor), []).append(int(pid))
        for model, tensor, shape, dtype, bm in con.execute(
                "SELECT model, tensor, shape, dtype, block_map FROM tensors"):
            models[model][tensor] = {
                "shape": json.loads(shape),
                "dtype": dtype,
                "block_map": np.frombuffer(bm, dtype="<i8").tolist(),
                "pages": cover.get((model, tensor), []),
            }
        manifest["models"] = models
        return manifest

    # ------------------------------------------------------------ journal --
    def journal_records(self) -> List[Dict]:
        return [json.loads(j) for (j,) in self._con.execute(
            "SELECT json FROM journal ORDER BY id")]

    def journal_append(self, record: Dict) -> int:
        con = self._con
        con.commit()                   # close any implicit transaction
        try:
            cur = con.cursor()
            # seq assignment and insert are one critical section, so two
            # concurrent writers can never mint the same intent seq
            cur.execute("BEGIN IMMEDIATE")
            if "seq" in record:
                seq = int(record["seq"])
            else:
                seq = int(cur.execute(
                    "SELECT COALESCE(MAX(seq), 0) + 1 FROM journal"
                ).fetchone()[0])
                record = {**record, "seq": seq}
            cur.execute("INSERT INTO journal(seq, json) VALUES (?, ?)",
                        (seq, json.dumps(record)))
            con.commit()
        except BaseException:
            con.rollback()
            raise
        crash_point("sqlite.journal.appended")
        return seq

    def journal_rewrite(self, records: Sequence[Dict]) -> None:
        con = self._con
        con.commit()
        try:
            cur = con.cursor()
            cur.execute("BEGIN IMMEDIATE")
            cur.execute("DELETE FROM journal")
            for r in records:
                cur.execute("INSERT INTO journal(seq, json) VALUES (?, ?)",
                            (int(r["seq"]), json.dumps(r)))
            crash_point("sqlite.journal.rewrite_staged")
            con.commit()
        except BaseException:
            con.rollback()
            raise
