"""Local-directory page backend: the historical ModelStore on-disk format.

Content-addressed ``page-<hash>.npy`` files plus a ``manifest.json``
committed by atomic rename — byte-compatible with stores written by the
old ``ModelStore.save(path)``, so existing checkpoints keep loading.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, List, Mapping, Sequence

import numpy as np

from .backend import PageBackend

MANIFEST_NAME = "manifest.json"


class LocalDirBackend(PageBackend):
    """Pages as one .npy file each under a local directory."""
    scheme = "file"

    def __init__(self, path: str):
        self.path = str(path)
        os.makedirs(self.path, exist_ok=True)

    def url(self) -> str:
        return f"file://{os.path.abspath(self.path)}"

    def _page_path(self, h: str) -> str:
        return os.path.join(self.path, f"page-{h}.npy")

    # ------------------------------------------------------------- pages --
    def put_pages(self, pages: Mapping[str, np.ndarray]) -> int:
        new = 0
        for h, arr in pages.items():
            fp = self._page_path(h)
            if os.path.exists(fp):               # content addressing
                continue
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".npy.tmp")
            with os.fdopen(fd, "wb") as f:
                np.save(f, np.ascontiguousarray(arr))
            os.replace(tmp, fp)                  # no torn page files
            new += 1
        return new

    def get_pages(self, hashes: Sequence[str]) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        for h in hashes:
            fp = self._page_path(h)
            if not os.path.exists(fp):
                raise KeyError(f"page {h!r} not in {self.path}")
            out[h] = np.load(fp)
        return out

    def list_pages(self) -> List[str]:
        out = []
        for name in os.listdir(self.path):
            if name.startswith("page-") and name.endswith(".npy"):
                out.append(name[len("page-"):-len(".npy")])
        return sorted(out)

    def delete_pages(self, hashes: Sequence[str]) -> int:
        n = 0
        for h in hashes:
            try:
                os.remove(self._page_path(h))
                n += 1
            except FileNotFoundError:
                pass
        return n

    # ---------------------------------------------------------- manifest --
    def commit_manifest(self, manifest: Dict) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(manifest, f)
        # The atomic commit point: a crash before this line leaves the
        # previous manifest untouched (crash-safety test).
        os.replace(tmp, os.path.join(self.path, MANIFEST_NAME))

    def load_manifest(self) -> Dict:
        with open(os.path.join(self.path, MANIFEST_NAME)) as f:
            return json.load(f)
