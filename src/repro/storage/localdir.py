"""Local-directory page backend: the historical ModelStore on-disk format.

Content-addressed ``page-<hash>.npy`` files plus a ``manifest.json``
committed by atomic rename — byte-compatible with stores written by the
old ``ModelStore.save(path)``, so existing checkpoints keep loading.

Durability additions (DESIGN.md §11): a line-oriented intent journal
(``journal.jsonl``, fsync'd appends, atomic-rename compaction) and a
``sweep_temp`` pass collecting the ``*.tmp`` staging files a crash
between ``mkstemp`` and ``os.replace`` strands.  Every rename seam is a
registered crash point so the kill-at-every-seam sweep can prove the
recovery story rather than assume it.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, List, Mapping, Sequence

import numpy as np

from .backend import PageBackend
from .crashpoints import crash_point, register_crash_points

MANIFEST_NAME = "manifest.json"
JOURNAL_NAME = "journal.jsonl"

register_crash_points({
    "localdir.put_pages.tmp_written":
        "page bytes staged in a mkstemp file, rename not yet issued",
    "localdir.put_pages.page_committed":
        "after one page's atomic rename, before the next page",
    "localdir.commit_manifest.tmp_written":
        "manifest JSON staged, atomic rename not yet issued",
    "localdir.commit_manifest.committed":
        "immediately after the manifest atomic rename",
    "localdir.delete_pages.mid":
        "mid-prune: some orphan pages unlinked, the rest still present",
    "localdir.journal.appended":
        "after an fsync'd journal append (intent or done marker)",
    "localdir.journal.rewrite_staged":
        "compacted journal staged in a tmp file, rename not yet issued",
    "localdir.journal.rewritten":
        "immediately after the journal compaction rename",
})


class LocalDirBackend(PageBackend):
    """Pages as one .npy file each under a local directory."""
    scheme = "file"

    def __init__(self, path: str):
        self.path = str(path)
        os.makedirs(self.path, exist_ok=True)

    def url(self) -> str:
        return f"file://{os.path.abspath(self.path)}"

    def _page_path(self, h: str) -> str:
        return os.path.join(self.path, f"page-{h}.npy")

    # ------------------------------------------------------------- pages --
    def put_pages(self, pages: Mapping[str, np.ndarray]) -> int:
        new = 0
        for h, arr in pages.items():
            fp = self._page_path(h)
            if os.path.exists(fp):               # content addressing
                continue
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".npy.tmp")
            with os.fdopen(fd, "wb") as f:
                np.save(f, np.ascontiguousarray(arr))
            crash_point("localdir.put_pages.tmp_written")
            os.replace(tmp, fp)                  # no torn page files
            crash_point("localdir.put_pages.page_committed")
            new += 1
        return new

    def get_pages(self, hashes: Sequence[str]) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        for h in hashes:
            fp = self._page_path(h)
            if not os.path.exists(fp):
                raise KeyError(f"page {h!r} not in {self.path}")
            out[h] = np.load(fp)
        return out

    def list_pages(self) -> List[str]:
        out = []
        for name in os.listdir(self.path):
            # staging debris (*.tmp) is never a page, even if a crashed
            # rename left it with a page-like prefix
            if (name.startswith("page-") and name.endswith(".npy")
                    and not name.endswith(".tmp")):
                out.append(name[len("page-"):-len(".npy")])
        return sorted(out)

    def delete_pages(self, hashes: Sequence[str]) -> int:
        n = 0
        for h in hashes:
            try:
                os.remove(self._page_path(h))
                n += 1
            except FileNotFoundError:
                pass
            crash_point("localdir.delete_pages.mid")
        return n

    # ---------------------------------------------------------- manifest --
    def commit_manifest(self, manifest: Dict) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(manifest, f)
        crash_point("localdir.commit_manifest.tmp_written")
        # The atomic commit point: a crash before this line leaves the
        # previous manifest untouched (crash-safety test).
        os.replace(tmp, os.path.join(self.path, MANIFEST_NAME))
        crash_point("localdir.commit_manifest.committed")

    def load_manifest(self) -> Dict:
        with open(os.path.join(self.path, MANIFEST_NAME)) as f:
            return json.load(f)

    # ------------------------------------------------------------ journal --
    def _journal_path(self) -> str:
        return os.path.join(self.path, JOURNAL_NAME)

    def journal_records(self) -> List[Dict]:
        try:
            with open(self._journal_path()) as f:
                text = f.read()
        except FileNotFoundError:
            return []
        out: List[Dict] = []
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                # torn tail from a crash mid-append: the record never
                # became durable, so it never happened
                continue
        return out

    def journal_append(self, record: Dict) -> int:
        if "seq" not in record:
            seqs = [r.get("seq", 0) for r in self.journal_records()]
            record = {**record, "seq": max(seqs, default=0) + 1}
        with open(self._journal_path(), "a") as f:
            f.write(json.dumps(record) + "\n")
            f.flush()
            os.fsync(f.fileno())
        crash_point("localdir.journal.appended")
        return int(record["seq"])

    def journal_rewrite(self, records: Sequence[Dict]) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
            f.flush()
            os.fsync(f.fileno())
        crash_point("localdir.journal.rewrite_staged")
        os.replace(tmp, self._journal_path())
        crash_point("localdir.journal.rewritten")

    def sweep_temp(self) -> int:
        n = 0
        for name in os.listdir(self.path):
            if name.endswith(".tmp"):            # mkstemp staging debris
                os.remove(os.path.join(self.path, name))
                n += 1
        return n
