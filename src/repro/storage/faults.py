"""Seeded fault injection + the typed recovery contract (DESIGN.md §8).

The paper's serving tier inherits the database's durability story; ours
assumed every ``get_pages`` and commit was perfect.  This module supplies
both halves of the missing fault model:

  * :class:`FaultInjectingBackend` — a composable wrapper (URL spelling
    ``fault+<inner-url>#<spec>``, resolved by ``open_backend``) that
    injects faults from a *seeded* schedule so chaos runs are exactly
    reproducible: transient read/write errors, bit-flip page corruption,
    latency spikes, ``database is locked`` contention, and torn commits
    (the write lands, the ack is lost).
  * The error taxonomy the recovery layer is typed against:
    :class:`TransientStorageError` (retry), :class:`CorruptPageError`
    (quarantine + refetch), :class:`FatalStorageError` (give up).
  * :class:`RetryPolicy` — bounded retries with exponential backoff and
    seeded jitter.  Backoff is *virtual*: no real sleeps — the seconds
    are returned to the caller and charged on the serving virtual clock
    as a named channel, so BENCH numbers stay honest under chaos.

``spec.max_consecutive`` caps the number of consecutive injections per
fault kind; after the cap the next operation is forced to succeed.  This
makes every bounded-retry loop convergent by construction, which is what
lets the chaos tests demand *bit-exact* logits at 10% injection rates.
"""
from __future__ import annotations

import dataclasses
import os
import sqlite3
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from .backend import _BENCH_PREFIX, ManifestConflictError, PageBackend

__all__ = [
    "StorageFaultError", "TransientStorageError", "CorruptPageError",
    "FatalStorageError", "is_transient", "FaultSpec",
    "FaultInjectingBackend", "RetryPolicy", "RetryOutcome",
    "RecoveryStats", "global_fault_spec", "set_global_fault_spec",
    "maybe_wrap", "fault_layer",
]


# ------------------------------------------------------------- taxonomy --
class StorageFaultError(RuntimeError):
    """Base of the storage fault taxonomy.  Subclasses tell the recovery
    layer what to do; anything else escaping a backend is a bug."""


class TransientStorageError(StorageFaultError):
    """The operation may succeed if simply retried (dropped connection,
    lost ack, scheduler hiccup).  :class:`RetryPolicy` retries these."""


class CorruptPageError(StorageFaultError):
    """A fetched page's bytes do not hash to its content address.  The
    page is quarantined and re-fetched as its own grouped call; this
    error surfaces only when refetching cannot produce clean bytes."""


class FatalStorageError(StorageFaultError):
    """Retries/backoff budget exhausted, or a non-recoverable backend
    condition.  Callers should degrade (host fallback) or abort."""


def is_transient(exc: BaseException) -> bool:
    """Classify an exception as retry-worthy.  ``database is locked`` is
    the canonical transient SQLite condition (another writer holds the
    reservation); :class:`ManifestConflictError` is *never* transient —
    it means the manifest moved and the caller must re-read and re-apply,
    not blindly re-commit."""
    if isinstance(exc, ManifestConflictError):
        return False
    if isinstance(exc, TransientStorageError):
        return True
    return (isinstance(exc, sqlite3.OperationalError)
            and "locked" in str(exc).lower())


# ------------------------------------------------------------ fault spec --
_FLOAT_FIELDS = ("transient", "corrupt", "lock", "torn", "latency",
                 "latency_ms")
_INT_FIELDS = ("seed", "max_consecutive")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded fault schedule.  Rates are per-opportunity probabilities
    drawn from one ``default_rng(seed)`` stream, so for a fixed call
    sequence the schedule is exactly reproducible."""
    transient: float = 0.0       # P(transient error) per read/write op
    corrupt: float = 0.0         # P(bit flip) per fetched page
    lock: float = 0.0            # P("database is locked") per commit
    torn: float = 0.0            # P(commit lands but ack lost)
    latency: float = 0.0         # P(latency spike) per read/write op
    latency_ms: float = 5.0      # spike magnitude (virtual milliseconds)
    seed: int = 0
    max_consecutive: int = 2     # forced success after this many in a row

    @classmethod
    def parse(cls, text: "str | FaultSpec | None") -> "FaultSpec":
        """``"transient=0.1,corrupt=0.05,seed=7"`` -> FaultSpec.  The
        empty string parses to the all-zero (no-fault) spec."""
        if isinstance(text, FaultSpec):
            return text
        kw = {}
        for part in (text or "").split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"bad fault spec item {part!r} "
                                 "(expected key=value)")
            k, v = part.split("=", 1)
            k = k.strip()
            if k in _FLOAT_FIELDS:
                kw[k] = float(v)
            elif k in _INT_FIELDS:
                kw[k] = int(v)
            else:
                raise ValueError(
                    f"unknown fault spec key {k!r} (expected one of "
                    f"{_FLOAT_FIELDS + _INT_FIELDS})")
        return cls(**kw)

    def __str__(self) -> str:
        default = FaultSpec()
        items = [f"{f.name}={getattr(self, f.name)}"
                 for f in dataclasses.fields(self)
                 if getattr(self, f.name) != getattr(default, f.name)]
        return ",".join(items)

    def any_faults(self) -> bool:
        return any(getattr(self, f) > 0 for f in
                   ("transient", "corrupt", "lock", "torn", "latency"))


# ------------------------------------------------------- global chaos hook --
_GLOBAL_SPEC: Optional[FaultSpec] = None


def set_global_fault_spec(spec: "str | FaultSpec | None") -> None:
    """Programmatic override of the ``REPRO_FAULTS`` env spec (tests)."""
    global _GLOBAL_SPEC
    _GLOBAL_SPEC = None if spec is None else FaultSpec.parse(spec)


def global_fault_spec() -> Optional[FaultSpec]:
    """The chaos-mode spec: a programmatic override if set, else the
    ``REPRO_FAULTS`` environment variable, else None."""
    if _GLOBAL_SPEC is not None:
        return _GLOBAL_SPEC
    env = os.environ.get("REPRO_FAULTS", "")
    return FaultSpec.parse(env) if env else None


def maybe_wrap(backend: PageBackend) -> PageBackend:
    """Wrap ``backend`` in a :class:`FaultInjectingBackend` when chaos
    mode is on (and it is not already wrapped).  Applied by ModelStore /
    DedupDB at their *URL-resolution* attach points only, so tests that
    construct a backend instance directly keep their exact call-count
    assertions."""
    spec = global_fault_spec()
    if spec is None or not spec.any_faults() \
            or isinstance(backend, FaultInjectingBackend):
        return backend
    return FaultInjectingBackend(backend, spec)


def fault_layer(backend) -> Optional["FaultInjectingBackend"]:
    """The FaultInjectingBackend in a wrapper chain, if any (walks
    ``.inner`` links so ``fault+objsim://`` compositions resolve too)."""
    seen = 0
    while backend is not None and seen < 8:
        if isinstance(backend, FaultInjectingBackend):
            return backend
        backend = getattr(backend, "inner", None)
        seen += 1
    return None


# --------------------------------------------------------------- injector --
def _flip_bit(arr: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """One random bit flip on a *copy* — the inner store stays clean, so
    a quarantine refetch observes the true bytes."""
    out = np.array(arr, copy=True)
    buf = out.view(np.uint8).reshape(-1)
    i = int(rng.integers(buf.size))
    buf[i] ^= np.uint8(1 << int(rng.integers(8)))
    return out


class FaultInjectingBackend(PageBackend):
    """Composable fault-injecting wrapper around any :class:`PageBackend`.

    Injection draws come from one seeded stream in call order, so a run
    with the same traffic sees the same schedule.  Microbench scratch
    pages (``zbench-`` prefix) are exempt: calibration is not traffic.
    Latency spikes never sleep — they accumulate in a drainable counter
    that the recovery layer charges to the serving virtual clock.
    """

    scheme = "fault"

    def __init__(self, inner: PageBackend,
                 spec: "str | FaultSpec | None" = None):
        self.inner = inner
        self.spec = FaultSpec.parse(spec)
        self._rng = np.random.default_rng(self.spec.seed)
        self._consecutive: Dict[str, int] = {}
        #: injected-fault counts by kind (observability + test assertions)
        self.injected: Dict[str, int] = {}
        self._injected_latency_s = 0.0

    # ------------------------------------------------------------ schedule --
    def _draw(self, rate: float) -> bool:
        """One seeded schedule draw."""
        return rate > 0 and float(self._rng.random()) < rate

    def _forced_ok(self, op: str) -> bool:
        """True when ``op`` has failed ``max_consecutive`` times in a
        row: this call is forced to succeed cleanly, ending the run.
        The guard is per *operation* (a commit that alternates lock /
        transient / torn failures still converges), which is what makes
        every bounded-retry loop convergent by construction."""
        run = self._consecutive.get(op, 0)
        if self.spec.max_consecutive > 0 \
                and run >= self.spec.max_consecutive:
            self._consecutive[op] = 0
            return True
        return False

    def _fail(self, op: str, kind: str, exc: Exception):
        self._consecutive[op] = self._consecutive.get(op, 0) + 1
        self.injected[kind] = self.injected.get(kind, 0) + 1
        raise exc

    def _ok(self, op: str) -> None:
        self._consecutive[op] = 0

    def _maybe_latency(self) -> None:
        if self._draw(self.spec.latency):
            self.injected["latency"] = self.injected.get("latency", 0) + 1
            self._injected_latency_s += self.spec.latency_ms * 1e-3

    def drain_injected_latency(self) -> float:
        """Seconds of injected latency since the last drain (charged by
        the recovery layer on the virtual clock, never slept)."""
        s, self._injected_latency_s = self._injected_latency_s, 0.0
        return s

    # --------------------------------------------------------------- pages --
    def put_pages(self, pages: Mapping[str, np.ndarray]) -> int:
        real = any(not h.startswith(_BENCH_PREFIX) for h in pages)
        if real and not self._forced_ok("put"):
            self._maybe_latency()
            if self._draw(self.spec.transient):
                self._fail("put", "transient", TransientStorageError(
                    f"injected transient write error ({len(pages)} pages)"))
        n = self.inner.put_pages(pages)
        if real:
            self._ok("put")
        return n

    def get_pages(self, hashes: Sequence[str]) -> Dict[str, np.ndarray]:
        real = [h for h in hashes if not h.startswith(_BENCH_PREFIX)]
        inject = bool(real) and not self._forced_ok("get")
        if inject:
            self._maybe_latency()
            if self._draw(self.spec.transient):
                self._fail("get", "transient", TransientStorageError(
                    f"injected transient read error ({len(real)} pages)"))
        got = self.inner.get_pages(hashes)
        flipped = 0
        if inject and self.spec.corrupt > 0:
            for h in real:
                if self._draw(self.spec.corrupt):
                    got[h] = _flip_bit(np.asarray(got[h]), self._rng)
                    flipped += 1
        if flipped:
            # a corrupted batch counts as a failed get: the quarantine
            # refetch that follows is then guaranteed a clean batch
            # within max_consecutive rounds
            self.injected["corrupt"] = \
                self.injected.get("corrupt", 0) + flipped
            self._consecutive["get"] = self._consecutive.get("get", 0) + 1
        elif real:
            self._ok("get")
        return got

    def list_pages(self):
        return self.inner.list_pages()

    def delete_pages(self, hashes: Sequence[str]) -> int:
        return self.inner.delete_pages(hashes)

    # ------------------------------------------------------------- journal --
    # The intent journal is the recovery layer's own bookkeeping: faults
    # are never injected into it (a durability layer that corrupts its
    # undo log proves nothing), so all primitives delegate verbatim.
    def journal_append(self, record: Dict) -> int:
        return self.inner.journal_append(record)

    def journal_records(self) -> List[Dict]:
        return self.inner.journal_records()

    def journal_rewrite(self, records: Sequence[Dict]) -> None:
        self.inner.journal_rewrite(records)

    def sweep_temp(self) -> int:
        return self.inner.sweep_temp()

    # ------------------------------------------------------------ manifest --
    def commit_manifest(self, manifest: Dict) -> None:
        if self._forced_ok("commit"):
            return self.inner.commit_manifest(manifest)
        if self._draw(self.spec.lock):
            # raw, exactly as sqlite3 surfaces it, so the classifier in
            # the retry layer (not this wrapper) does the typing
            self._fail("commit", "lock",
                       sqlite3.OperationalError("database is locked"))
        if self._draw(self.spec.transient):
            self._fail("commit", "transient",
                       TransientStorageError("injected transient commit"))
        self.inner.commit_manifest(manifest)
        if self._draw(self.spec.torn):
            # torn commit: the write landed but the ack was lost.  The
            # retry that follows must be idempotent (all backends are:
            # content-addressed puts + versioned manifest replace).
            self._fail("commit", "torn",
                       TransientStorageError("injected torn commit "
                                             "(ack lost)"))
        self._ok("commit")

    def load_manifest(self) -> Dict:
        if not self._forced_ok("load"):
            self._maybe_latency()
            if self._draw(self.spec.transient):
                self._fail("load", "transient", TransientStorageError(
                    "injected transient manifest read"))
        out = self.inner.load_manifest()
        self._ok("load")
        return out

    def has_manifest(self) -> bool:
        return self.inner.has_manifest()

    # --------------------------------------------------------------- admin --
    def url(self) -> str:
        return f"fault+{self.inner.url()}#{self.spec}"

    def close(self) -> None:
        self.inner.close()

    def microbench(self, *a, **kw):
        # calibration reads the *inner* tier's characteristics; fault
        # overhead is charged separately (backoff/latency channels)
        return self.inner.microbench(*a, **kw)


# ------------------------------------------------------------ retry policy --
@dataclasses.dataclass
class RetryOutcome:
    """What one recovered call cost: retry count + virtual backoff."""
    retries: int = 0
    backoff_seconds: float = 0.0


@dataclasses.dataclass
class RecoveryStats:
    """Accumulator the store-level recovery layer maintains; serving
    tiers snapshot-diff it per batch into their ServeStats."""
    retries: int = 0
    corrupt_detected: int = 0
    refetch_pages: int = 0
    backoff_seconds: float = 0.0
    latency_seconds: float = 0.0

    def snapshot(self) -> "RecoveryStats":
        return dataclasses.replace(self)

    def since(self, prev: "RecoveryStats") -> "RecoveryStats":
        return RecoveryStats(
            retries=self.retries - prev.retries,
            corrupt_detected=self.corrupt_detected - prev.corrupt_detected,
            refetch_pages=self.refetch_pages - prev.refetch_pages,
            backoff_seconds=self.backoff_seconds - prev.backoff_seconds,
            latency_seconds=self.latency_seconds - prev.latency_seconds)

    def register_into(self, registry, namespace: str = "recovery") -> None:
        """Expose every field as a live metric view in a
        :class:`~repro.obs.metrics.MetricsRegistry` (duck-typed so this
        numpy-only layer never imports the obs package)."""
        registry.register_object(
            namespace, self, [f.name for f in dataclasses.fields(self)])


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retries with exponential backoff + seeded jitter.

    Backoff never sleeps: accumulated seconds come back in the
    :class:`RetryOutcome` and are charged on the serving virtual clock
    as a named channel.  ``call_timeout`` caps the *virtual* backoff
    budget of one logical call — past it the call is fatal even if
    retries remain, mirroring a real per-request deadline.
    """
    max_retries: int = 4
    backoff_base: float = 0.002       # seconds (virtual)
    backoff_multiplier: float = 2.0
    jitter: float = 0.25              # +[0, jitter) fraction per step
    call_timeout: float = 1.0         # virtual-seconds budget per call
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def run(self, fn, describe: str = "storage call"):
        """``fn()`` with bounded retries on transient errors.  Returns
        ``(result, RetryOutcome)``.  Non-transient errors (including
        ManifestConflictError) propagate untouched; exhausting the retry
        or backoff budget raises :class:`FatalStorageError` chained to
        the last transient cause."""
        backoff = 0.0
        attempt = 0
        while True:
            try:
                return fn(), RetryOutcome(attempt, backoff)
            except Exception as exc:
                if not is_transient(exc):
                    raise
                attempt += 1
                if attempt > self.max_retries:
                    fatal = FatalStorageError(
                        f"{describe}: {self.max_retries} retries exhausted")
                    # the spent budget rides on the error so callers can
                    # still charge it to their RecoveryStats
                    fatal.outcome = RetryOutcome(attempt - 1, backoff)
                    raise fatal from exc
                step = self.backoff_base * \
                    self.backoff_multiplier ** (attempt - 1)
                step *= 1.0 + self.jitter * float(self._rng.random())
                backoff += step
                if backoff > self.call_timeout:
                    fatal = FatalStorageError(
                        f"{describe}: virtual backoff budget "
                        f"({self.call_timeout}s) exceeded")
                    fatal.outcome = RetryOutcome(attempt - 1, backoff)
                    raise fatal from exc
