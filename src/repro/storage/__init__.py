"""Pluggable page-storage backends for the deduplicated model store.

``open_backend(url)`` resolves a storage URL to a :class:`PageBackend`:

===========  =========================================================
URL                                        backend
===========  =========================================================
``file:///abs/dir`` or bare path           :class:`LocalDirBackend`
``sqlite:///rel.db``, ``sqlite:////abs.db``  :class:`SQLiteBackend`
``objsim://[dir][?seek_ms=&bandwidth_mbps=]``  :class:`ObjectStoreSimBackend`
``memory://``                              :class:`MemoryBackend`
``fault+<inner-url>#<spec>``               :class:`FaultInjectingBackend`
===========  =========================================================

SQLite paths follow the SQLAlchemy convention: three slashes for a
relative path, four for an absolute one.  ``objsim://`` with a path
wraps a local directory backend; without one it wraps an in-memory
store (tests / benchmarks).  Bare strings with no scheme are treated as
local directories — the back-compat shim for the historical
``ModelStore.save(path)`` call sites.
"""
from __future__ import annotations

from urllib.parse import parse_qs, urlparse

from .backend import (MANIFEST_VERSION, ManifestConflictError,
                      MemoryBackend, PageBackend, StorageProfile,
                      resolve_dtype)
from .crashpoints import CrashPointReached, crash_point
from .faults import (CorruptPageError, FatalStorageError,
                     FaultInjectingBackend, FaultSpec, RetryPolicy,
                     StorageFaultError, TransientStorageError)
from .journal import Journal, RecoveryReport, recover_backend
from .localdir import LocalDirBackend
from .objsim import ObjectStoreSimBackend
from .sqlite import SQLiteBackend

__all__ = [
    "MANIFEST_VERSION", "ManifestConflictError", "MemoryBackend",
    "PageBackend", "StorageProfile", "resolve_dtype",
    "LocalDirBackend", "SQLiteBackend", "ObjectStoreSimBackend",
    "FaultInjectingBackend", "FaultSpec", "RetryPolicy",
    "StorageFaultError", "TransientStorageError", "CorruptPageError",
    "FatalStorageError", "CrashPointReached", "crash_point",
    "Journal", "RecoveryReport", "recover_backend",
    "open_backend",
]


def _sqlalchemy_path(rest: str) -> str:
    """``sqlite:///foo.db`` -> ``foo.db``; ``sqlite:////abs/foo.db`` ->
    ``/abs/foo.db`` (strip exactly one leading slash)."""
    return rest[1:] if rest.startswith("/") else rest


def _recovered(backend: PageBackend) -> PageBackend:
    """Journal replay at the URL attach point (DESIGN.md §11): a store a
    crashed writer left dirty is GC'd before anything reads it.  Clean
    journals make this a single cheap read."""
    recover_backend(backend)
    return backend


def open_backend(url) -> PageBackend:
    """Resolve a storage URL (or bare directory path, or an already-open
    backend) to a :class:`PageBackend`, replaying any crash-recovery
    journal the previous writer left behind."""
    if isinstance(url, PageBackend):
        return url
    url = str(url)
    if url.startswith("fault+"):
        # fault-injection composition: fault+<inner-url>#<spec>, e.g.
        # fault+sqlite:///m.db#transient=0.1,corrupt=0.05,seed=7 — the
        # spec rides in the fragment so inner query strings stay intact
        # (the inner open_backend already ran recovery on the real store)
        inner_url, _, spec = url[len("fault+"):].partition("#")
        return FaultInjectingBackend(open_backend(inner_url),
                                     FaultSpec.parse(spec))
    if "://" not in url:                       # bare path: legacy call sites
        return _recovered(LocalDirBackend(url))
    scheme, rest = url.split("://", 1)
    scheme = scheme.lower()
    if scheme == "file":
        # standard file URL: the path component is absolute
        parsed = urlparse(url)
        return _recovered(LocalDirBackend((parsed.netloc or "") + parsed.path))
    if scheme == "sqlite":
        return _recovered(
            SQLiteBackend(_sqlalchemy_path(rest.split("?", 1)[0])))
    if scheme == "memory":
        return MemoryBackend()
    if scheme == "objsim":
        path, _, query = rest.partition("?")
        params = parse_qs(query)
        kw = {}
        if "seek_ms" in params:
            kw["seek"] = float(params["seek_ms"][0]) * 1e-3
        if "bandwidth_mbps" in params:
            kw["bandwidth"] = float(params["bandwidth_mbps"][0]) * 1e6
        if not path:
            inner = None                       # in-memory inner store
        elif path.endswith((".db", ".sqlite")):
            inner = _recovered(SQLiteBackend(path))
        else:
            inner = _recovered(LocalDirBackend(path))
        return ObjectStoreSimBackend(inner, **kw)
    raise ValueError(f"unknown storage URL scheme {scheme!r} in {url!r} "
                     "(expected file | sqlite | objsim | memory)")
