"""Named crash seams + the exhaustive kill-at-every-seam sweep (DESIGN.md §11).

Durability claims are only as good as the set of instants they were
tested at.  Instead of sampling chaos, every multi-step mutation in the
storage layer threads through *named crash points* — one per durable
seam (after each page write, before/after the manifest rename or
COMMIT, mid-prune, mid-journal-truncate).  The registry is populated at
import time, so the sweep can enumerate every seam without executing
anything; :func:`crash_point` is a no-op unless armed.

Arming:

  * ``REPRO_CRASH_POINT=<name>`` (+ ``REPRO_CRASH_MODE=kill|raise``) —
    the subprocess sweep: the armed process SIGKILLs itself the first
    time it reaches the seam, exactly like a power cut mid-syscall.
  * :func:`armed` — an in-process context manager for unit tests;
    ``mode="raise"`` raises :class:`CrashPointReached` instead of
    killing, so a single test can crash an operation and then assert on
    the wreckage.

The harness half of this module (``run_sweep`` / ``python -m
repro.storage.crashpoints --sweep``) runs a scripted store mutation in
a subprocess armed at seam *k*, confirms the process died by SIGKILL,
reopens the store (which replays the intent journal,
``storage/journal.py``), and asserts the recovery invariants: manifest
readable, zero orphan pages, zero temp files, empty journal, and
logits bit-exact against one of the two never-crashed runs (the state
before or after the atomic commit point — nothing else is legal).
Swept **exhaustively over every registered seam**: a registered seam
no scenario reaches fails the sweep.
"""
from __future__ import annotations

import contextlib
import json
import os
import signal
import subprocess
import sys
import tempfile
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "CrashPointReached", "register_crash_points", "crash_point",
    "armed", "all_crash_points", "prime_store", "mutate_store",
    "serve_logits", "check_recovered", "run_sweep", "main",
    "ENV_POINT", "ENV_MODE",
]

ENV_POINT = "REPRO_CRASH_POINT"
ENV_MODE = "REPRO_CRASH_MODE"          # "kill" (default) | "raise"

#: name -> human description; populated by register_crash_points() at
#: import time of the module hosting the seam, so enumeration never
#: requires execution
_REGISTRY: Dict[str, str] = {}

#: programmatic arming (tests): (seam name, mode); checked before the
#: environment so an in-process `armed()` block shadows a sweep env
_ARMED: Optional[Tuple[str, str]] = None


class CrashPointReached(RuntimeError):
    """Raised by an armed crash point in ``raise`` mode — the in-process
    stand-in for SIGKILL that unit tests can catch and assert after."""


def register_crash_points(points: Dict[str, str]) -> None:
    """Register named seams (import time).  Re-registration with the
    same description is idempotent; a name collision with a different
    description is a bug in the caller."""
    for name, desc in points.items():
        old = _REGISTRY.get(name)
        if old is not None and old != desc:
            raise ValueError(f"crash point {name!r} already registered "
                             f"with a different description")
        _REGISTRY[name] = desc


def all_crash_points() -> Dict[str, str]:
    """Every registered seam.  Imports the host modules first so the
    registry is complete even if nothing touched storage yet."""
    import repro.core.store          # noqa: F401  (store.save.* seams)
    import repro.storage.journal     # noqa: F401  (recover.* seams)
    import repro.storage.localdir    # noqa: F401
    import repro.storage.sqlite      # noqa: F401
    return dict(_REGISTRY)


def crash_point(name: str) -> None:
    """Mark a durable seam.  No-op unless this exact seam is armed;
    unregistered names are a hard error so the registry stays the
    single exhaustive source of truth for the sweep."""
    if name not in _REGISTRY:
        raise RuntimeError(f"crash_point({name!r}) is not registered; "
                           "add it to the module's register_crash_points()")
    target = _ARMED
    if target is None:
        env = os.environ.get(ENV_POINT)
        if not env:
            return
        target = (env, os.environ.get(ENV_MODE, "kill"))
    if target[0] != name:
        return
    if target[1] == "raise":
        raise CrashPointReached(name)
    # the real thing: no atexit, no finally, no flush — the next
    # instruction never runs, exactly like a power cut
    os.kill(os.getpid(), signal.SIGKILL)


@contextlib.contextmanager
def armed(name: str, mode: str = "raise"):
    """Arm one seam for the duration of a with-block (tests)."""
    global _ARMED
    if name not in all_crash_points():
        raise ValueError(f"unknown crash point {name!r}")
    prev, _ARMED = _ARMED, (name, mode)
    try:
        yield
    finally:
        _ARMED = prev


# ======================================================================
# The scripted store operation the sweep kills at every seam.
#
# Numpy + the core store only (no jax): subprocess startup stays cheap
# enough to afford one process per (seam, backend-kind) pair.
# ======================================================================
def _store_config():
    from ..core import DedupConfig, LSHConfig, StoreConfig
    return StoreConfig(
        dedup=DedupConfig(block_shape=(32, 32),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=4.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=4)


def _model_tensors(extra: bool = False):
    import numpy as np
    rng = np.random.default_rng(7)
    base = (rng.standard_normal((64, 64)) * 0.05).astype(np.float32)
    out = {"m0": {"w": base.copy()},
           "m1": {"w": (base + np.float32(1e-3)).astype(np.float32)}}
    if extra:
        # dissimilar weights: the repack renames/extends the page set,
        # so the save both writes fresh pages AND prunes orphans
        rng2 = np.random.default_rng(42)
        out["m2"] = {"w": rng2.standard_normal((64, 64))
                     .astype(np.float32)}
    return out


def prime_store(url: str) -> None:
    """Committed baseline: two deduplicating variants saved cleanly."""
    from ..core.store import ModelStore
    store = ModelStore(_store_config())
    for name, tensors in _model_tensors().items():
        store.register(name, tensors)
    store.save(url)


def mutate_store(url: str) -> None:
    """The swept operation: overwrite the primed store with the next
    packing generation — m1's weights revised and a third dissimilar
    model added — so the save writes fresh pages, commits a manifest
    referencing a different page set, AND prunes the primed
    generation's orphans.  Every storage seam fires."""
    import numpy as np

    from ..core.store import ModelStore
    store = ModelStore(_store_config())
    tensors = _model_tensors(extra=True)
    for t in tensors.values():
        # revise every model: no page of the primed generation survives
        # content-addressing, so the prune has real orphans to collect
        t["w"] = (t["w"] * np.float32(1.5)).astype(np.float32)
    for name, t in tensors.items():
        store.register(name, t)
    store.save(url)


def serve_logits(url: str):
    """Deterministic 'serving' probe: a fixed seeded input against every
    model's materialized weights, concatenated.  Bit-exact iff the
    recovered store state is bit-exact."""
    import numpy as np

    from ..core.store import ModelStore
    store = ModelStore.open(url)
    probe = np.random.default_rng(3).standard_normal((8, 64)) \
        .astype(np.float32)
    outs = [probe @ store.materialize(m, "w")
            for m in sorted(store.dedup.models)]
    return np.concatenate([o.reshape(-1) for o in outs])


#: seams strictly AFTER the manifest's atomic commit point: recovery
#: must land on the mutated state (golden B); everything else must
#: roll back to the primed state (golden A)
_POST_COMMIT_SEAMS = frozenset({
    "localdir.commit_manifest.committed",
    "localdir.delete_pages.mid",
    "localdir.journal.rewrite_staged",
    "localdir.journal.rewritten",
    "sqlite.commit_manifest.committed",
    "sqlite.delete_pages.staged",
    "sqlite.journal.rewrite_staged",
    "store.save.manifest_committed",
    "store.save.pruned",
    "recover.gc_journaled",
    "recover.gc_done",
})


def _kinds_for(seam: str) -> Tuple[str, ...]:
    if seam.startswith("localdir."):
        return ("file",)
    if seam.startswith("sqlite."):
        return ("sqlite",)
    return ("file", "sqlite")        # store.save.* / recover.* seams


def _url_for(kind: str, base: str) -> str:
    if kind == "file":
        return f"file://{os.path.join(base, 'store')}"
    return f"sqlite:///{os.path.join(base, 'store.db')}"


def check_recovered(url: str, golden_a, golden_b,
                    expect: Optional[str] = None) -> List[str]:
    """Recovery invariants after a kill; returns human-readable
    violations (empty = clean).  ``expect`` pins which golden the
    recovered store must equal ('a' | 'b' | None = either)."""
    import numpy as np

    from . import open_backend
    backend = open_backend(url)       # replays the journal on open
    problems: List[str] = []
    try:
        if backend.journal_records():
            problems.append("journal not empty after recovery")
        if backend.sweep_temp() != 0:
            problems.append("temp files survived recovery")
        try:
            manifest = backend.load_manifest()
        except FileNotFoundError:
            problems.append("manifest unreadable after recovery")
            return problems
        refs = {p["hash"] for p in manifest["pages"]}
        stored = set(backend.list_pages())
        if stored - refs:
            problems.append(f"{len(stored - refs)} orphan page(s) "
                            "survived recovery")
        if refs - stored:
            problems.append(f"{len(refs - stored)} referenced page(s) "
                            "missing after recovery")
    finally:
        backend.close()
    logits = serve_logits(url)
    is_a = bool(np.array_equal(logits, golden_a))
    is_b = bool(np.array_equal(logits, golden_b))
    if not (is_a or is_b):
        problems.append("recovered logits match neither the pre- nor "
                        "the post-commit never-crashed run")
    elif expect == "a" and not is_a:
        problems.append("recovered to the post-commit state where the "
                        "commit point was never reached")
    elif expect == "b" and not is_b:
        problems.append("recovered to the pre-commit state after the "
                        "commit point had landed")
    return problems


def _golden(kind: str, base: str):
    """(golden_a, golden_b) for one backend kind: logits of the primed
    store and of the cleanly mutated store, never crashed."""
    gdir = os.path.join(base, f"golden-{kind}")
    os.makedirs(gdir, exist_ok=True)
    url = _url_for(kind, gdir)
    prime_store(url)
    golden_a = serve_logits(url)
    mutate_store(url)
    golden_b = serve_logits(url)
    return golden_a, golden_b


def _src_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _sweep_one(seam: str, kind: str, base: str, golden) -> Dict:
    """Kill one subprocess at ``seam`` against a ``kind`` store, then
    recover in-process and check every invariant."""
    workdir = os.path.join(base, f"{seam.replace('.', '_')}-{kind}")
    os.makedirs(workdir, exist_ok=True)
    url = _url_for(kind, workdir)
    prime_store(url)
    cmd = [sys.executable, "-m", "repro.storage.crashpoints",
           "--op", "mutate", "--url", url]
    if seam.startswith("recover."):
        # recovery seams only fire while replaying a dirty journal: the
        # driver first crashes a save in-process (raise mode) to leave
        # one behind, then reopens — and the env-armed kill lands there
        cmd += ["--prime-crash", "store.save.manifest_committed"]
    env = dict(os.environ)
    env[ENV_POINT] = seam
    env[ENV_MODE] = "kill"
    env["PYTHONPATH"] = _src_root() + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    triggered = proc.returncode == -signal.SIGKILL
    result = {"seam": seam, "kind": kind, "triggered": triggered,
              "returncode": proc.returncode, "problems": []}
    if not triggered:
        tail = (proc.stderr or proc.stdout or "").strip().splitlines()
        result["problems"] = [
            f"seam never reached (exit {proc.returncode}"
            + (f": {tail[-1]}" if tail else "") + ")"]
        result["ok"] = False
        return result
    expect = "b" if seam in _POST_COMMIT_SEAMS else "a"
    result["problems"] = check_recovered(url, *golden, expect=expect)
    result["ok"] = not result["problems"]
    return result


def run_sweep(seams: Optional[Iterable[str]] = None,
              base_dir: Optional[str] = None,
              verbose=None) -> List[Dict]:
    """The exhaustive sweep: every registered seam (or ``seams``) is
    killed at least once; each kill is recovered and invariant-checked.
    Returns one result dict per (seam, kind) run."""
    registry = all_crash_points()
    chosen = sorted(seams) if seams is not None else sorted(registry)
    unknown = [s for s in chosen if s not in registry]
    if unknown:
        raise ValueError(f"unknown crash point(s): {unknown}")
    results: List[Dict] = []
    with contextlib.ExitStack() as stack:
        if base_dir is None:
            base_dir = stack.enter_context(
                tempfile.TemporaryDirectory(prefix="crash-sweep-"))
        golden = {kind: _golden(kind, base_dir)
                  for kind in ("file", "sqlite")}
        for seam in chosen:
            for kind in _kinds_for(seam):
                res = _sweep_one(seam, kind, base_dir, golden[kind])
                results.append(res)
                if verbose:
                    status = "ok" if res["ok"] else \
                        "FAIL: " + "; ".join(res["problems"])
                    verbose(f"[crash-sweep] {seam} ({kind}): {status}")
    return results


def main(argv=None) -> int:
    """CLI: ``--sweep`` (exhaustive), ``--list``, or one ``--op`` (the
    subprocess entry point the sweep arms and kills)."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="run the exhaustive kill-at-every-seam sweep")
    ap.add_argument("--list", action="store_true",
                    help="list registered crash points and exit")
    ap.add_argument("--op", choices=("prime", "mutate", "logits"),
                    help="run one scripted store operation (the sweep "
                         "subprocess entry point)")
    ap.add_argument("--url", help="storage URL for --op")
    ap.add_argument("--prime-crash", default=None, metavar="SEAM",
                    help="before --op mutate: crash a save at SEAM "
                         "in-process (raise mode) to leave a dirty "
                         "journal, then reopen — reaches the recover.* "
                         "seams")
    args = ap.parse_args(argv)

    if args.list:
        for name, desc in sorted(all_crash_points().items()):
            print(f"{name:<40} {desc}")
        return 0
    if args.sweep:
        results = run_sweep(verbose=print)
        failed = [r for r in results if not r["ok"]]
        swept = {r["seam"] for r in results if r["triggered"]}
        missed = sorted(set(all_crash_points()) - swept)
        print(f"[crash-sweep] {len(results)} kills over "
              f"{len(set(r['seam'] for r in results))} seams: "
              f"{len(failed)} failure(s), {len(missed)} unreached")
        if missed:
            print(f"[crash-sweep] UNREACHED seams: {missed}")
        return 1 if failed or missed else 0
    if args.op:
        if not args.url:
            ap.error("--op requires --url")
        if args.op == "prime":
            prime_store(args.url)
        elif args.op == "mutate":
            if args.prime_crash:
                all_crash_points()      # registry must be loaded first
                try:
                    with armed(args.prime_crash, mode="raise"):
                        mutate_store(args.url)
                except CrashPointReached:
                    pass                # the dirty journal we wanted
                from ..core.store import ModelStore
                ModelStore.open(args.url)    # recovery replays here
            else:
                mutate_store(args.url)
        else:
            print(json.dumps(serve_logits(args.url).tolist()))
        return 0
    ap.error("choose one of --sweep / --list / --op")
    return 2


if __name__ == "__main__":
    # `python -m` executes this file as a SECOND module object named
    # __main__; delegate to the canonical import so the registry (and
    # any armed seam) is the same one the storage modules populate.
    from repro.storage import crashpoints as _canonical
    raise SystemExit(_canonical.main())
