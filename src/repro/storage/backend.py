"""PageBackend: the pluggable persistence API under ModelStore (DESIGN.md §4).

The paper's thesis is that deduplicated models live *in a database*: the
page — not the tensor — is the unit of storage, keyed by content hash.
``PageBackend`` is that contract.  A backend stores opaque page arrays
(``[blocks_per_page, bh, bw]`` in the store's native page dtype) under
content hashes, plus one manifest (the relational metadata: models →
tensors → block maps → pages) committed atomically/transactionally.

Implementations in this package:

  * :class:`~repro.storage.localdir.LocalDirBackend` — content-addressed
    ``page-<hash>.npy`` files + ``manifest.json`` (the historical
    ``ModelStore.save(path)`` format, unchanged on disk).
  * :class:`~repro.storage.sqlite.SQLiteBackend` — pages as BLOB rows and
    the manifest as proper relational tables (``models`` / ``tensors`` /
    ``manifest_pages`` / ``tensor_pages``) committed in one transaction:
    the paper's native habitat, stdlib-only.
  * :class:`~repro.storage.objsim.ObjectStoreSimBackend` — latency/
    bandwidth-injected wrapper simulating a remote object store (the
    fig-8 "working set exceeds the pool" regime).
  * :class:`MemoryBackend` (here) — dict-backed, for tests and as the
    default inner store of the object-store simulator.

``microbench()`` measures the backend's grouped-fetch characteristics and
returns a :class:`StorageProfile` (bandwidth, seek) that calibrates the
serving engine's :class:`~repro.serving.engine.StorageModel` virtual
clock — replacing the hardcoded hdd/ssd/nvme presets with numbers from
the tier actually serving the pages.
"""
from __future__ import annotations

import abc
import dataclasses
import time
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

MANIFEST_VERSION = 2

#: reserved hash prefix for microbench scratch pages (never collides with
#: real content hashes, which are hex)
_BENCH_PREFIX = "zbench-"


def resolve_dtype(name) -> np.dtype:
    """np.dtype lookup that also resolves ml_dtypes extras (bfloat16)
    when numpy alone doesn't know the name."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, str(name)))


class ManifestConflictError(RuntimeError):
    """Optimistic-locking conflict: another writer committed a manifest
    after this handle last observed one.  The stale writer's transaction
    is rolled back; reload the manifest (``load_manifest``) to adopt the
    winner's state, re-apply the mutation, and commit again."""


@dataclasses.dataclass(frozen=True)
class StorageProfile:
    """Calibrated fetch model of a backend: ``seek + nbytes / bandwidth``."""
    backend: str                 # scheme/name of the measured backend
    bandwidth: float             # sustained grouped-read bytes/second
    seek: float                  # per-request fixed overhead, seconds
    page_bytes: int = 0          # page size the calibration used

    def fetch_seconds(self, nbytes: int) -> float:
        return self.seek + nbytes / self.bandwidth


class PageBackend(abc.ABC):
    """Abstract content-addressed page store + manifest commit point.

    Pages are ndarray values keyed by content-hash strings; the backend
    treats both as opaque (hashing and dtype policy live in ModelStore).
    ``get_pages`` is *grouped*: one call fetches a whole miss set so a
    backend can amortize its per-request overhead (one seek / one SQL
    query / one object-store round trip) across the batch.
    """

    scheme: str = "abstract"

    # ------------------------------------------------------------- pages --
    @abc.abstractmethod
    def put_pages(self, pages: Mapping[str, np.ndarray]) -> int:
        """Store pages by hash; already-present hashes are skipped
        (content addressing dedups on the backend too).  Returns the
        number of pages newly written."""

    @abc.abstractmethod
    def get_pages(self, hashes: Sequence[str]) -> Dict[str, np.ndarray]:
        """Grouped fetch: all requested pages in ONE backend request.
        Raises ``KeyError`` on the first missing hash."""

    @abc.abstractmethod
    def list_pages(self) -> List[str]:
        """All stored page hashes (sorted)."""

    @abc.abstractmethod
    def delete_pages(self, hashes: Sequence[str]) -> int:
        """Remove pages; unknown hashes are ignored.  Returns the number
        actually deleted (the orphan-pruning hook for ``ModelStore.save``)."""

    # ---------------------------------------------------------- manifest --
    @abc.abstractmethod
    def commit_manifest(self, manifest: Dict) -> None:
        """Atomically replace the manifest: a reader must observe either
        the previous manifest or this one, never a torn state (atomic
        rename for files, one transaction for SQL)."""

    @abc.abstractmethod
    def load_manifest(self) -> Dict:
        """The last committed manifest; ``FileNotFoundError`` if none."""

    def has_manifest(self) -> bool:
        try:
            self.load_manifest()
            return True
        except FileNotFoundError:
            return False

    # ------------------------------------------------------------ journal --
    # The write-ahead intent journal (storage/journal.py, DESIGN.md §11):
    # multi-step mutations append an intent before touching pages, a done
    # marker after, and ``journal_rewrite`` compacts/clears atomically.
    # Recovery on open replays whatever is left.  The base implementation
    # is in-process (exactly as durable as MemoryBackend itself); file and
    # SQL backends override with fsync'd / transactional storage.

    def journal_append(self, record: Dict) -> int:
        """Durably append one record; assigns and returns the next ``seq``
        unless the record already carries one (done markers echo their
        intent's seq)."""
        j = self.__dict__.setdefault("_journal", [])
        if "seq" not in record:
            record = {**record,
                      "seq": max((r.get("seq", 0) for r in j), default=0) + 1}
        j.append(dict(record))
        return int(record["seq"])

    def journal_records(self) -> List[Dict]:
        """All journal records in append order (empty = clean store)."""
        return [dict(r) for r in self.__dict__.get("_journal", [])]

    def journal_rewrite(self, records: Sequence[Dict]) -> None:
        """Atomically replace the journal (compaction; ``[]`` clears)."""
        self.__dict__["_journal"] = [dict(r) for r in records]

    def sweep_temp(self) -> int:
        """Remove staging debris a crash can strand (``*.tmp`` files for
        directory backends); returns how many items were swept."""
        return 0

    # ------------------------------------------------------------- admin --
    def url(self) -> str:
        """Round-trippable URL (``open_backend(b.url())`` reopens it)."""
        return f"{self.scheme}://"

    def close(self) -> None:
        """Release handles (no-op for stateless backends)."""

    # -------------------------------------------------------- calibration --
    def microbench(self, page_bytes: int = 128 * 1024, pages: int = 8,
                   repeats: int = 3) -> StorageProfile:
        """Measure (seek, bandwidth) with scratch pages, then clean up.

        Two timed operations per repeat — a single-page get (``seek +
        b/bw``) and a grouped ``pages``-page get (``seek + n*b/bw``) —
        give two equations in two unknowns; medians over ``repeats`` keep
        one scheduler hiccup from poisoning the calibration.  Backends
        with *injected* performance (the object-store sim) override this
        and return their configured profile directly.
        """
        side = max(1, int(np.sqrt(page_bytes / 4)))
        rng = np.random.default_rng(0)
        scratch = {f"{_BENCH_PREFIX}{i:04d}":
                   rng.standard_normal((side, side)).astype(np.float32)
                   for i in range(pages)}
        nbytes = side * side * 4
        names = sorted(scratch)
        self.put_pages(scratch)
        try:
            t_one, t_group = [], []
            for _ in range(repeats):
                t0 = time.perf_counter()
                self.get_pages(names[:1])
                t_one.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                self.get_pages(names)
                t_group.append(time.perf_counter() - t0)
            one = float(np.median(t_one))
            group = float(np.median(t_group))
        finally:
            self.delete_pages(names)
        bw = (pages - 1) * nbytes / max(group - one, 1e-9)
        bw = float(min(max(bw, 1e6), 1e12))       # clamp to sane hardware
        seek = float(max(one - nbytes / bw, 1e-7))
        return StorageProfile(self.scheme, bw, seek, nbytes)


class MemoryBackend(PageBackend):
    """In-process dict backend: tests, and the object-store sim's default
    inner store.  The manifest commit is trivially atomic (one rebind)."""

    scheme = "memory"

    def __init__(self):
        self._pages: Dict[str, np.ndarray] = {}
        self._manifest: Optional[Dict] = None

    def put_pages(self, pages: Mapping[str, np.ndarray]) -> int:
        new = 0
        for h, arr in pages.items():
            if h not in self._pages:
                self._pages[h] = np.array(arr, copy=True)
                new += 1
        return new

    def get_pages(self, hashes: Sequence[str]) -> Dict[str, np.ndarray]:
        return {h: self._pages[h].copy() for h in hashes}

    def list_pages(self) -> List[str]:
        return sorted(self._pages)

    def delete_pages(self, hashes: Sequence[str]) -> int:
        n = 0
        for h in hashes:
            if self._pages.pop(h, None) is not None:
                n += 1
        return n

    def commit_manifest(self, manifest: Dict) -> None:
        self._manifest = dict(manifest)

    def load_manifest(self) -> Dict:
        if self._manifest is None:
            raise FileNotFoundError("memory backend has no manifest")
        return dict(self._manifest)
