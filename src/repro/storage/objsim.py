"""Object-store simulator: a latency/bandwidth-injected PageBackend.

Wraps any inner backend (in-memory by default) and *reports* remote-
object-store performance through ``microbench()`` instead of measuring:
the serving engine's :class:`~repro.serving.engine.StorageModel` virtual
clock then charges every pool miss as if pages lived behind an S3-like
tier (tens of ms per request, modest bandwidth) — the fig-8 "working set
exceeds the pool" regime where grouped fetches and prefetching earn
their keep — while the actual page bytes move at memory speed, keeping
benchmarks and tests fast and deterministic.

It also counts calls: ``get_calls`` vs ``pages_fetched`` is how tests
assert the miss path really is *grouped* (one backend request per batch).
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from .backend import MemoryBackend, PageBackend, StorageProfile

#: S3-ish single-region defaults: first-byte latency ~20 ms, 200 MB/s
DEFAULT_SEEK = 20e-3
DEFAULT_BANDWIDTH = 200e6


class ObjectStoreSimBackend(PageBackend):
    """Wraps another backend with object-store-like latency accounting
    (per-request seek + bandwidth), for storage-tier experiments."""
    scheme = "objsim"

    def __init__(self, inner: Optional[PageBackend] = None,
                 seek: float = DEFAULT_SEEK,
                 bandwidth: float = DEFAULT_BANDWIDTH):
        self.inner = inner if inner is not None else MemoryBackend()
        self.seek = float(seek)
        self.bandwidth = float(bandwidth)
        self.get_calls = 0
        self.put_calls = 0
        self.pages_fetched = 0

    def url(self) -> str:
        # file and sqlite inners carry their (absolute) path in the URL;
        # open_backend() tells them apart by the .db/.sqlite suffix.  A
        # memory inner has no path — reopening its URL starts empty.
        inner_path = getattr(self.inner, "path", "")
        if inner_path:
            import os
            inner_path = os.path.abspath(inner_path)
        return (f"objsim://{inner_path}"
                f"?seek_ms={self.seek * 1e3:g}"
                f"&bandwidth_mbps={self.bandwidth / 1e6:g}")

    # ------------------------------------------------- delegated storage --
    def put_pages(self, pages: Mapping[str, np.ndarray]) -> int:
        self.put_calls += 1
        return self.inner.put_pages(pages)

    def get_pages(self, hashes: Sequence[str]) -> Dict[str, np.ndarray]:
        self.get_calls += 1
        self.pages_fetched += len(set(hashes))
        return self.inner.get_pages(hashes)

    def list_pages(self) -> List[str]:
        return self.inner.list_pages()

    def delete_pages(self, hashes: Sequence[str]) -> int:
        return self.inner.delete_pages(hashes)

    def commit_manifest(self, manifest: Dict) -> None:
        self.inner.commit_manifest(manifest)

    def load_manifest(self) -> Dict:
        return self.inner.load_manifest()

    def journal_append(self, record: Dict) -> int:
        return self.inner.journal_append(record)

    def journal_records(self) -> List[Dict]:
        return self.inner.journal_records()

    def journal_rewrite(self, records: Sequence[Dict]) -> None:
        self.inner.journal_rewrite(records)

    def sweep_temp(self) -> int:
        return self.inner.sweep_temp()

    def close(self) -> None:
        self.inner.close()

    # -------------------------------------------------------- calibration --
    def microbench(self, page_bytes: int = 128 * 1024, pages: int = 8,
                   repeats: int = 3) -> StorageProfile:
        """Injected, not measured: the whole point of the simulator."""
        return StorageProfile("objsim", self.bandwidth, self.seek,
                              page_bytes)
