"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 quantization with **error feedback** (residual carried to the next
step), applied per-leaf with a per-leaf fp32 scale.  Under GSPMD the
data-parallel all-reduce happens on whatever the gradient dtype is, so
quantize->(all-reduce)->dequantize cuts DCN bytes 4x vs fp32 / 2x vs
bf16; error feedback keeps the optimizer trajectory unbiased to first
order (Karimireddy et al. '19).

``make_grad_compressor`` returns a ``grad_transform`` for
``launch.steps.make_train_step`` plus the error-state initializer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def quantize_leaf(g, bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / qmax
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale.astype(F32)


def dequantize_leaf(q, scale) -> jnp.ndarray:
    return q.astype(F32) * scale


def compress_with_feedback(grads, err_state, bits: int = 8):
    """(grads, err) -> (decompressed grads, new err).  The round trip
    models the compressed wire format; XLA reduces the int8 payload."""
    def leaf(g, e):
        g = g.astype(F32) + e
        q, s = quantize_leaf(g, bits)
        deq = dequantize_leaf(q, s)
        return deq, g - deq
    out = jax.tree.map(leaf, grads, err_state)
    deq = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def make_grad_compressor(bits: int = 8):
    """Stateful-via-closure compressor: the error state rides inside the
    optimizer loop (see launch/train.py)."""
    def transform(grads_and_err):
        grads, err = grads_and_err
        return compress_with_feedback(grads, err, bits)
    return transform
