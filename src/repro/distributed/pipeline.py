"""Pipeline parallelism over the `pod` axis (GPipe schedule).

At ≥480B scale, pure DP across pods wastes the slow DCN hop on gradient
all-reduce of the full parameter set.  This module provides the
alternative: layers are partitioned into stages (one per pod), and
microbatches stream through a `shard_map`ed loop with
`lax.ppermute` stage-to-stage handoffs — the collective crossing DCN is
then one activation tensor per microbatch instead of all gradients.

``pipeline_apply`` is schedule-only and takes any per-stage function, so
the model zoo's scan-based stacks drop in unchanged (a stage closure
over ``_run_group``).  Bubble fraction = (S-1)/(M+S-1) for S stages and
M microbatches.

Self-check (8 host devices, 2 stages):

    REPRO_PP_DEVICES=8 python -m repro.distributed.pipeline
"""
from __future__ import annotations

if __name__ == "__main__":        # must precede the jax import below
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count="
                          + os.environ.get("REPRO_PP_DEVICES", "8"))

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version compat: ``jax.shard_map`` (keyword ``check_vma``, or
    ``check_rep`` on 0.5/0.6) vs ``jax.experimental.shard_map.shard_map``
    (0.4.x, ``check_rep``).  Replication checking is off in all cases —
    the final all-gather makes the output replicated but the checker
    can't prove it."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:        # jax with shard_map but pre-rename kwarg
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def pipeline_apply(stage_fn: Callable, stage_params, microbatches,
                   *, mesh, axis: str = "pod"):
    """Run ``microbatches`` [M, ...] through all pipeline stages.

    ``stage_params``: pytree with a leading stage axis (sharded over
    ``axis``); ``stage_fn(params_slice, x) -> y`` applies one stage.
    Returns outputs [M, ...] (valid on every device after the final
    broadcast).
    """
    n_stages = mesh.shape[axis]
    M = microbatches.shape[0]

    def inner(params_local, mb):
        # params_local leaves: [1, ...] (this stage's slice); mb: [M, ...]
        idx = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params_local)
        T = M + n_stages - 1

        def step(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (clamped when past the end)
            inj = jnp.minimum(t, M - 1)
            x0 = jax.lax.dynamic_index_in_dim(mb, inj, 0, keepdims=False)
            x_in = jnp.where(idx == 0, x0, buf)
            y = stage_fn(p, x_in)
            # hand off to the next stage (ring; last->0 ignored)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            # the last stage's result for microbatch (t - n_stages + 1)
            out_t = jnp.clip(t - (n_stages - 1), 0, M - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, out_t, 0,
                                               keepdims=False)
            write = (idx == n_stages - 1) & (t >= n_stages - 1)
            upd = jnp.where(write, y, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, out_t, 0)
            return buf, outs

        buf0 = jnp.zeros_like(mb[0])
        outs0 = jnp.zeros_like(mb)
        _, outs = jax.lax.fori_loop(0, T, step, (buf0, outs0))
        # broadcast final outputs from the last stage to every stage
        if n_stages > 1:
            outs = jax.lax.all_gather(outs, axis)[n_stages - 1]
        return outs

    pspec = P(axis)
    out = _shard_map(
        inner, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: pspec, stage_params),
                  P()),
        out_specs=P(),
    )(stage_params, microbatches)
    return out


def _self_check():
    import os
    import numpy as np
    from ..launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))

    # 4-layer MLP, 2 stages x 2 layers
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.standard_normal((4, 16, 16)) * 0.3, jnp.float32)

    def two_layers(w_pair, x):
        for i in range(2):
            x = jnp.tanh(x @ w_pair[i])
        return x

    stage_params = W.reshape(2, 2, 16, 16)       # [stages, 2, 16, 16]
    mb = jnp.asarray(rng.standard_normal((3, 8, 16)), jnp.float32)

    out = pipeline_apply(two_layers, stage_params, mb, mesh=mesh)

    ref = mb
    for i in range(4):
        ref = jnp.tanh(ref @ W[i])
    err = float(jnp.abs(out - ref).max())
    print(f"pipeline self-check max err: {err:.2e}")
    assert err < 1e-6
    # also prove it lowers with collective-permute on the pod axis
    lowered = jax.jit(lambda sp, m: pipeline_apply(
        two_layers, sp, m, mesh=mesh)).lower(stage_params, mb)
    txt = lowered.compile().as_text()
    assert "collective-permute" in txt
    print("HLO contains collective-permute: ok")


if __name__ == "__main__":
    _self_check()
