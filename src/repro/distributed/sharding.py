"""Sharding recipes: DP / FSDP / TP / EP / SP over the production mesh.

Axes (launch/mesh.py): single-pod ``("data", "model")`` = (16, 16);
multi-pod ``("pod", "data", "model")`` = (2, 16, 16).

* params: 2D-sharded — FSDP over ``data``, TP over ``model`` (giant MoEs
  only fit 256 chips at 256-way param sharding).
* MoE experts: EP over ``model``; expert-internal dims FSDP over ``data``.
* activations: batch over (``pod``, ``data``); optional sequence parallel
  (``seq`` axis) for long prefill; logits vocab over ``model``.

Models call :func:`hint` with a *site name*; the active
:class:`ShardingRecipe` (a contextvar set by the launcher) maps sites to
``PartitionSpec``s.  Outside a recipe/mesh context hints are identity, so
smoke tests run unsharded on one CPU device.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import re
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar("recipe", default=None)


@dataclasses.dataclass(frozen=True)
class ShardingRecipe:
    """Axis assignment for one run mode (train / prefill / decode)."""
    dp: Tuple[str, ...] = ("data",)       # batch ("pod","data") when multi-pod
    tp: Optional[str] = "model"           # tensor/expert parallel axis
    fsdp: Optional[str] = "data"          # param FSDP axis
    seq: Optional[str] = None             # sequence-parallel axis (prefill)
    kv_seq: Optional[str] = None          # decode KV-cache sequence axis
    sites: Dict[str, P] = dataclasses.field(default_factory=dict)

    def site(self, name: str) -> Optional[P]:
        return self.sites.get(name)


def make_recipe(mode: str, multi_pod: bool = False,
                overrides: Optional[Dict[str, P]] = None) -> ShardingRecipe:
    dp = ("pod", "data") if multi_pod else ("data",)
    tp, fsdp = "model", "data"
    if mode == "train":
        sites = {
            "residual": P(dp, None, None),
            "act_ff":   P(dp, None, tp),
            "logits":   P(dp, None, tp),
            "moe_disp": P(tp, None, None),      # [E, C, D] expert-sharded
        }
        rec = ShardingRecipe(dp, tp, fsdp, None, None, sites)
    elif mode == "prefill":
        # Sequence parallel: 32k tokens split over `model`, batch over dp.
        # NOTE §Perf iteration 2b: forcing head-sharded attention via
        # attn_q/attn_kv/attn_o hints made GSPMD all-gather the residual
        # stream instead (worse); ring attention is the real fix. The
        # hint sites remain available but are unset here.
        sites = {
            "residual": P(dp, tp, None),
            "act_ff":   P(dp, tp, None),
            "logits":   P(dp, None, tp),      # [B, 1, V]: vocab over TP
            "moe_disp": P(tp, None, None),
        }
        rec = ShardingRecipe(dp, tp, fsdp, tp, None, sites)
    elif mode == "decode":
        # One token per step: KV cache sequence sharded over `model`.
        sites = {
            "residual": P(dp, None, None),
            "act_ff":   P(dp, None, tp),
            "logits":   P(dp, None, tp),
            "moe_disp": P(tp, None, None),
        }
        rec = ShardingRecipe(dp, tp, fsdp, None, tp, sites)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    if overrides:
        sites = dict(rec.sites)
        sites.update(overrides)
        rec = dataclasses.replace(rec, sites=sites)
    return rec


@contextlib.contextmanager
def use_recipe(recipe: Optional[ShardingRecipe]):
    tok = _ACTIVE.set(recipe)
    try:
        yield recipe
    finally:
        _ACTIVE.reset(tok)


def current_recipe() -> Optional[ShardingRecipe]:
    return _ACTIVE.get()


def hint(x, site: str):
    """Best-effort ``with_sharding_constraint`` at a named activation site."""
    rec = _ACTIVE.get()
    if rec is None:
        return x
    spec = rec.site(site)
    if spec is None:
        return x
    spec = _fit_rank(spec, x.ndim)
    return jax.lax.with_sharding_constraint(x, spec)


def _fit_rank(spec: P, ndim: int) -> P:
    parts = list(spec)
    if len(parts) < ndim:
        parts = parts + [None] * (ndim - len(parts))
    elif len(parts) > ndim:
        # Drop *inner* Nones first, else truncate (decode: [B,1,D] vs [B,S,D]).
        parts = [p for p in parts if p is not None]
        parts = parts + [None] * (ndim - len(parts)) if len(parts) < ndim \
            else parts[:ndim]
    return P(*parts)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes that do not evenly divide the corresponding dim
    (jit input shardings require even partitioning; e.g. batch=1 decode
    cells and odd vocabs fall back to replication on that dim)."""
    import math as _math
    sizes = dict(mesh.shape)
    parts = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        if ax is None:
            parts.append(None)
            continue
        axes = list(ax) if isinstance(ax, tuple) else [ax]
        while axes and dim % _math.prod(sizes[a] for a in axes) != 0:
            axes = axes[:-1]
        if not axes:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(tuple(axes))
    return P(*parts)


# ------------------------------------------------------------- param rules --
# leaf name -> spec builder(recipe, ndim).  All per-layer params carry a
# leading stacked-layer axis (never sharded).
def _mat(in_ax, out_ax):
    def rule(rec: ShardingRecipe, ndim: int) -> P:
        base = [in_ax(rec), out_ax(rec)]
        return P(*([None] * (ndim - 2) + base))
    return rule


_FSDP = lambda r: r.fsdp
_TP = lambda r: r.tp
_NONE = lambda r: None

_PARAM_RULES = {
    # attention (cross-attn c* shares rules)
    r"^(wq|wk|wv|cq|ck|cv)$": _mat(_FSDP, _TP),
    r"^(wo|co)$":             _mat(_TP, _FSDP),
    r"^(bq|bk|bv)$":          lambda rec, nd: P(*([None] * (nd - 1) + [rec.tp])),
    # dense mlp + arctic dense-residual
    r"^(w1|w3|dw1|dw3)$":     _mat(_FSDP, _TP),
    r"^(w2|dw2)$":            _mat(_TP, _FSDP),
    # MoE: experts over TP(=EP) axis, d_model over FSDP
    r"^(ew1|ew3)$": lambda rec, nd: P(*([None] * (nd - 3) + [rec.tp, rec.fsdp, None])),
    r"^ew2$":       lambda rec, nd: P(*([None] * (nd - 3) + [rec.tp, None, rec.fsdp])),
    r"^router$":    _mat(_FSDP, _NONE),
    # mamba
    r"^in_proj$":   _mat(_FSDP, _TP),
    r"^out_proj$":  _mat(_TP, _FSDP),
    r"^(conv_w|conv_b|A_log|Dp|dt_bias)$":
        lambda rec, nd: P(*([None] * (nd - 1) + [rec.tp])),
    # embeddings
    r"^embed$":     lambda rec, nd: P(rec.tp, rec.fsdp),
    r"^head$":      lambda rec, nd: P(rec.fsdp, rec.tp),
    r"^pos_embed$": lambda rec, nd: P(*([None] * nd)),
}


def param_spec(path: str, ndim: int,
               recipe: Optional[ShardingRecipe] = None) -> P:
    rec = recipe or current_recipe() or make_recipe("train")
    leaf = path.split("/")[-1]
    for pat, rule in _PARAM_RULES.items():
        if re.match(pat, leaf):
            return rule(rec, ndim)
    return P()      # norms, scalars: replicated


def param_specs(params, recipe: Optional[ShardingRecipe] = None):
    """Pytree of PartitionSpecs matching a params pytree (by key path)."""
    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        ndim = len(tree.shape)
        return param_spec(prefix, ndim, recipe)
    return walk(params, "")


POOL_SHARD_AXIS = "shard"


def slab_spec(ndim: int = 5) -> P:
    """PartitionSpec of a stacked per-shard page slab ``[num_shards,
    capacity, blocks_per_page, bh, bw]``: the shard dimension partitions
    over the serving mesh's ``shard`` axis, block payloads replicate.
    (The dry-run `dedup_serving*` variants shard the flat pool the same
    way over the production axes.)"""
    return P(*([POOL_SHARD_AXIS] + [None] * (ndim - 1)))


def slab_sharding(mesh, shape):
    """NamedSharding for a stacked slab of ``shape`` on a ``("shard",)``
    serving mesh (see ``launch.mesh.make_shard_mesh``); falls back to
    replication on dims the mesh cannot evenly partition."""
    from jax.sharding import NamedSharding
    return NamedSharding(mesh, sanitize_spec(slab_spec(len(shape)),
                                             shape, mesh))


def cache_specs(cache, recipe: ShardingRecipe):
    """Specs for a decode cache pytree (leaf-name keyed)."""
    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        leaf = prefix.split("/")[-1]
        nd = len(tree.shape)
        if leaf in ("k", "v"):          # [L, B, S, K, hd]
            return P(None, recipe.dp, recipe.kv_seq, None, None)
        if leaf == "ssm_state":         # [L, B, H, hd, state]
            return P(None, recipe.dp, recipe.tp, None, None)
        if leaf == "conv_state":        # [L, B, K-1, C]
            return P(None, recipe.dp, None, recipe.tp)
        if leaf in ("enc_k", "enc_v"):  # [L, B, S_enc, K, hd]
            return P(None, recipe.dp, recipe.kv_seq, None, None)
        if leaf == "pos":
            return P()
        return P(*([None] * nd))
    return walk(cache, "")
