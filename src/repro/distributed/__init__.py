from .sharding import (ShardingRecipe, cache_specs, current_recipe, hint,
                       make_recipe, param_spec, param_specs, use_recipe)

__all__ = ["ShardingRecipe", "cache_specs", "current_recipe", "hint",
           "make_recipe", "param_spec", "param_specs", "use_recipe"]
