"""gemma2-9b [dense]: local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    kv_heads=8,
    d_ff=14336,
    vocab=256_000,
    head_dim=256,             # gemma2 uses wide heads (16*256 != d_model)
    rope_theta=10_000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    window_pattern=2,         # alternate local / global
    tie_embeddings=True,
    embed_scale=True,         # embeddings scaled by sqrt(d_model)
    act="gelu",               # GeGLU
    gated_mlp=True,
    source="arXiv:2408.00118",
)
