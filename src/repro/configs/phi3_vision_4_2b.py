"""phi-3-vision-4.2b [vlm]: phi3-mini text backbone + CLIP frontend stub.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

Per assignment, the vision frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings; the backbone consumes [text tokens | patch
embeddings] as one causal sequence.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    kv_heads=32,              # MHA (GQA kv=32)
    d_ff=8192,
    vocab=32064,
    head_dim=96,
    rope_theta=10_000.0,
    act="silu",
    gated_mlp=True,
    vlm_stub=True,
    num_patches=576,          # 24x24 CLIP-L patch grid
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
