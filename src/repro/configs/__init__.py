"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from importlib import import_module
from typing import Dict, List

from .base import (ModelConfig, MoEConfig, SHAPES, ShapeSpec, SSMConfig,
                   reduced, shape_supported)

_MODULES = {
    "phi-3-vision-4.2b": ".phi3_vision_4_2b",
    "gemma2-9b": ".gemma2_9b",
    "qwen3-14b": ".qwen3_14b",
    "qwen2-72b": ".qwen2_72b",
    "deepseek-7b": ".deepseek_7b",
    "hymba-1.5b": ".hymba_1_5b",
    "whisper-small": ".whisper_small",
    "arctic-480b": ".arctic_480b",
    "kimi-k2-1t-a32b": ".kimi_k2_1t_a32b",
    "mamba2-1.3b": ".mamba2_1_3b",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    try:
        mod = _MODULES[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; known: {list_archs()}") from None
    return import_module(mod, __package__).CONFIG


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "SHAPES", "ShapeSpec",
           "get_config", "list_archs", "reduced", "shape_supported"]
