"""Architecture + shape configuration system.

Every assigned architecture gets a ``ModelConfig`` in its own module; the
four assigned input shapes are global (``SHAPES``).  ``reduced()`` derives
the CPU-smoke-test config for an architecture (same family, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    dense_ff: int = 0              # arctic: parallel dense-FFN residual width


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256               # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    rope_theta: float = 10_000.0
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    sliding_window: int = 0        # gemma2 local layers / hymba
    window_pattern: int = 0        # every Nth layer global (gemma2: 2)
    tie_embeddings: bool = False
    norm_type: str = "rms"         # rms | layer
    norm_eps: float = 1e-6
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU / plain)
    gated_mlp: bool = True
    embed_scale: bool = False      # gemma2 multiplies embeddings by sqrt(d)
    moe: Optional[MoEConfig] = None
    first_dense_layers: int = 0    # kimi-k2: layer 0 dense
    ssm: Optional[SSMConfig] = None
    hybrid: bool = False           # hymba: parallel attn + SSM heads
    encdec: bool = False           # whisper
    enc_layers: int = 0
    vlm_stub: bool = False         # phi-3-vision: precomputed patch embeddings
    num_patches: int = 576
    dtype: str = "bfloat16"
    remat: bool = True
    scan_unroll: bool = False      # dry-run accounting: unroll layer scans so
                                   # cost_analysis counts every layer (XLA
                                   # counts while-loop bodies once)
    optimizer: str = "adamw"       # adamw | adafactor (giant MoEs)
    # --- dedup-serving knobs (the paper's technique as a runtime feature) ---
    dedup_serving: bool = False    # lower serve with virtual (paged) weights
    dedup_ratio: float = 0.35      # distinct-block fraction (paper: 2.7-3.6x)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.num_heads))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid(sliding-window+SSM) only.
        gemma2's alternating pattern still has full-attention global layers
        -> quadratic -> skipped (DESIGN.md §5)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True   # all ten assigned archs decode (whisper is enc-dec)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers), for 6ND."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.hd
        attn = d * self.num_heads * hd + 2 * d * self.kv_heads * hd \
            + self.num_heads * hd * d
        mlp_mult = 3 if self.gated_mlp else 2
        if self.family == "ssm":
            s = self.ssm
            din = s.expand * d
            nheads = din // s.head_dim
            per_layer = d * (2 * din + 2 * s.n_groups * s.d_state + nheads) \
                + din * d + nheads + nheads
        elif self.family == "hybrid":
            s = self.ssm
            din = s.expand * d
            nheads = din // s.head_dim
            ssm_p = d * (2 * din + 2 * s.n_groups * s.d_state + nheads) + din * d
            per_layer = attn + ssm_p + mlp_mult * d * self.d_ff
        elif self.moe is not None:
            moe_layers = self.num_layers - self.first_dense_layers
            dense_layers = self.first_dense_layers
            expert = mlp_mult * d * self.moe.d_ff
            per = attn + self.moe.num_experts * expert \
                + (mlp_mult * d * self.moe.dense_ff if self.moe.dense_ff else 0) \
                + d * self.moe.num_experts  # router
            dense = attn + mlp_mult * d * self.d_ff if self.d_ff else attn
            return emb + per * moe_layers + dense * dense_layers
        else:
            per_layer = attn + mlp_mult * d * self.d_ff
        total = emb + per_layer * self.num_layers
        if self.encdec:
            # encoder layers: attn + ungated mlp; decoder adds cross-attn
            total += self.enc_layers * (attn + 2 * d * self.d_ff)
            total += self.num_layers * attn     # cross attention
        return total

    def active_param_count(self) -> int:
        """MoE: only top_k experts are active per token (6·N_active·D)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        mlp_mult = 3 if self.gated_mlp else 2
        expert = mlp_mult * d * self.moe.d_ff
        inactive = (self.moe.num_experts - self.moe.top_k) * expert
        return self.param_count() - inactive * (self.num_layers
                                                - self.first_dense_layers)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def shape_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(supported, reason-if-not) per the assignment's skip rules."""
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 524k decode is O(L^2); "
                       "skipped per assignment (see DESIGN.md §5)")
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=max(2, cfg.first_dense_layers + 1),
        d_model=64, num_heads=4, kv_heads=2, d_ff=128, vocab=256,
        head_dim=16, dtype="float32", remat=False,
        enc_layers=2 if cfg.encdec else 0,
        num_patches=8 if cfg.vlm_stub else cfg.num_patches,
        sliding_window=16 if cfg.sliding_window else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(num_experts=4, top_k=min(2, cfg.moe.top_k),
                              d_ff=64, capacity_factor=2.0,
                              dense_ff=32 if cfg.moe.dense_ff else 0)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                              n_groups=1, chunk=8)
    return dataclasses.replace(cfg, **kw)
