"""arctic-480b [moe]: 128 experts top-2 with a parallel dense-FFN residual.
[hf:Snowflake/snowflake-arctic-base; hf]

Cross-*expert* block dedup makes this the paper technique's best fit
(128 experts ~ 128 model variants, DESIGN.md §5).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    kv_heads=8,
    d_ff=4864,
    vocab=32_000,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff=4864, capacity_factor=1.25,
                  dense_ff=4864),
    act="silu",
    gated_mlp=True,
    optimizer="adafactor",    # fp32 Adam states for 480B do not fit 256 chips
    source="hf:Snowflake/snowflake-arctic-base",
)
