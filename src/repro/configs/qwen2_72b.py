"""qwen2-72b [dense]: GQA with QKV bias.  [arXiv:2407.10671; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    kv_heads=8,
    d_ff=29568,
    vocab=152_064,
    head_dim=128,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    act="silu",
    gated_mlp=True,
    source="arXiv:2407.10671",
)
