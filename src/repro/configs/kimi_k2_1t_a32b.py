"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 384 experts top-8, first
layer dense.  [arXiv:2501.kimi2; unverified — paper-table config]
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    kv_heads=8,
    d_ff=18432,               # dense first layer FFN width
    vocab=163_840,
    head_dim=112,             # 7168 / 64
    rope_theta=50_000.0,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff=2048, capacity_factor=1.25),
    first_dense_layers=1,
    act="silu",
    gated_mlp=True,
    optimizer="adafactor",
    source="arXiv:2501.kimi2",
)
