"""hymba-1.5b [hybrid]: parallel attention + mamba heads in every layer.
[arXiv:2411.13676; hf]

Hymba runs sliding-window attention in all but three layers (first,
middle, last are global) with an SSM branch in parallel; outputs are
mean-fused.  ssm_state=16 per assignment.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    kv_heads=5,
    d_ff=5504,
    vocab=32_001,
    head_dim=64,
    rope_theta=10_000.0,
    sliding_window=1024,
    window_pattern=-3,        # sentinel: first/middle/last layers global
    hybrid=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    act="silu",
    gated_mlp=True,
    source="arXiv:2411.13676",
)
