"""mamba2-1.3b [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,              # attention-free
    kv_heads=0,
    d_ff=0,                   # no MLP: pure mamba stack
    vocab=50_280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
