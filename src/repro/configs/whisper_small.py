"""whisper-small [audio]: encoder-decoder transformer backbone.
[arXiv:2212.04356; unverified]

The conv/mel frontend is a STUB per assignment: ``input_specs()`` provides
precomputed frame embeddings for the encoder.  LayerNorm + plain GELU MLP
+ learned positions (no RoPE), faithful to the whisper backbone.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,            # decoder layers
    enc_layers=12,
    d_model=768,
    num_heads=12,
    kv_heads=12,
    d_ff=3072,
    vocab=51_865,
    head_dim=64,
    norm_type="layer",
    act="gelu",
    gated_mlp=False,
    encdec=True,
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
