"""qwen3-14b [dense]: qk_norm + GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    kv_heads=8,
    d_ff=17408,
    vocab=151_936,
    head_dim=128,
    rope_theta=1_000_000.0,
    qk_norm=True,
    act="silu",
    gated_mlp=True,
    source="hf:Qwen/Qwen3-8B",
)
