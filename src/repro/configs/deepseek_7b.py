"""deepseek-7b [dense]: llama-architecture.  [arXiv:2401.02954; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    kv_heads=32,              # MHA
    d_ff=11008,
    vocab=102_400,
    head_dim=128,
    rope_theta=10_000.0,
    act="silu",
    gated_mlp=True,
    source="arXiv:2401.02954",
)
