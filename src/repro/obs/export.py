"""Trace exporters: Chrome-trace/Perfetto JSON and flat JSONL.

The Chrome trace format (the JSON Perfetto and ``chrome://tracing``
both load) wants ``traceEvents`` with complete ("X") events stamped in
microseconds.  Our timestamps are *virtual* seconds — we export
``ts = start_t * 1e6`` unchanged, so a 50ms SLO renders as 50ms on the
timeline even though no wall time was ever consumed.

Track layout: one track per clock channel (``channel/storage``,
``channel/compute``, ``channel/idle``), one per shard
(``shard/0`` ...), one for the per-request spans (``requests``) and
one per remaining span kind.  Track names are emitted as "M"
``thread_name`` metadata records, the shape Perfetto's schema expects.

The top-level ``otherData`` carries the clock's channel ledger and the
tracer's charged-span ledger side by side, so ``trace_report.py`` can
re-verify the conservation invariant from the file alone, without the
live objects.
"""
from __future__ import annotations

import json
from typing import Dict, List

__all__ = ["to_chrome_trace", "to_jsonl", "write_trace",
           "validate_chrome_trace", "load_trace"]

_PID = 1


def _track_name(span) -> str:
    shard = span.attrs.get("shard")
    if shard is not None:
        return f"shard/{shard}"
    if span.channel is not None:
        return f"channel/{span.channel}"
    if span.kind == "request":
        return "requests"
    return f"kind/{span.kind}"


def to_chrome_trace(tracer, clock=None) -> dict:
    """One Chrome-trace JSON object for the tracer's finished spans
    (virtual-clock microsecond timestamps)."""
    clock = clock if clock is not None else tracer.clock
    tracks: Dict[str, int] = {}
    events: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
        "args": {"name": "repro-serving (virtual clock)"},
    }]

    def tid_for(track: str) -> int:
        tid = tracks.get(track)
        if tid is None:
            tid = len(tracks) + 1
            tracks[track] = tid
            events.append({"name": "thread_name", "ph": "M",
                           "pid": _PID, "tid": tid,
                           "args": {"name": track}})
        return tid

    for sp in tracer.spans():
        end = sp.end_t if sp.end_t is not None else sp.start_t
        args = dict(sp.attrs)
        args["sid"] = sp.sid
        if sp.parent is not None:
            args["parent"] = sp.parent
        if sp.channel is not None:
            args["channel"] = sp.channel
        if sp.charge is not None:
            args["charge"] = sp.charge
        events.append({
            "name": sp.name, "cat": sp.kind, "ph": "X", "pid": _PID,
            "tid": tid_for(_track_name(sp)),
            "ts": sp.start_t * 1e6,
            "dur": max(0.0, end - sp.start_t) * 1e6,
            "args": args,
        })

    other = {
        "tracer_channel_seconds": dict(tracer.channel_seconds),
        "dropped_spans": getattr(tracer, "dropped", 0),
    }
    if clock is not None:
        other["clock_channels"] = dict(clock.channels)
        other["clock_now"] = clock.now
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def to_jsonl(tracer) -> str:
    """Flat one-span-per-line JSON (oldest first), for ad-hoc jq /
    pandas analysis."""
    return "\n".join(json.dumps(sp.to_dict(), sort_keys=True)
                     for sp in tracer.spans()) + "\n"


def write_trace(path: str, tracer, clock=None) -> str:
    """Write the trace to ``path``: ``*.jsonl`` gets the flat form,
    anything else the Chrome-trace JSON.  Returns the path."""
    if str(path).endswith(".jsonl"):
        text = to_jsonl(tracer)
    else:
        text = json.dumps(to_chrome_trace(tracer, clock=clock),
                          indent=1, sort_keys=True)
    with open(path, "w") as fh:
        fh.write(text)
    return str(path)


def validate_chrome_trace(doc: dict) -> List[str]:
    """Schema check for the Chrome-trace export (used by
    ``make trace-smoke``); returns a list of problems, empty when the
    document is well-formed."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return ["top level is not an object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    tids: Dict[int, str] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            problems.append(f"event {i}: unexpected phase {ph!r}")
            continue
        if ph == "M":
            if ev.get("name") == "thread_name":
                tids[ev.get("tid")] = ev["args"]["name"]
            continue
        for key in ("name", "cat", "pid", "tid", "ts", "dur"):
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        if ev.get("tid") not in tids:
            problems.append(
                f"event {i}: tid {ev.get('tid')!r} has no thread_name "
                "metadata")
        if isinstance(ev.get("dur"), (int, float)) and ev["dur"] < 0:
            problems.append(f"event {i}: negative duration")
    other = doc.get("otherData", {})
    if not isinstance(other, dict) \
            or "tracer_channel_seconds" not in other:
        problems.append("otherData.tracer_channel_seconds missing")
    return problems


def load_trace(path: str) -> List[dict]:
    """Read a trace written by :func:`write_trace` back into a flat
    list of span dicts (either format)."""
    with open(path) as fh:
        text = fh.read()
    if str(path).endswith(".jsonl"):
        return [json.loads(line) for line in text.splitlines() if line]
    doc = json.loads(text)
    out = []
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        span = {"name": ev["name"], "kind": ev.get("cat", "span"),
                "start_t": ev["ts"] / 1e6,
                "end_t": (ev["ts"] + ev["dur"]) / 1e6,
                "sid": args.pop("sid", None),
                "parent": args.pop("parent", None)}
        if "channel" in args:
            span["channel"] = args.pop("channel")
        if "charge" in args:
            span["charge"] = args.pop("charge")
        span["attrs"] = args
        out.append(span)
    return out
