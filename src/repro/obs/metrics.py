"""One enumerable metrics registry over the stack's stats surfaces.

``ServeStats``, ``RecoveryStats``, the pool / transfer / router
counters and the virtual clock each grew their own ad-hoc attribute
surface across PRs 1-8.  :class:`MetricsRegistry` unifies them without
touching that attribute API: a metric is a *view* — a name, a kind and
a zero-arg callable that reads the live object — so registering is
free, values are never copied until :meth:`snapshot`, and the existing
dataclasses stay the single source of truth.

Kinds:

  * ``counter`` — monotone scalar (requests served, pages fetched);
    :meth:`diff` subtracts snapshots.
  * ``gauge`` — instantaneous scalar or ``{label: value}`` mapping
    (clock channels, slab occupancy).
  * ``histogram`` — a list of float samples; snapshots summarize to
    ``{count, mean, p50, p99}`` (nearest-rank, matching
    ``ServeStats.percentile``).

Names are dotted ``namespace.field`` (``serve.requests``,
``faults.retries``, ``clock.idle``); ``launch/serve.py
--report-json`` dumps a snapshot, and the report-line audit test pins
every registered serve counter to exactly one ``[report]`` line.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

__all__ = ["MetricsRegistry"]

_KINDS = ("counter", "gauge", "histogram")


def _percentile(samples: List[float], q: float) -> float:
    """Nearest-rank percentile, same convention as
    ``ServeStats.percentile`` (q in [0, 100])."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    idx = max(0, min(len(xs) - 1, int(round(q / 100.0 * len(xs))) - 1))
    return float(xs[idx])


class _Metric:
    __slots__ = ("name", "kind", "read", "help")

    def __init__(self, name: str, kind: str, read: Callable[[], object],
                 help: str = ""):
        self.name = name
        self.kind = kind
        self.read = read
        self.help = help


class MetricsRegistry:
    """Ordered name -> metric-view table with snapshot/diff."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    # -- registration -------------------------------------------------------
    def register(self, name: str, kind: str,
                 read: Callable[[], object], help: str = "") -> None:
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}; "
                             f"have {_KINDS}")
        if name in self._metrics:
            raise ValueError(f"metric {name!r} already registered")
        self._metrics[name] = _Metric(name, kind, read, help)

    def counter(self, name: str, read: Callable[[], object],
                help: str = "") -> None:
        self.register(name, "counter", read, help)

    def gauge(self, name: str, read: Callable[[], object],
              help: str = "") -> None:
        self.register(name, "gauge", read, help)

    def histogram(self, name: str, read: Callable[[], object],
                  help: str = "") -> None:
        self.register(name, "histogram", read, help)

    def register_object(self, namespace: str, obj, fields,
                        help_prefix: str = "") -> None:
        """Register dataclass-style ``fields`` of ``obj`` under
        ``namespace.``: numeric attrs become counters, list attrs
        histograms, dict attrs gauges."""
        for f in fields:
            name = f"{namespace}.{f}"
            val = getattr(obj, f)
            read = (lambda o=obj, a=f: getattr(o, a))
            if isinstance(val, list):
                self.histogram(name, read, help_prefix)
            elif isinstance(val, dict):
                self.gauge(name, read, help_prefix)
            else:
                self.counter(name, read, help_prefix)

    # -- enumeration --------------------------------------------------------
    def names(self, kind: Optional[str] = None) -> List[str]:
        return [m.name for m in self._metrics.values()
                if kind is None or m.kind == kind]

    def kind(self, name: str) -> str:
        return self._metrics[name].kind

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)

    # -- snapshots ----------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Materialize every view.  Histograms summarize to
        ``{count, mean, p50, p99}``; gauges backed by dicts copy the
        mapping; everything else reads as a plain number."""
        out: Dict[str, object] = {}
        for m in self._metrics.values():
            val = m.read()
            if m.kind == "histogram":
                xs = [float(x) for x in val]
                out[m.name] = {
                    "count": len(xs),
                    "mean": (sum(xs) / len(xs)) if xs else 0.0,
                    "p50": _percentile(xs, 50.0),
                    "p99": _percentile(xs, 99.0),
                }
            elif isinstance(val, dict):
                out[m.name] = {str(k): float(v) for k, v in val.items()}
            else:
                out[m.name] = float(val)
        return out

    def diff(self, before: Dict[str, object],
             after: Optional[Dict[str, object]] = None
             ) -> Dict[str, float]:
        """Counter deltas between two snapshots (``after`` defaults to
        a fresh :meth:`snapshot`); gauges and histograms are skipped —
        they are not monotone."""
        if after is None:
            after = self.snapshot()
        out: Dict[str, float] = {}
        for m in self._metrics.values():
            if m.kind != "counter":
                continue
            if m.name in before and m.name in after:
                out[m.name] = float(after[m.name]) - float(before[m.name])
        return out
