"""Observability for the dedup serving stack (DESIGN.md §10).

Three pieces, all on the virtual clock:

  * :mod:`repro.obs.trace` — nested spans with named-channel charge
    accounting.  The default tracer is a zero-allocation no-op, so the
    serving hot path pays one ``get_tracer()`` attribute hop when
    tracing is off.
  * :mod:`repro.obs.metrics` — one enumerable :class:`MetricsRegistry`
    over the stats dataclasses (``ServeStats``, ``RecoveryStats``,
    pool / transfer / router counters) that were previously N
    disconnected ad-hoc surfaces.
  * :mod:`repro.obs.export` — Chrome-trace/Perfetto JSON and flat
    JSONL exporters plus schema validation for CI.

The load-bearing invariant: a charged span records *the same float*
that was passed to ``VirtualClock.advance``, accumulated in the same
order, so per-channel span time equals ``VirtualClock.spent`` per
channel **exactly** — tracing is a second, independent witness of the
clock discipline.
"""
from .trace import (
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    get_tracer,
    set_tracer,
    use_tracer,
)
from .metrics import MetricsRegistry
from .export import (
    to_chrome_trace,
    to_jsonl,
    validate_chrome_trace,
    write_trace,
)

__all__ = [
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "use_tracer",
    "MetricsRegistry",
    "to_chrome_trace",
    "to_jsonl",
    "validate_chrome_trace",
    "write_trace",
]
