"""Virtual-clock request-path tracing.

A :class:`Tracer` produces nested spans ``(name, kind, start_t,
end_t, channel, attrs)`` timed on a
:class:`~repro.serving.traffic.VirtualClock`.  Three usage shapes:

  * ``with tracer.span("fetch", kind="frontend", channel="ssd",
    charge=dt): clock.advance(dt, "ssd")`` — a *charged* span: the
    span wraps the clock advance and records the **same float** that
    the clock was charged, accumulated into
    :attr:`Tracer.channel_seconds` with the identical
    ``get(ch, 0.0) + x`` update the clock itself performs, in the same
    order.  After a run, per-channel span time equals
    ``VirtualClock.spent`` per channel *exactly* (``==``, no
    tolerance) — see :meth:`Tracer.assert_matches_clock`.
  * ``with tracer.span("fault_group", kind="storage", pages=n) as sp``
    — an *attributed* span (no charge): pure structure + attrs, used
    by engines / pools / backends whose virtual seconds are folded
    onto the clock later by the frontend.  ``sp.set(bytes=...)`` adds
    attrs discovered mid-flight.
  * ``tracer.emit("request", arrival, done, kind="request", rid=...)``
    — a retrospective span for intervals that cannot be live context
    managers because they interleave (one span per request id,
    covering arrival → completion across other requests' dispatches).

The default tracer is :data:`NULL_TRACER`, a no-op that allocates
nothing per call (one shared null context manager, one shared null
span), so instrumentation left in the hot path is free when tracing is
off.  Spans may only be opened via the context manager — the
``span-discipline`` lint bans bare :meth:`Tracer.span_begin` /
:meth:`Tracer.span_end` pairs outside this module.

Retention is a bounded ring: the newest ``ring`` finished spans are
kept (``collections.deque(maxlen=ring)``); eviction drops oldest-first
and never touches the open-span stack or the channel accounting, so a
long run stays bounded without corrupting open trees or conservation.
"""
from __future__ import annotations

import contextlib
from collections import deque
from typing import Dict, Iterator, List, Optional

__all__ = [
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "get_tracer",
    "set_tracer",
    "use_tracer",
]


class Span:
    """One finished or in-flight span.  ``start_t`` / ``end_t`` are
    virtual seconds (or monotonic event counts when the tracer has no
    clock); ``charge`` is the float charged to ``channel`` on the
    virtual clock, ``None`` for purely attributed spans."""

    __slots__ = ("sid", "parent", "name", "kind", "start_t", "end_t",
                 "channel", "charge", "attrs")

    def __init__(self, sid: int, parent: Optional[int], name: str,
                 kind: str, start_t: float,
                 channel: Optional[str] = None,
                 charge: Optional[float] = None,
                 attrs: Optional[dict] = None):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.kind = kind
        self.start_t = float(start_t)
        self.end_t: Optional[float] = None
        self.channel = channel
        self.charge = charge
        self.attrs = attrs or {}

    def set(self, **attrs) -> "Span":
        """Attach attrs discovered after the span opened."""
        self.attrs.update(attrs)
        return self

    @property
    def duration(self) -> float:
        end = self.end_t if self.end_t is not None else self.start_t
        return end - self.start_t

    def to_dict(self) -> dict:
        d = {"sid": self.sid, "parent": self.parent, "name": self.name,
             "kind": self.kind, "start_t": self.start_t,
             "end_t": self.end_t}
        if self.channel is not None:
            d["channel"] = self.channel
        if self.charge is not None:
            d["charge"] = self.charge
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        return d

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Span({self.name!r}, kind={self.kind!r}, "
                f"[{self.start_t}, {self.end_t}], "
                f"channel={self.channel!r}, charge={self.charge!r})")


class _SpanHandle:
    """Context manager yielded by :meth:`Tracer.span`; closes the span
    (and books its charge) on exit even when the body raises."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._span.attrs.setdefault("error", exc_type.__name__)
        self._tracer.span_end(self._span)
        return False


class _NullSpan:
    """Shared inert span: ``set`` is a no-op so instrumented code can
    write ``sp.set(...)`` unconditionally."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self


class _NullHandle:
    __slots__ = ()

    def __enter__(self) -> _NullSpan:
        return _NULL_SPAN

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()
_NULL_HANDLE = _NullHandle()


class NullTracer:
    """The zero-alloc default: every call returns a shared singleton
    and records nothing."""

    __slots__ = ()

    enabled = False
    clock = None

    def span(self, name: str, **kw) -> _NullHandle:
        return _NULL_HANDLE

    def emit(self, name: str, start_t: float, end_t: float, **kw) -> None:
        return None

    def event(self, name: str, **kw) -> None:
        return None

    def spans(self) -> List[Span]:
        return []


NULL_TRACER = NullTracer()


class Tracer:
    """Span recorder bound to (at most) one virtual clock.

    ``clock``: a :class:`~repro.serving.traffic.VirtualClock` used as
    the time source; ``None`` falls back to a monotonic event counter
    (ordering-only timestamps for clock-less unit tests).  ``ring``:
    retention cap on *finished* spans — the deque drops oldest-first.

    One tracer is meant to witness one traced run against one fresh
    clock; reusing a tracer across clocks breaks the conservation
    check by construction.
    """

    def __init__(self, clock=None, ring: int = 65536):
        if ring < 1:
            raise ValueError("ring must hold at least one span")
        self.clock = clock
        self.enabled = True
        self.channel_seconds: Dict[str, float] = {}
        self._ring: "deque[Span]" = deque(maxlen=int(ring))
        self._stack: List[Span] = []
        self._next_sid = 0
        self._seq = 0.0   # event-counter fallback time source
        self.dropped = 0  # finished spans evicted by the ring

    # -- time ---------------------------------------------------------------
    def _now(self) -> float:
        if self.clock is not None:
            return self.clock.now
        self._seq += 1.0
        return self._seq

    # -- low-level span primitives (context-manager use only: the ----------
    # span-discipline lint bans calling these outside this module) ---------
    def span_begin(self, name: str, kind: str = "span",
                   channel: Optional[str] = None,
                   charge: Optional[float] = None, **attrs) -> Span:
        parent = self._stack[-1].sid if self._stack else None
        sp = Span(self._next_sid, parent, name, kind, self._now(),
                  channel=channel, charge=charge, attrs=attrs)
        self._next_sid += 1
        self._stack.append(sp)
        return sp

    def span_end(self, sp: Span) -> Span:
        if not self._stack or self._stack[-1] is not sp:
            raise RuntimeError(
                f"span {sp.name!r} closed out of order (open stack: "
                f"{[s.name for s in self._stack]})")
        self._stack.pop()
        sp.end_t = self._now()
        if sp.channel is not None and sp.charge is not None:
            # the *identical* update VirtualClock.advance performs, fed
            # the identical float, in the same order -> exact equality
            self.channel_seconds[sp.channel] = \
                self.channel_seconds.get(sp.channel, 0.0) + sp.charge
        self._finish(sp)
        return sp

    def _finish(self, sp: Span) -> None:
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        self._ring.append(sp)

    # -- public API ---------------------------------------------------------
    def span(self, name: str, kind: str = "span",
             channel: Optional[str] = None,
             charge: Optional[float] = None, **attrs) -> _SpanHandle:
        """Open a nested span as a context manager.  Pass ``channel``
        and ``charge`` together to book virtual seconds (the same float
        handed to ``clock.advance``); either alone is an error."""
        if (channel is None) != (charge is None):
            raise ValueError("channel and charge must be given together")
        return _SpanHandle(self, self.span_begin(
            name, kind=kind, channel=channel, charge=charge, **attrs))

    def emit(self, name: str, start_t: float, end_t: float,
             kind: str = "span", **attrs) -> Span:
        """Record a completed span retrospectively (request trees:
        intervals that interleave and cannot be live context
        managers).  Never charges a channel."""
        parent = self._stack[-1].sid if self._stack else None
        sp = Span(self._next_sid, parent, name, kind, start_t,
                  attrs=attrs)
        self._next_sid += 1
        sp.end_t = float(end_t)
        self._finish(sp)
        return sp

    def event(self, name: str, kind: str = "event", **attrs) -> Span:
        """Zero-duration marker at the current time (policy decisions,
        sheds, retries)."""
        t = self._now()
        return self.emit(name, t, t, kind=kind, **attrs)

    # -- inspection ---------------------------------------------------------
    def spans(self) -> List[Span]:
        """Finished spans, oldest first (bounded by the ring)."""
        return list(self._ring)

    def open_spans(self) -> List[Span]:
        return list(self._stack)

    def find(self, name: Optional[str] = None,
             kind: Optional[str] = None) -> List[Span]:
        return [s for s in self._ring
                if (name is None or s.name == name)
                and (kind is None or s.kind == kind)]

    # -- the conservation invariant -----------------------------------------
    def assert_matches_clock(self, clock=None) -> None:
        """Exact (``==``) per-channel agreement between charged span
        time and the clock's channel ledger.  Charged spans replay the
        clock's own float accumulation, so any mismatch means an
        advance happened outside a charged span (or a span charged
        seconds the clock never saw)."""
        clock = clock if clock is not None else self.clock
        if clock is None:
            raise ValueError("no clock to check against")
        if self._stack:
            raise AssertionError(
                f"open spans at conservation check: "
                f"{[s.name for s in self._stack]}")
        for ch in set(self.channel_seconds) | set(clock.channels):
            mine = self.channel_seconds.get(ch, 0.0)
            clk = clock.channels.get(ch, 0.0)
            if mine != clk:
                raise AssertionError(
                    f"channel {ch!r}: span time {mine!r} != clock "
                    f"spent {clk!r} (an advance escaped its span)")


# ------------------------------------------------------ global tracer ----
_ACTIVE: "NullTracer | Tracer" = NULL_TRACER


def get_tracer() -> "NullTracer | Tracer":
    """The active tracer (the no-op :data:`NULL_TRACER` by default)."""
    return _ACTIVE


def set_tracer(tracer: "NullTracer | Tracer | None"):
    """Install ``tracer`` globally (``None`` restores the no-op);
    returns the previous tracer."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer if tracer is not None else NULL_TRACER
    return prev


@contextlib.contextmanager
def use_tracer(tracer: "NullTracer | Tracer") -> Iterator:
    """Scoped :func:`set_tracer`: installs ``tracer`` for the body and
    restores the previous tracer on exit."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)
