"""Device-resident page pool: the HBM tier of the paper's buffer pool.

The paper pages deduplicated blocks between disk and DRAM; on TPU the
same two tiers are host DRAM (the ModelStore's distinct-block arrays)
and HBM (DESIGN.md §2).  :class:`DevicePagePool` is the HBM side:

  * a **fixed preallocated slab** ``[capacity_pages, blocks_per_page,
    bh, bw]`` living on the accelerator — page loads are real
    ``jax.device_put`` + ``dynamic_update_slice`` transfers, not numpy
    copies;
  * a **physical→slot remap**: :meth:`remap` rewrites a
    ``ModelStore.virtual_tensor`` flat block map (physical slot space,
    ``page * l + slot``) into slab-slot space (``slab_slot * l + slot``)
    with one vectorized lookup, cached per (packing, slab) generation;
  * **compute entry points** — :meth:`gather_rows`, :meth:`virtual_matmul`,
    :meth:`unblock` — that run the Pallas dedup kernels (or their jitted
    XLA equivalents off-TPU) directly against the resident slab, so
    inference never densifies weights on the host.

The pool is driven by :class:`~repro.core.bufferpool.BufferPool` through
its ``on_load``/``on_evict`` callbacks: the policy simulator stays the
single source of truth for *which* pages are resident, and this class
keeps the invariant ``slab occupied slots == pool resident set``.

Kernel mode — how :meth:`gather_rows` / :meth:`virtual_matmul` execute:

  * ``"pallas"``: the Pallas dedup kernels (interpret-mode off-TPU —
    the correctness path the equivalence tests exercise).
  * ``"xla"``: jitted XLA gathers, the same math lowered without Pallas
    (the right choice on GPU).
  * ``"host"``: numpy gathers against a *host mirror* of the slab.  Off
    accelerator the "HBM" tier physically lives in host DRAM, so the
    mirror — maintained page-for-page with the slab — is the honest
    fast path there: same slot remap, same residency invariant, zero
    per-batch weight densification; interpret-mode Pallas and eager XLA
    gathers are correctness tools, not performance paths, on CPU.
  * ``"auto"`` (default): Pallas on TPU, host mirror otherwise.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.blocks import BlockGrid
from ..core.store import ModelStore, VirtualTensor
from ..kernels import ops
from ..obs import get_tracer
from .transfer import TransferEngine

__all__ = ["DevicePagePool"]


# --------------------------------------------------------- jitted XLA paths --
@functools.partial(jax.jit, static_argnames=("bh", "width"))
def _gather_rows_xla(slab, bmap2d, rows, *, bh: int, width: int):
    """Row gather without densifying: the slab is viewed as a flat stack
    of block *rows* ([S*l*bh, bw]) and exactly the requested rows are
    gathered — the XLA lowering of what dedup_embedding does via DMA."""
    S, l, _, bw = slab.shape
    flat_rows = slab.reshape(S * l * bh, bw)
    rb, off = rows // bh, rows % bh
    dev = bmap2d[rb]                                  # [n, gw]
    out = flat_rows[dev * bh + off[:, None]]          # [n, gw, bw]
    return out.reshape(out.shape[0], -1)[:, :width]


@functools.partial(jax.jit, static_argnames=("grid",))
def _unblock_xla(slab, dev_map, *, grid: BlockGrid):
    """Reassemble a full tensor from resident slab blocks on device
    (the LM-serving load path: zero host-side materialization)."""
    S, l, bh, bw = slab.shape
    gh, gw = grid.grid
    blocks = jnp.take(slab.reshape(S * l, bh, bw), dev_map, axis=0)
    x2 = (blocks.reshape(gh, gw, bh, bw)
                .transpose(0, 2, 1, 3)
                .reshape(gh * bh, gw * bw))
    return x2[:grid.shape2d[0], :grid.shape2d[1]].reshape(grid.tensor_shape)


@functools.partial(jax.jit, static_argnames=("grid",))
def _matmul_xla(slab, bmap2d, x, *, grid: BlockGrid):
    W = _unblock_xla(slab, bmap2d.reshape(-1), grid=grid)
    W = W.reshape(grid.shape2d)
    return jnp.matmul(x[..., :grid.shape2d[0]], W,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _pad_pow2(n: int, floor: int = 8) -> int:
    p = floor
    while p < n:
        p <<= 1
    return p


class DevicePagePool:
    """Fixed-capacity HBM slab of deduplicated pages + slot remap."""

    def __init__(self, store: ModelStore, capacity_pages: int,
                 dtype=jnp.float32, kernel_mode: str = "auto",
                 device=None, stage_rows: int = 0):
        if kernel_mode not in ("auto", "pallas", "xla", "host"):
            raise ValueError(f"unknown kernel_mode {kernel_mode!r}")
        self.store = store
        bh, bw = store.cfg.dedup.block_shape
        self.block_shape = (bh, bw)
        self.blocks_per_page = store.cfg.blocks_per_page
        self.capacity = int(capacity_pages)
        # Borrow-staging tail (sharded serving): ``stage_rows`` extra
        # page rows allocated PAST the resident slots, written by
        # ShardedPagePool once per staging change.  Extended remaps
        # point borrowed pages at ``capacity + stage_idx``, so the
        # kernels read one stable buffer — no per-call slab concat.
        self.stage_rows = int(stage_rows)
        self.dtype = dtype
        self.kernel_mode = kernel_mode
        # Mesh placement: a sharded pool pins each shard's slab (and its
        # compute) to one device of the serving mesh; None = default.
        self.device = device
        rows = self.capacity + self.stage_rows
        # The preallocated HBM slab. jnp.zeros commits the allocation on
        # the default device up front; every load is an in-place-style
        # functional update of this one buffer.  In host mode the mirror
        # below is the tier's physical backing, so the device buffer is
        # never allocated at all.
        self.slab = None if self.mode() == "host" else self._put(jnp.zeros(
            (rows, self.blocks_per_page, bh, bw), dtype))
        # Host mirror, kept page-for-page identical with the slab: the
        # "host" kernel mode computes from it, and off-accelerator it is
        # the physical backing of the tier anyway.
        self.host_slab = np.zeros(
            (rows, self.blocks_per_page, bh, bw), np.float32)
        self.slot_of: Dict[int, int] = {}        # physical page id -> slot
        self._free: List[int] = list(range(self.capacity - 1, -1, -1))
        # page id -> slot as an int64 array (-1 = absent), maintained O(1)
        # per load/evict so per-batch remaps are pure vectorized lookups
        self._page_to_slot = np.full(store.packing.num_pages, -1,
                                     dtype=np.int64)
        self.generation = 0                      # bumped on load/evict/flush
        self.loads = 0
        self.evicts = 0
        # (model, tensor) -> (pack_gen, slab_gen, dev_map np.int32,
        #                     complete: no -1 holes)
        self._remap_cache: Dict[Tuple[str, str],
                                Tuple[int, int, np.ndarray, bool]] = {}
        # Batched/overlapped host->HBM movement (DESIGN.md §6): the
        # buffer pool's on_load_group callback lands in load_group(),
        # which stages a group's pages in ONE stacked buffer, ships it
        # with one device_put and commits it with one scatter.
        self.transfer = TransferEngine(self)

    def _put(self, x):
        """Commit an array to this pool's device (identity when unpinned)."""
        return x if self.device is None else jax.device_put(x, self.device)

    # ------------------------------------------------------ page movement --
    def load(self, pid: int) -> None:
        """BufferPool ``on_load``: transfer one page host->device into a
        free slab slot.  In host mode the mirror *is* the device tier
        (host DRAM), so the jnp slab is left untouched — pallas/xla modes
        do the real ``device_put`` + ``dynamic_update_slice`` transfer.

        ``store.page_array`` sources the page through the store's
        attached :class:`~repro.storage.PageBackend` when one is present
        (a store opened from SQLite / a directory / the object-store
        sim): slab faults reach all the way down to the storage tier,
        and the engines' grouped demand fetches prefault the batch's
        pages in one backend round trip first."""
        if pid in self.slot_of:
            return
        with get_tracer().span("page_load", kind="transfer",
                               pid=int(pid), pages=1):
            # fetch BEFORE taking a slot: a storage fault mid-fetch must
            # not leak a free slot (exception safety under fault injection)
            page = self.store.page_array(pid, dtype=np.float32)
            slot = self._free.pop()
            # time only the host->HBM leg: page_array may have faulted the
            # storage backend, which must never leak into the fitted
            # channel
            t0 = time.perf_counter()
            if self.mode() != "host":
                self.slab = jax.lax.dynamic_update_slice(
                    self.slab,
                    self._put(jnp.asarray(page[None], self.dtype)),
                    (slot, 0, 0, 0))
            self.host_slab[slot] = page
            self.slot_of[pid] = slot
            self._page_to_slot[pid] = slot
            self.generation += 1
            self.loads += 1
            self.transfer.record_single(time.perf_counter() - t0)

    def load_group(self, pids) -> None:
        """BufferPool ``on_load_group``: transfer a whole group of pages
        host->device as ONE staged stack + one scatter + one generation
        bump (vs. the per-page path's K round trips and K bumps).  Pages
        prestaged by the engine's double buffer commit from the already
        in-flight device bytes (see :class:`TransferEngine`)."""
        self.transfer.load_group(pids)

    def evict(self, pid: int) -> None:
        """BufferPool ``on_evict``: release the page's slot.  The slab
        bytes are left in place — a slot without a slot_of entry is
        unreachable through any remap, so no scrub is needed."""
        slot = self.slot_of.pop(pid, None)
        if slot is None:
            return
        self._free.append(slot)
        self._page_to_slot[pid] = -1
        self.generation += 1
        self.evicts += 1

    def flush(self) -> None:
        """Forget every resident page (store repacked: page ids renamed,
        and the page-id universe may have changed size)."""
        self.slot_of.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
        self._page_to_slot = np.full(self.store.packing.num_pages, -1,
                                     dtype=np.int64)
        self._remap_cache.clear()
        self.transfer.drop_pending()             # staged bytes are stale too
        self.generation += 1

    # ----------------------------------------------------------- queries --
    def resident_pages(self) -> Set[int]:
        return set(self.slot_of)

    def occupied_slots(self) -> Set[int]:
        return set(self.slot_of.values())

    def flat_pool(self) -> jnp.ndarray:
        """Kernel view of the slab (incl. any staging tail):
        [(capacity+stage_rows)*blocks_per_page, bh, bw]."""
        bh, bw = self.block_shape
        return self.slab.reshape(self.slab.shape[0] * self.blocks_per_page,
                                 bh, bw)

    def slot_page(self, slot: int) -> np.ndarray:
        """Host copy of one slab slot (tests / debugging)."""
        if self.mode() == "host":
            return self.host_slab[slot].copy()
        return np.asarray(self.slab[slot])

    def mode(self) -> str:
        """Resolved compute mode: pallas | xla | host."""
        if self.kernel_mode != "auto":
            return self.kernel_mode
        return "pallas" if jax.default_backend() == "tpu" else "host"

    def use_pallas(self) -> bool:
        return self.mode() == "pallas"

    # ------------------------------------------------------------- remap --
    def remap(self, vt: VirtualTensor,
              key: Optional[Tuple[str, str]] = None,
              strict: bool = True) -> Optional[np.ndarray]:
        """Rewrite a virtual tensor's physical flat block map into slab
        slot space with one vectorized lookup (cached per packing + slab
        generation under ``key``).

        ``strict=True`` returns None when *any* of the tensor's pages is
        not resident (whole-tensor consumers: unblock / virtual_matmul).
        ``strict=False`` returns the map with ``-1`` holes for absent
        pages — a row-gather caller that has already faulted its batch's
        pages (and verified them via :meth:`pages_resident`) only touches
        resident entries, so partial residency still serves off the slab.
        """
        hit = self._remap_cache.get(key) if key is not None else None
        if hit is not None and hit[0] == self.store.pack_generation \
                and hit[1] == self.generation:
            dev_map, complete = hit[2], hit[3]
        else:
            l = self.blocks_per_page
            slots = self._page_to_slot[vt.block_map // l]
            holes = slots < 0
            dev_map = np.where(holes, -1,
                               slots * l + vt.block_map % l).astype(np.int32)
            complete = not holes.any()
            if key is not None:
                self._remap_cache[key] = (self.store.pack_generation,
                                          self.generation, dev_map, complete)
        if strict and not complete:
            return None
        return dev_map

    def pages_resident(self, pages) -> bool:
        return all(p in self.slot_of for p in pages)

    # ------------------------------------------------------------ compute --
    def gather_rows(self, dev_map: np.ndarray, grid: BlockGrid,
                    rows: np.ndarray, pad: bool = False):
        """Rows of the virtual 2-D tensor, gathered from the resident
        slab.  Pallas mode runs ``dedup_embedding`` per column stripe;
        xla mode one jitted gather; host mode a numpy fancy-index gather
        from the slab mirror (returns np.ndarray).

        Sharded serving's borrowed pages live in the slab's own staging
        TAIL (``stage_rows`` past ``capacity`` — see ``__init__``), so
        an extended remap needs no extra buffer here.

        For the jit modes ``rows`` is padded to a power-of-two bucket so
        caches stay warm across varying batch row counts; ``pad=True``
        returns the padded ``[bucket, width]`` array (rows past ``n`` are
        row-0 garbage) so *downstream* jits also see stable shapes —
        indices into the first ``n`` rows are unaffected."""
        bh, bw = self.block_shape
        gh, gw = grid.grid
        width = grid.shape2d[1]
        rows = np.asarray(rows)      # repro: allow-host (index array)
        n = len(rows)
        bmap2d = dev_map.reshape(gh, gw)
        # Partial remaps carry -1 holes; negative indexing would silently
        # wrap to the wrong slab bytes, so a touched hole (the caller's
        # page set failed to cover its rows) must surface as None — the
        # engines then take the host fallback instead of serving garbage.
        if n and (bmap2d[np.unique(rows // bh)] < 0).any():
            return None
        mode = self.mode()
        l = self.blocks_per_page
        if mode == "host":
            with get_tracer().span("kernel", kind="kernel",
                                   op="gather_rows", mode=mode, rows=n):
                slab = self.host_slab
                flat_rows = slab.reshape(slab.shape[0] * l * bh, bw)
                rb, off = rows // bh, rows % bh
                out = flat_rows[bmap2d[rb] * bh + off[:, None]]  # [n,gw,bw]
                return out.reshape(n, gw * bw)[:, :width]
        # Pad with a *requested* row, not row 0: under partial residency
        # row 0's block may be absent and must never be touched.
        ids = np.full(_pad_pow2(max(n, 1)), rows[0] if n else 0, np.int32)
        ids[:n] = rows
        with get_tracer().span("kernel", kind="kernel", op="gather_rows",
                               mode=mode, rows=n):
            if mode == "pallas":
                pool = self.slab.reshape(self.slab.shape[0] * l, bh, bw)
                out = ops.dedup_embedding_striped(
                    self._put(jnp.asarray(ids)), pool,
                    self._put(jnp.asarray(bmap2d)), width=width)
            else:
                out = _gather_rows_xla(self.slab,
                                       self._put(jnp.asarray(bmap2d)),
                                       self._put(jnp.asarray(ids)),
                                       bh=bh, width=width)
        return out if pad else out[:n]

    def virtual_matmul(self, dev_map: np.ndarray, grid: BlockGrid, x):
        """``x @ W_virtual`` with W never densified: dedup_matmul streams
        slab blocks through the scalar-prefetched block map (pallas);
        host mode runs the same k-loop blockwise in numpy against the
        slab mirror."""
        bh, bw = self.block_shape
        gh, gw = grid.grid
        K, N = grid.shape2d
        bmap2d = dev_map.reshape(gh, gw)
        mode = self.mode()
        l = self.blocks_per_page
        if mode == "host":
            slab = self.host_slab
            blocks = slab.reshape(slab.shape[0] * l, bh, bw)
            # repro: allow-host — host-mode kernel: the mirror IS the tier
            x = np.asarray(x, dtype=np.float32)
            xp = x
            if x.shape[-1] != gh * bh:
                assert x.shape[-1] == K, (x.shape, K)
                xp = np.zeros(x.shape[:-1] + (gh * bh,), np.float32)
                xp[..., :K] = x
            y = np.zeros(x.shape[:-1] + (gw * bw,), np.float32)
            for j in range(gw):                  # the kernel's (j, k) loops
                acc = y[..., j * bw:(j + 1) * bw]
                for k in range(gh):
                    acc += xp[..., k * bh:(k + 1) * bh] \
                        @ blocks[bmap2d[k, j]]
            return y[..., :N]
        with get_tracer().span("kernel", kind="kernel",
                               op="virtual_matmul", mode=mode):
            if mode == "pallas":
                pad = gh * bh - x.shape[-1]
                if pad:
                    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
                    x = jnp.pad(x, widths)
                bm = 128 if jax.default_backend() == "tpu" else 8
                pool = self.slab.reshape(self.slab.shape[0] * l, bh, bw)
                y = ops.dedup_matmul(self._put(x), pool,
                                     self._put(jnp.asarray(bmap2d)), bm=bm)
                return y[..., :N]
            if x.shape[-1] != gh * bh:  # _matmul_xla slices x to K itself
                assert x.shape[-1] == K, (x.shape, K)
            return _matmul_xla(self.slab,
                               self._put(jnp.asarray(bmap2d)),
                               self._put(x), grid=grid)

    def unblock(self, dev_map: np.ndarray, grid: BlockGrid):
        """Full tensor reassembled from resident slab blocks (the LM
        model-switch path; np from the mirror in host mode, on-device
        otherwise)."""
        l = self.blocks_per_page
        bh, bw = self.block_shape
        mode = self.mode()
        with get_tracer().span("kernel", kind="kernel", op="unblock",
                               mode=mode):
            if mode == "host":
                from ..core.blocks import unblock_tensor
                slab = self.host_slab
                blocks = slab.reshape(slab.shape[0] * l, bh, bw)[dev_map]
                return unblock_tensor(blocks, grid)
            return _unblock_xla(self.slab,
                                self._put(jnp.asarray(dev_map)), grid=grid)
