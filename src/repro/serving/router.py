"""Request router for sharded page-pool serving.

A batch's page working set rarely lives on one shard only; the router
sends the batch to the shard that *owns the majority of its cover
pages* (placement score = |pages ∩ shard's owned set|, ties to the
lowest shard id — except replication ties, which spread to the tied
shard with the lowest observed load so replicas actually absorb
traffic), and splits the set into:

  * ``owned``    — pages placement assigned to the chosen shard.  These
    are demand-faulted through that shard's own buffer pool (shard-local
    eviction), preserving the per-shard residency invariant.
  * ``borrowed`` — the minority pages owned elsewhere.  These are never
    loaded into the chosen shard's slab; the borrow protocol stages
    their bytes from an *owning* shard's host mirror (see
    ``shard_pool.ShardedPagePool.stage_borrows``), charged to the fetch
    channel like any other miss.

The router is pure placement arithmetic — set intersections over the
current :class:`~repro.serving.shard_pool.Placement` — so routing a
batch costs no weight or storage access, exactly like the affinity
scheduler's page-set scoring.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from ..obs import get_tracer

__all__ = ["RouteDecision", "ShardRouter"]


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Where one batch runs, and how its page set splits there."""
    shard: int
    owned: Tuple[int, ...]       # pages the chosen shard owns (sorted)
    borrowed: Tuple[int, ...]    # minority pages owned elsewhere (sorted)
    pack_generation: int         # placement generation this was routed under

    @property
    def page_set(self) -> frozenset:
        return frozenset(self.owned) | frozenset(self.borrowed)


class ShardRouter:
    """Majority-cover routing over a placement provider.

    ``placement_fn`` returns the current
    :class:`~repro.serving.shard_pool.Placement` (rebuilt per pack
    generation), so routing decisions can never outlive the packing
    whose page ids they were made from.
    """

    def __init__(self, placement_fn: Callable,
                 balance_replicas: bool = True,
                 dead_fn: Optional[Callable] = None):
        self._placement = placement_fn
        # Failover awareness: ``dead_fn`` returns the currently-dead
        # shard ids (ShardedPagePool.dead).  Routing only ever considers
        # alive shards; a dead shard's owned pages fall into the batch's
        # ``borrowed`` minority and serve via the borrow-staging path
        # from surviving owners or the store.
        self._dead = dead_fn or (lambda: ())
        # Replica load balancing (ROADMAP): when several shards tie on
        # cover *because the batch's pages are replicated on them*, send
        # the batch to the least-loaded of the tied shards instead of
        # always the lowest id — replication only pays off if the
        # replicas actually absorb traffic.  ``rebalanced`` counts the
        # batches this moved off the default (lowest-id) shard.
        self.balance_replicas = balance_replicas
        self.rebalanced = 0
        # Routing-DECISION counters (what the router asked for).  What
        # actually executed — borrows staged, fallbacks, per-shard batch
        # totals — lives on the serving ServeStats; the two differ when
        # e.g. an oversized borrow set is refused staging.
        self.batches_per_shard: Dict[int, int] = {}
        self.borrowed_pages = 0

    def choose(self, pages, record: bool = True) -> int:
        """The shard owning the majority of ``pages``.  Ties go to the
        lowest shard id — except replication ties (the tied shards all
        hold replicas of the batch's shared pages), which go to the tied
        shard with the fewest batches routed so far, so replicated reads
        move off the hot shard.  ``record=False`` (advisory probes)
        never bumps the ``rebalanced`` proof counter."""
        pl = self._placement()
        dead = set(self._dead())
        alive = [s for s in range(pl.num_shards) if s not in dead]
        if not alive:
            raise RuntimeError("no alive shards to route to "
                               f"({pl.num_shards} shards, all failed)")
        ps = set(pages)
        if not ps or len(alive) == 1:
            return alive[0]
        scores = {s: len(ps & pl.owned_sets[s]) for s in alive}
        best_score = max(scores.values())
        tied = [s for s in alive if scores[s] == best_score]
        if len(tied) > 1 and self.balance_replicas \
                and ps & pl.replicated:
            chosen = min(tied,
                         key=lambda s: (self.batches_per_shard.get(s, 0), s))
            if record and chosen != tied[0]:
                self.rebalanced += 1
            return chosen
        return tied[0]

    def split(self, pages, shard: int) -> Tuple[List[int], List[int]]:
        """(owned, borrowed) of ``pages`` relative to ``shard``."""
        pl = self._placement()
        owned, borrowed = [], []
        for p in sorted(set(int(p) for p in pages)):
            (owned if shard in pl.shards_of(p) else borrowed).append(p)
        return owned, borrowed

    def route(self, pages, record: bool = True) -> RouteDecision:
        """Route one batch; ``record=False`` recomputes the decision
        without counting stats (deterministic given the same observed
        per-shard loads)."""
        pl = self._placement()
        shard = self.choose(pages, record=record)
        owned, borrowed = self.split(pages, shard)
        if record:
            self.batches_per_shard[shard] = \
                self.batches_per_shard.get(shard, 0) + 1
            self.borrowed_pages += len(borrowed)
            tr = get_tracer()
            if tr.enabled:
                # advisory probes (record=False) never reach the trace:
                # one route event per executed batch, same as the stats
                tr.event("route", kind="policy", shard=shard,
                         owned=len(owned), borrowed=len(borrowed))
        return RouteDecision(shard, tuple(owned), tuple(borrowed),
                             pl.pack_generation)
