"""Request-level serving front end: SLO-driven continuous batching and
cost-based admission over the existing engines.

The generator (``serving/traffic.py``) produces an open-loop arrival
stream; this module turns it into engine batches:

  arrival -> admission -> formation -> (engine) schedule -> route -> serve

* **Continuous batch formation** — queued requests for the same model
  merge into one engine batch.  A model's batch closes when it reaches
  ``max_batch`` or when the oldest member's SLO slack no longer covers
  the batch's estimated service time (waiting any longer would blow the
  deadline the batch was being held open to amortize).
* **Cost-based admission** — among closeable batches the frontend
  dispatches the one with the lowest estimated fetch cost per request:
  the candidate's page working set (``ModelStore.model_pages`` /
  the batch's own page estimate) is diffed against the routed shard's
  *own* resident set (``ShardRouter`` + per-shard residency), so a
  batch whose pages are already slab-resident on its shard — the dedup
  affinity win — goes first and cold batches pay their fetch when they
  must, not ahead of hot ones.
* **Shedding** — a request whose deadline cannot be met even by
  dispatching *now* (``deadline < now + est_service``) is shed instead
  of served dead-on-arrival; shed counts land in
  :class:`~repro.serving.engine.ServeStats` and goodput reports the
  fraction of offered requests served within SLO.
* **Virtual-clock discipline** — the whole simulation runs on a
  :class:`~repro.serving.traffic.VirtualClock`: queueing time is idle
  channel time, fetch time is the engine's (deterministic) virtual
  storage seconds, compute time is either a deterministic
  :class:`BatchComputeModel` (benchmarks: bit-stable under a seed) or
  the engine's measured wall compute folded onto the clock.  The
  ``frontend-clock`` lint enforces that no path here consumes time
  without charging a named channel.

``policy="naive"`` is the control: per-arrival FIFO dispatch, one
request per batch, no admission, no shedding — what a serving tier
without a front end does.  ``BENCH_traffic.json`` measures both.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..obs import get_tracer
from .engine import LMServingEngine, ServeStats
from .traffic import Request, VirtualClock

__all__ = ["BatchComputeModel", "RequestLedger", "ServingFrontend"]

#: EMA smoothing for observed per-model arrival rates and compute cost
#: (mirrors BufferPool's rate_ema so the λ feeds compare like for like)
_RATE_EMA = 0.2
_EPS = 1e-12


def _residual_split(total: float, part: float) -> Tuple[float, float]:
    """Split ``total`` into ``(a, b)`` with ``a + b == total`` *exactly*
    in floats and ``a`` as close to ``part`` as that allows.  Trace
    stage breakdowns use this so per-request stage sums reproduce the
    reported latency bit-for-bit (naive ``a + (total - a)`` can miss
    ``total`` by an ulp)."""
    a = part
    for _ in range(4):
        b = total - a
        if a + b == total:
            return a, b
        a = total - b
    return 0.0, total


@dataclasses.dataclass
class BatchComputeModel:
    """Deterministic per-batch compute-time model for the virtual
    clock: ``base + per_request * n`` seconds per dispatched batch.
    Benchmarks use it so latency distributions are bit-stable under a
    fixed seed; without one the frontend folds the engine's measured
    wall compute onto the clock instead."""
    base: float = 5e-4
    per_request: float = 5e-5

    def batch_seconds(self, n: int) -> float:
        """Virtual compute seconds for an ``n``-request batch."""
        return self.base + self.per_request * max(0, int(n))


@dataclasses.dataclass
class RequestLedger:
    """At-most-once request accounting that survives restarts
    (DESIGN.md §11).

    A request id moves ``offered`` → queued (offered minus every other
    set) → ``in_flight`` → ``served`` | ``shed``.  ``in_flight`` is the
    crash window: the dispatch intent is persisted *before* the engine
    computes, and the id only becomes ``served`` after results are
    captured.  A restart therefore re-admits queued and in-flight ids
    (their results died with the process; recompute is deterministic)
    and never re-serves a served one — delivery is at-most-once, and
    nothing is dropped beyond explicit sheds.
    """
    offered: Set[int] = dataclasses.field(default_factory=set)
    served: Set[int] = dataclasses.field(default_factory=set)
    shed: Set[int] = dataclasses.field(default_factory=set)
    in_flight: Set[int] = dataclasses.field(default_factory=set)
    readmitted: int = 0                  # cumulative across restarts

    def admit(self, rid: int) -> None:
        self.offered.add(int(rid))

    def record_served(self, rid: int) -> None:
        self.in_flight.discard(int(rid))
        self.served.add(int(rid))

    def record_shed(self, rid: int) -> None:
        self.in_flight.discard(int(rid))
        self.shed.add(int(rid))

    def to_dict(self) -> Dict:
        return {"offered": sorted(self.offered),
                "served": sorted(self.served),
                "shed": sorted(self.shed),
                "in_flight": sorted(self.in_flight),
                "readmitted": int(self.readmitted)}

    @classmethod
    def from_dict(cls, d: Dict) -> "RequestLedger":
        return cls(offered={int(r) for r in d["offered"]},
                   served={int(r) for r in d["served"]},
                   shed={int(r) for r in d["shed"]},
                   in_flight={int(r) for r in d["in_flight"]},
                   readmitted=int(d.get("readmitted", 0)))


class ServingFrontend:
    """Continuous-batching front end over one serving engine.

    ``engine``: an :class:`EmbeddingServingEngine` or
    :class:`LMServingEngine` (1 or N shards — routing happens inside
    the engine's server).  ``max_batch``: formation cap per dispatched
    batch.  ``policy``: ``"slo"`` (formation + admission + shedding) or
    ``"naive"`` (per-arrival FIFO control).  ``compute_model``: a
    :class:`BatchComputeModel` for deterministic virtual compute;
    ``None`` folds measured wall compute onto the clock.
    ``capture=True`` keeps each request's result rows (logits / tokens)
    in :attr:`results` for the bit-equality tests.

    When the engine has a prefetcher, the frontend feeds it the
    *observed* per-model arrival rates (EMA over the virtual clock) via
    ``Prefetcher.attach_rates`` — the λ of Eq. 2 measured at the door
    instead of back-derived from pool access counts.
    """

    POLICIES = ("slo", "naive")

    def __init__(self, engine, max_batch: int = 8, policy: str = "slo",
                 compute_model: Optional[BatchComputeModel] = None,
                 capture: bool = True,
                 snapshot_path: Optional[str] = None):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"have {self.POLICIES}")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.policy = policy
        self.compute_model = compute_model
        self.capture = capture
        # warm restart (DESIGN.md §11): when set, the frontend persists
        # its snapshot around every dispatch (atomic rename), so a
        # killed process resumes via ServingFrontend.restore
        self.snapshot_path = snapshot_path
        self.ledger = RequestLedger()
        self._resumed = False
        self.clock = VirtualClock()
        self.results: Dict[int, np.ndarray] = {}
        self.dispatched: List[Tuple[str, List[Request]]] = []
        self._lm = isinstance(engine, LMServingEngine)
        self._queues: Dict[str, List[Request]] = {}   # model -> FIFO
        self._fifo: List[Request] = []                # naive global FIFO
        self._rates: Dict[str, float] = {}            # observed λ (EMA)
        self._last_arrival: Dict[str, float] = {}
        self._cpr: Optional[float] = None             # EMA compute/request
        pf = getattr(engine, "prefetcher", None)
        if pf is not None and hasattr(pf, "attach_rates"):
            pf.attach_rates(self.arrival_rates)

    # -- observability -----------------------------------------------------
    def arrival_rates(self) -> Dict[str, float]:
        """Observed per-model arrival rates (requests per virtual
        second, EMA-smoothed) — the λ feed for the prefetcher."""
        return dict(self._rates)

    @property
    def stats(self) -> ServeStats:
        """The engine's stats object (request-level counters included)."""
        return self.engine.stats

    # -- sizing helpers ----------------------------------------------------
    def _rows(self, req: Request) -> int:
        payload = req.payload[0] if self._lm else req.payload
        return int(np.asarray(payload).shape[0])

    def _merge(self, reqs: List[Request]):
        """One engine payload from a batch's requests (same model)."""
        if self._lm:
            steps = {int(r.payload[1]) for r in reqs}
            if len(steps) != 1:
                raise ValueError(
                    f"cannot merge LM requests with mixed decode steps "
                    f"{sorted(steps)} into one batch")
            prompts = np.concatenate([np.asarray(r.payload[0])
                                      for r in reqs], axis=0)
            return prompts, steps.pop()
        return np.concatenate([np.asarray(r.payload) for r in reqs],
                              axis=0)

    # -- cost model --------------------------------------------------------
    def _batch_pages(self, model: str, reqs: List[Request]) -> List[int]:
        server = self.engine.server
        if self._lm:
            return server.store.model_pages(model)
        rows = np.unique(np.concatenate(
            [np.asarray(r.payload).reshape(-1) for r in reqs]))
        return server.embedding_rows_pages(
            model, self.engine.embed_tensor, rows)

    def _est_fetch(self, model: str, reqs: List[Request]) -> float:
        """Estimated virtual fetch seconds for this batch: its page
        working set diffed against the shard the router would place it
        on (advisory route, nothing recorded), costed as one grouped
        fetch.  This is the admission score — misses against the
        routed shard's *own* residency, so dedup affinity (pages kept
        hot by other variants on the same shard) directly lowers a
        candidate's price."""
        server = self.engine.server
        pages = self._batch_pages(model, reqs)
        router = getattr(server, "router", None)
        if router is not None:
            shard = router.route(pages, record=False).shard
            resident = server.shard_resident_pages(shard)
        else:
            resident = server.shard_resident_pages()
        misses = len(set(pages) - resident)
        return server.storage.fetch_group_seconds(server.page_bytes,
                                                  misses)

    def _est_compute(self, n: int) -> float:
        if self.compute_model is not None:
            return self.compute_model.batch_seconds(n)
        return (self._cpr or 0.0) * n

    def _est_service(self, model: str, reqs: List[Request]) -> float:
        rows = sum(self._rows(r) for r in reqs)
        return self._est_fetch(model, reqs) + self._est_compute(rows)

    # -- queue management --------------------------------------------------
    def _pending(self) -> int:
        if self.policy == "naive":
            return len(self._fifo)
        return sum(len(q) for q in self._queues.values())

    def _admit(self, req: Request) -> None:
        """Enqueue one arrival and fold it into the λ estimate."""
        # offered counts at admission (not run() entry) so a killed run
        # books only what it actually saw and a resume never re-counts
        self.engine.stats.offered_requests += 1
        self.ledger.admit(req.rid)
        last = self._last_arrival.get(req.model)
        self._last_arrival[req.model] = req.arrival_t
        if last is not None and req.arrival_t > last:
            inst = 1.0 / (req.arrival_t - last)
            prev = self._rates.get(req.model)
            self._rates[req.model] = inst if prev is None else \
                (1.0 - _RATE_EMA) * prev + _RATE_EMA * inst
        if self.policy == "naive":
            self._fifo.append(req)
        else:
            self._queues.setdefault(req.model, []).append(req)

    # -- formation ---------------------------------------------------------
    def _form(self) -> Optional[Tuple[str, List[Request]]]:
        """Pick the next batch to dispatch, or None to keep waiting.

        A model's queue is *closeable* when it holds ``max_batch``
        requests (nothing to gain by waiting) or when its oldest
        member's slack no longer covers the estimated service time
        (*forced*: wait any longer and the deadline dies).  Forced
        batches dispatch first (earliest deadline); otherwise the
        cheapest candidate per request wins — cost-based admission."""
        if self.policy == "naive":
            if not self._fifo:
                return None
            req = self._fifo.pop(0)
            return req.model, [req]
        forced: List[Tuple[float, str]] = []
        full: List[Tuple[float, float, str]] = []
        now = self.clock.now
        for model, q in self._queues.items():
            take = q[: self.max_batch]
            est = self._est_service(model, take)
            if now >= take[0].deadline - est - _EPS:
                forced.append((take[0].deadline, model))
            elif len(q) >= self.max_batch:
                n = max(1, sum(self._rows(r) for r in take))
                full.append((self._est_fetch(model, take) / n,
                             take[0].arrival_t, model))
        if forced:
            forced.sort()
            model = forced[0][1]
        elif full:
            full.sort()
            model = full[0][2]
        else:
            return None
        q = self._queues[model]
        batch, self._queues[model] = q[: self.max_batch], q[self.max_batch:]
        if not self._queues[model]:
            del self._queues[model]
        return model, batch

    def _next_forced_time(self) -> Optional[float]:
        """Earliest future instant at which some queue becomes forced
        (its oldest member's slack hits the estimated service time)."""
        out = None
        for model, q in self._queues.items():
            take = q[: self.max_batch]
            t = take[0].deadline - self._est_service(model, take)
            if out is None or t < out:
                out = t
        return out

    # -- dispatch ----------------------------------------------------------
    def _capture_results(self, kept: List[Request]) -> None:
        out = self.engine.last_tokens if self._lm \
            else self.engine.last_logits
        if out is None:
            return
        out = np.asarray(out)
        row = 0
        for r in kept:
            n = self._rows(r)
            self.results[r.rid] = out[row: row + n].copy()
            row += n

    def _dispatch(self, model: str, batch: List[Request]) -> None:
        """Shed the dead, serve the rest, charge the clock, record
        per-request latencies."""
        tr = get_tracer()
        st: ServeStats = self.engine.stats
        kept = batch
        if self.policy == "slo":
            est = self._est_service(model, batch)
            kept = [r for r in batch
                    if r.deadline >= self.clock.now + est - _EPS]
            st.shed_requests += len(batch) - len(kept)
            kept_rids = {r.rid for r in kept}
            for r in batch:
                if r.rid not in kept_rids:
                    self.ledger.record_shed(r.rid)
            if tr.enabled and len(kept) < len(batch):
                now = self.clock.now
                for r in batch:
                    if r.deadline >= now + est - _EPS:
                        continue
                    # a shed request's tree is queue-only: no service
                    tr.emit("request", r.arrival_t, now, kind="request",
                            rid=r.rid, model=model, shed=True,
                            slo_miss=False, queue_s=now - r.arrival_t,
                            service_s=0.0, fetch_s=0.0, compute_s=0.0,
                            latency_s=now - r.arrival_t)
            if not kept:
                self._persist()
                return
        # dispatch intent: in-flight ids hit the durable snapshot BEFORE
        # the engine computes, so a crash from here to the served mark
        # re-admits exactly these requests on restart (at-most-once)
        for r in kept:
            self.ledger.in_flight.add(r.rid)
        self._persist()
        start = self.clock.now
        f0, c0 = st.fetch_seconds, st.compute_seconds
        with tr.span("dispatch", kind="frontend", model=model,
                     requests=len(kept)) as dsp:
            if self._lm:
                prompts, steps = self._merge(kept)
                self.engine.submit(model, prompts, steps=steps)
            else:
                self.engine.submit(model, self._merge(kept))
            self.engine.run(max_batches=1)
            d_fetch = st.fetch_seconds - f0
            rows = sum(self._rows(r) for r in kept)
            if self.compute_model is not None:
                d_compute = self.compute_model.batch_seconds(rows)
            else:
                d_compute = st.compute_seconds - c0
            channel = self.engine.server.storage.channel
            # charged spans: the exact floats handed to clock.advance,
            # so span channel totals replay the clock ledger bit-for-bit
            with tr.span("fetch", kind="frontend", channel=channel,
                         charge=d_fetch):
                self.clock.advance(d_fetch, channel)
            with tr.span("compute", kind="frontend", channel="compute",
                         charge=d_compute):
                self.clock.advance(d_compute, "compute")
            dsp.set(fetch_s=d_fetch, compute_s=d_compute)
        done = self.clock.now
        service = done - start
        inst = d_compute / max(1, rows)
        self._cpr = inst if self._cpr is None else \
            (1.0 - _RATE_EMA) * self._cpr + _RATE_EMA * inst
        for r in kept:
            st.queue_latencies.append(start - r.arrival_t)
            st.service_latencies.append(service)
            st.request_latencies.append(done - r.arrival_t)
            missed = done > r.deadline + _EPS
            if missed:
                st.slo_misses += 1
            if tr.enabled:
                # residual stage splits: queue + service == latency and
                # fetch + compute == service hold *exactly* in floats
                latency = done - r.arrival_t
                queue_s, service_s = _residual_split(
                    latency, start - r.arrival_t)
                fetch_s, compute_s = _residual_split(service_s, d_fetch)
                tr.emit("request", r.arrival_t, done, kind="request",
                        rid=r.rid, model=model, shed=False,
                        slo_miss=missed, queue_s=queue_s,
                        service_s=service_s, fetch_s=fetch_s,
                        compute_s=compute_s, latency_s=latency)
        self.dispatched.append((model, kept))
        if self.capture:
            self._capture_results(kept)
        for r in kept:
            self.ledger.record_served(r.rid)
        self._persist()

    # -- the event loop ----------------------------------------------------
    def run(self, requests: List[Request],
            max_dispatches: Optional[int] = None) -> ServeStats:
        """Serve an arrival stream to completion (discrete-event loop
        on the virtual clock); returns the engine's stats with the
        request-level counters filled in.

        Ids the ledger already knows — served, shed, or re-admitted by
        :meth:`restore` — are not offered again, so a resumed run can
        be handed the SAME regenerated stream and picks up exactly
        where the crash left it.  ``max_dispatches`` stops after that
        many batches (the kill-and-restart harness; the books stay
        balanced, pending requests wait in the persisted snapshot)."""
        tr = get_tracer()
        # on a resumed run the caller hands back the SAME regenerated
        # stream, so ids the ledger already offered are filtered out;
        # a fresh frontend must NOT filter (independent streams may
        # legitimately reuse rid numbering)
        if self._resumed:
            reqs = sorted((r for r in requests
                           if r.rid not in self.ledger.offered),
                          key=lambda r: (r.arrival_t, r.rid))
        else:
            reqs = sorted(requests, key=lambda r: (r.arrival_t, r.rid))
        st: ServeStats = self.engine.stats
        i = 0
        dispatched = 0
        while i < len(reqs) or self._pending():
            if max_dispatches is not None and dispatched >= max_dispatches:
                break
            while i < len(reqs) and reqs[i].arrival_t <= self.clock.now \
                    + _EPS:
                if tr.enabled:
                    tr.event("admit", kind="frontend", rid=reqs[i].rid,
                             model=reqs[i].model)
                self._admit(reqs[i])
                i += 1
            batch = self._form()
            if batch is not None:
                self._dispatch(*batch)
                dispatched += 1
                continue
            # nothing closeable: idle to the next decision point (next
            # arrival, or the instant a queue's slack runs out).  The
            # charged idle span is arithmetically tick_to(): same dt,
            # same single advance.
            candidates = []
            if i < len(reqs):
                candidates.append(reqs[i].arrival_t)
            forced = self._next_forced_time()
            if forced is not None:
                candidates.append(forced)
            if not candidates:
                break
            t = max(min(candidates), self.clock.now)
            if t > self.clock.now:
                dt = t - self.clock.now
                with tr.span("idle", kind="frontend", channel="idle",
                             charge=dt):
                    self.clock.advance(dt, "idle")
        # a run must leave the books balanced: every simulated second
        # in a named channel, and (when tracing this clock) every
        # charged second witnessed by a span.  A *resumed* clock
        # carries pre-crash channel time no span of this process
        # witnessed, so the span cross-check only applies to runs that
        # started on this tracer's watch.
        self._persist()
        self.clock.assert_conserved()
        if getattr(tr, "clock", None) is self.clock and not self._resumed:
            tr.assert_matches_clock(self.clock)
        return st

    # -- warm restart ------------------------------------------------------
    def pending_requests(self) -> int:
        """Requests queued (including restart re-admissions) but not
        yet dispatched or shed."""
        return self._pending()

    def assert_ledger_conserved(self) -> None:
        """The at-most-once book balance: ``served + shed + in-flight +
        queued == offered`` with no id in two terminal states."""
        led = self.ledger
        dup = led.served & led.shed
        if dup:
            raise AssertionError(
                f"requests both served and shed: {sorted(dup)[:5]}")
        resolved = (len(led.served) + len(led.shed)
                    + len(led.in_flight) + self._pending())
        if resolved != len(led.offered):
            raise AssertionError(
                f"request ledger leaked: {len(led.offered)} offered but "
                f"{len(led.served)} served + {len(led.shed)} shed + "
                f"{len(led.in_flight)} in-flight + {self._pending()} "
                "queued")

    #: ServeStats fields a snapshot carries across a restart; scalars
    #: merge additively into the fresh engine's stats, lists extend
    _SNAP_STATS = ("requests", "batches", "offered_requests",
                   "shed_requests", "slo_misses", "readmitted_requests",
                   "fetch_seconds", "compute_seconds", "pages_fetched",
                   "queue_latencies", "service_latencies",
                   "request_latencies")

    def snapshot(self) -> Dict:
        """JSON-safe frontend state: clock ledger, queued request ids,
        the at-most-once ledger, λ/compute estimators and the
        request-level stats.  Payloads are NOT serialized — a restart
        regenerates the (seeded, deterministic) request stream and
        :meth:`restore` re-binds ids to the regenerated objects."""
        st = self.engine.stats
        stats = {}
        for key in self._SNAP_STATS:
            v = getattr(st, key)
            stats[key] = list(v) if isinstance(v, list) else v
        return {
            "version": 1,
            "policy": self.policy,
            "max_batch": self.max_batch,
            "clock": self.clock.snapshot(),
            "queued": {m: [r.rid for r in q]
                       for m, q in self._queues.items()},
            "fifo": [r.rid for r in self._fifo],
            "ledger": self.ledger.to_dict(),
            "rates": dict(self._rates),
            "last_arrival": dict(self._last_arrival),
            "cpr": self._cpr,
            "stats": stats,
        }

    def _persist(self) -> None:
        if self.snapshot_path is None:
            return
        tmp = f"{self.snapshot_path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.snapshot(), f)
        os.replace(tmp, self.snapshot_path)   # never a torn snapshot

    @classmethod
    def restore(cls, engine, snap: Dict, requests: List[Request],
                compute_model: Optional[BatchComputeModel] = None,
                capture: bool = True,
                snapshot_path: Optional[str] = None) -> "ServingFrontend":
        """Warm restart from a :meth:`snapshot` (or its JSON) after a
        crash: a FRESH engine (its pools rebuild lazily from the
        recovered store) plus the snapshot's clock/ledger/queues.

        ``requests`` must contain every id the snapshot references —
        the deterministic regeneration of the original stream.  Queued
        ids re-enter their queues; in-flight ids (dispatched, never
        acknowledged) are re-admitted for recompute.  Both count as
        re-admissions in the ledger and in
        ``ServeStats.readmitted_requests``."""
        fe = cls(engine, max_batch=int(snap["max_batch"]),
                 policy=str(snap["policy"]), compute_model=compute_model,
                 capture=capture, snapshot_path=snapshot_path)
        fe.clock = VirtualClock.from_snapshot(snap["clock"])
        fe.ledger = RequestLedger.from_dict(snap["ledger"])
        fe._rates = {str(m): float(v) for m, v in snap["rates"].items()}
        fe._last_arrival = {str(m): float(v)
                            for m, v in snap["last_arrival"].items()}
        fe._cpr = None if snap["cpr"] is None else float(snap["cpr"])
        by_rid = {r.rid: r for r in requests}
        readmitted = 0
        for model, rids in snap["queued"].items():
            fe._queues[model] = [by_rid[rid] for rid in rids]
            readmitted += len(rids)
        fe._fifo = [by_rid[rid] for rid in snap["fifo"]]
        readmitted += len(fe._fifo)
        # in-flight = the crash window: dispatched, never acknowledged.
        # The results died with the process; re-queue for deterministic
        # recompute — delivery stays at-most-once because served ids
        # are never offered again.
        for rid in sorted(fe.ledger.in_flight):
            req = by_rid[rid]
            if fe.policy == "naive":
                fe._fifo.append(req)
            else:
                fe._queues.setdefault(req.model, []).append(req)
            readmitted += 1
        fe.ledger.in_flight.clear()
        # in-flight ids were dispatched first but re-entered last:
        # restore arrival order so EDF/FIFO formation is unchanged
        for q in fe._queues.values():
            q.sort(key=lambda r: (r.arrival_t, r.rid))
        fe._fifo.sort(key=lambda r: (r.arrival_t, r.rid))
        st: ServeStats = engine.stats
        for key, v in snap["stats"].items():
            cur = getattr(st, key)
            if isinstance(cur, list):
                cur.extend(v)
            elif isinstance(cur, float):
                setattr(st, key, cur + float(v))
            else:
                setattr(st, key, cur + int(v))
        fe.ledger.readmitted += readmitted
        st.readmitted_requests += readmitted
        fe._resumed = True
        return fe
