"""Batched, overlapped host->HBM page transfers (DESIGN.md §6).

The per-page miss path pays K serialized host->HBM round trips for a
batch with K misses: one ``jax.device_put`` plus one slab-sized
``dynamic_update_slice`` each (``DevicePagePool.load``).  The
:class:`TransferEngine` is the grouped alternative the buffer pool's
``on_load_group`` callback drives:

  * **coalesce** — a group's pages are assembled into ONE stacked host
    staging buffer (``ModelStore.page_stack``: a single grouped backend
    fault plus one vectorized gather, never K ``page_array`` calls);
  * **one transfer** — the stack ships with a single ``device_put`` and
    commits with a single scatter (``slab.at[slots].set``), so the slab
    is rewritten once per group, not once per page;
  * **one generation bump** — downstream remap caches are invalidated
    once per group instead of K times;
  * **double buffering** — :meth:`stage` lets the serving engine issue
    the *next* batch's transfer while the current batch computes.  JAX
    dispatch is asynchronous, so the ``device_put`` overlaps the
    in-flight compute; when the group is later committed the bytes are
    already device-side and the commit is just the scatter.  Staged-
    ahead bytes are counted as *overlapped* (``ServeStats.
    overlap_fraction``).

Every movement — grouped or the pool's per-page fallback — is recorded
as an issue-side ``(pages, bytes, seconds)`` sample for observability;
:meth:`storage_model` fits ``seconds = seek + bytes / bandwidth`` over
a *blocking* :meth:`measure` sweep (serving samples time async
dispatch, not the transfer), so the host<->HBM channel of the virtual
clock is charged at the measured group-transfer bandwidth of this
machine instead of a preset per-page guess.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import get_tracer

__all__ = ["TransferStats", "PendingGroup", "TransferEngine",
           "fit_channel"]

#: samples kept for the bandwidth fit (serving runs are unbounded)
_MAX_RECORDS = 512


def _bucket_pad(*arrs: np.ndarray):
    """Pad index arrays (all the same length) to the next power of two
    by repeating their first element — duplicate gathers/writes of
    identical rows are harmless — so varying group sizes reuse a few
    compiled gather/scatter shapes instead of recompiling per size."""
    n = len(arrs[0])
    bucket = 1
    while bucket < n:
        bucket <<= 1
    if bucket == n:
        return arrs if len(arrs) > 1 else arrs[0]
    out = tuple(np.concatenate([a, np.full(bucket - n, a[0], a.dtype)])
                for a in arrs)
    return out if len(out) > 1 else out[0]


@dataclasses.dataclass
class TransferStats:
    """Host->HBM movement counters for one TransferEngine."""
    groups: int = 0              # commit operations (a per-page load = 1)
    pages: int = 0               # pages moved host->HBM
    bytes: int = 0               # bytes moved host->HBM
    seconds: float = 0.0         # issue-side wall seconds (async dispatch)
    overlapped_bytes: int = 0    # bytes that were staged ahead of demand
    staged_groups: int = 0       # prestage() calls that issued a transfer
    records: List[Tuple[int, int, float]] = \
        dataclasses.field(default_factory=list)   # (pages, bytes, seconds)

    def record(self, pages: int, nbytes: int, seconds: float,
               overlapped_bytes: int = 0) -> None:
        self.groups += 1
        self.pages += pages
        self.bytes += nbytes
        self.seconds += seconds
        self.overlapped_bytes += overlapped_bytes
        if len(self.records) < _MAX_RECORDS:
            self.records.append((pages, nbytes, seconds))

    @property
    def overlap_fraction(self) -> float:
        return self.overlapped_bytes / self.bytes if self.bytes else 0.0


@dataclasses.dataclass
class PendingGroup:
    """A staged (not yet committed) transfer: host stack assembled, the
    device copy already issued (async) when the pool has a device slab."""
    index: Dict[int, int]            # pid -> row in the stack
    host: np.ndarray                 # [k, l, bh, bw] staging buffer
    dev: Optional[object]            # device copy (None in host mode)
    pack_generation: int


def fit_channel(records: Sequence[Tuple[int, int, float]]
                ) -> Tuple[float, float]:
    """Least-squares ``seconds = seek + bytes/bandwidth`` over measured
    group samples; returns ``(bandwidth B/s, seek seconds)`` clamped to
    sane ranges (degenerate sample sets fall back to mean throughput)."""
    recs = [(b, t) for _, b, t in records if t > 0 and b > 0]
    if not recs:
        return 20e9, 1e-6                      # dram-ish: nothing measured
    xs = np.array([b for b, _ in recs], np.float64)
    ys = np.array([t for _, t in recs], np.float64)
    if len(recs) >= 2 and np.ptp(xs) > 0:
        slope, seek = np.polyfit(xs, ys, 1)
        if slope <= 0:
            # flat (or noise-inverted) size axis: the channel is per-
            # OPERATION dominated — model it as pure seek, free bytes
            return 1e13, float(np.mean(ys))
        seek = max(seek, 0.0)
    else:
        slope, seek = float(np.mean(ys / xs)), 0.0
    bandwidth = float(np.clip(1.0 / max(slope, 1e-15), 1e6, 1e14))
    return bandwidth, float(max(seek, 0.0))


class TransferEngine:
    """Grouped page movement for one :class:`~repro.serving.device_pool.
    DevicePagePool`.  The pool owns residency bookkeeping state (slots,
    generation); this class owns how bytes get there."""

    def __init__(self, pool, max_pending: int = 2):
        self.pool = pool
        self.max_pending = max_pending
        self.stats = TransferStats()
        self._pending: "OrderedDict[frozenset, PendingGroup]" = OrderedDict()

    # ------------------------------------------------------------ helpers --
    @property
    def page_nbytes(self) -> int:
        bh, bw = self.pool.block_shape
        return self.pool.blocks_per_page * bh * bw \
            * np.dtype(np.float32).itemsize

    def _missing(self, pids) -> List[int]:
        seen, out = set(), []
        for p in pids:
            p = int(p)
            if p not in seen and p not in self.pool.slot_of:
                seen.add(p)
                out.append(p)
        return out

    # Callers (load_group / stage) own the channel charge; _stack
    # only assembles bytes.  # repro: allow-uncharged
    def _stack(self, pids: List[int]) -> np.ndarray:
        """One grouped backend fault + one vectorized gather."""
        return self.pool.store.page_stack(pids, dtype=np.float32)

    def _to_device(self, stack: np.ndarray):
        import jax.numpy as jnp
        return self.pool._put(jnp.asarray(stack, self.pool.dtype))

    def _scatter(self, slab, slots: np.ndarray, staged):
        """One scatter committing ``staged`` rows into ``slots``, padded
        to a power-of-two bucket (``_bucket_pad``; callers that already
        padded pass pow2 inputs and this is a no-op)."""
        import jax.numpy as jnp
        padded = _bucket_pad(slots)
        if len(padded) > len(slots):
            staged = jnp.concatenate(
                [staged, jnp.broadcast_to(
                    staged[:1], (len(padded) - len(slots),)
                    + staged.shape[1:])], axis=0)
            slots = padded
        return slab.at[jnp.asarray(slots, jnp.int32)].set(staged)

    def drop_pending(self) -> None:
        self._pending.clear()

    def _fresh_pending(self) -> None:
        """Evict stale (repacked) and over-quota pending stages."""
        gen = self.pool.store.pack_generation
        for key in [k for k, pg in self._pending.items()
                    if pg.pack_generation != gen]:
            del self._pending[key]
        while len(self._pending) > self.max_pending:
            self._pending.popitem(last=False)

    # ------------------------------------------------------------ staging --
    def stage(self, pids) -> Optional[PendingGroup]:
        """Assemble ``pids``'s not-yet-resident pages into one staging
        stack and issue the (async) device copy.  The engines call this
        for the *next* batch right before computing the current one, so
        the copy rides under compute — JAX dispatch returns immediately.
        Commit happens later, when the buffer pool actually admits the
        pages (:meth:`load_group`)."""
        self.pool.store.packing                  # settle before gen read
        self._fresh_pending()
        missing = self._missing(pids)
        if not missing:
            return None
        key = frozenset(missing)
        hit = self._pending.get(key)
        if hit is not None:
            return hit
        for staged in self._pending.values():    # already covered by one?
            if key <= staged.index.keys():
                return staged
        with get_tracer().span("stage", kind="transfer",
                               pages=len(missing),
                               bytes=len(missing) * self.page_nbytes):
            stack = self._stack(missing)
            dev = None if self.pool.mode() == "host" \
                else self._to_device(stack)
        pg = PendingGroup({p: i for i, p in enumerate(missing)}, stack, dev,
                          self.pool.store.pack_generation)
        self._pending[key] = pg
        while len(self._pending) > self.max_pending:
            self._pending.popitem(last=False)
        self.stats.staged_groups += 1
        return pg

    # ------------------------------------------------------------- commit --
    def _full_cover(self, missing: List[int]) -> Optional[PendingGroup]:
        """A pending group whose staged bytes cover the WHOLE commit
        (the double-buffer hit).  Partial covers are not spliced — the
        splice would need shape-varying device gathers/concats that
        recompile per group; a clean restage is cheaper and rarer."""
        key = set(missing)
        for pg in self._pending.values():
            if key <= pg.index.keys():
                return pg
        return None

    def load_group(self, pids) -> int:
        """Commit a group: one scatter into the slab, one host-mirror
        write, one generation bump.  A group fully staged by a previous
        :meth:`stage` commits from the already in-flight device bytes
        (the overlapped path, counted in ``overlapped_bytes``); anything
        else is staged now.  Returns pages loaded."""
        self._fresh_pending()
        missing = self._missing(pids)
        if not missing:
            return 0
        if len(missing) > len(self.pool._free):
            raise RuntimeError(
                f"group of {len(missing)} pages exceeds the slab's "
                f"{len(self.pool._free)} free slots")
        with get_tracer().span("load_group", kind="transfer",
                               pages=len(missing),
                               bytes=len(missing) * self.page_nbytes) as sp:
            pg = self._full_cover(missing)
            overlapped = 0
            if pg is not None:
                rows = np.asarray([pg.index[p] for p in missing],  # repro: allow-host
                                  dtype=np.int64)
                host_stack = pg.host[rows]
                # staged ahead of demand: in device modes the bytes are
                # already in flight to HBM; in host mode the staging stack
                # (the grouped store gather) was assembled under compute
                overlapped = len(missing) * self.page_nbytes
                for key in [k for k, v in self._pending.items() if v is pg]:
                    del self._pending[key]       # consumed
            else:
                rows = None
                host_stack = self._stack(missing)
            # Time only the host->HBM leg (mirror write + device_put +
            # scatter): _stack() above may fault the STORAGE backend, and
            # a channel fitted over storage seconds would double-charge
            # misses under charge_transfer.
            t0 = time.perf_counter()
            slots = np.asarray([self.pool._free.pop() for _ in missing],  # repro: allow-host
                               dtype=np.int64)
            # Exception safety: slots are popped, but residency maps are
            # not yet touched.  If the device leg fails, every popped slot
            # goes back to the free list and the generation is NOT bumped
            # — the pool looks exactly as before the call (no half-mapped
            # slots; slab bytes in an unmapped slot are unreachable by any
            # remap).
            try:
                self.pool.host_slab[slots] = host_stack
                if self.pool.mode() != "host":
                    if pg is not None and pg.dev is not None:
                        # reuse the staged device bytes: bucket-pad the
                        # gather and the scatter to the SAME pow2 shape
                        # (repeat index 0; duplicate writes of identical
                        # rows are harmless), so varying group sizes hit a
                        # few compiled shapes
                        rows_p, slots_p = _bucket_pad(rows, slots)
                        import jax.numpy as jnp
                        staged = pg.dev[jnp.asarray(rows_p, jnp.int32)]
                        self.pool.slab = self._scatter(self.pool.slab,
                                                       slots_p, staged)
                    else:
                        self.pool.slab = self._scatter(
                            self.pool.slab, slots,
                            self._to_device(host_stack))
            except BaseException:
                self.pool._free.extend(int(s) for s in slots)
                raise

            for pid, slot in zip(missing, slots):
                self.pool.slot_of[pid] = int(slot)
                self.pool._page_to_slot[pid] = int(slot)
            self.pool.generation += 1            # ONCE per group
            self.pool.loads += len(missing)
            self.stats.record(len(missing),
                              len(missing) * self.page_nbytes,
                              time.perf_counter() - t0,
                              overlapped_bytes=overlapped)
            sp.set(overlapped_bytes=overlapped)
        return len(missing)

    def record_single(self, seconds: float) -> None:
        """Per-page fallback accounting (``DevicePagePool.load``): the
        same stats stream, a group of one."""
        self.stats.record(1, self.page_nbytes, seconds)

    # -------------------------------------------------------- calibration --
    def measure(self, group_sizes: Sequence[int] = (1, 2, 4, 8),
                reps: int = 3) -> List[Tuple[int, int, float]]:
        """Blocking bandwidth sweep: time a size-n staged transfer +
        scatter end to end (``block_until_ready``) for each group size,
        without touching residency (the scatter result is discarded).
        Returns ``(pages, bytes, best seconds)`` samples."""
        bh, bw = self.pool.block_shape
        l = self.pool.blocks_per_page
        out: List[Tuple[int, int, float]] = []
        rng = np.random.default_rng(0)
        for n in group_sizes:
            n = int(min(n, max(1, self.pool.capacity)))
            src = rng.standard_normal((n, l, bh, bw)).astype(np.float32)
            slots = np.arange(n, dtype=np.int64)
            best = float("inf")
            # one untimed warmup per size so compile/allocator effects
            # never pollute the fit
            for rep in range(max(1, reps) + 1):
                t0 = time.perf_counter()
                if self.pool.mode() == "host":
                    # host tier: the "transfer" is a mirror memcpy
                    scratch = np.empty_like(src)
                    scratch[:] = src
                else:
                    dev = self._to_device(src)
                    res = self._scatter(self.pool.slab, slots, dev)
                    res.block_until_ready()
                if rep:
                    best = min(best, time.perf_counter() - t0)
            out.append((n, n * self.page_nbytes, best))
        return out

    def storage_model(self, group_sizes: Sequence[int] = (1, 2, 4, 8),
                      reps: int = 3, **kw):
        """A :class:`~repro.serving.engine.StorageModel` of the host<->HBM
        channel, fitted from a BLOCKING :meth:`measure` sweep — the
        calibrated replacement for preset per-page charges.  The serving
        ``stats.records`` are deliberately NOT used: serving timings are
        issue-side (JAX dispatch is asynchronous), so on an accelerator
        they measure dispatch latency, not the transfer."""
        bandwidth, seek = fit_channel(self.measure(group_sizes, reps))
        from .engine import StorageModel
        kw.setdefault("channel", "hbm")
        return StorageModel(kind=f"measured:{self.pool.mode()}",
                            bandwidth=bandwidth, seek=seek, **kw)
