"""Batch schedulers for the multi-model serving engines.

The engines (`serving/engine.py`) used to drain their per-model queues
with a hard-coded round-robin sweep.  This module turns batch ordering
into a policy:

  * ``fifo``           — global arrival order, model-oblivious.
  * ``round_robin``    — one batch per model per sweep (the old behavior;
    fair, but interleaves models that share nothing, thrashing the pool).
  * ``dedup_affinity`` — co-schedules batches whose page working sets
    overlap the currently *resident* pages, so model variants that share
    deduplicated pages run back-to-back and turn sharing into hits
    (paper Sec. 6: the Eq.-2 win only materializes if sharers actually
    arrive within the reuse horizon).  Ties break by arrival order, and a
    starvation bound forces the oldest batch after ``max_defer``
    consecutive deferrals, so affinity never parks a cold model forever.

Schedulers see batches as :class:`ScheduledBatch` — payload plus the
batch's estimated page working set (the engine computes it at submit
time from the store's packing; that is what makes affinity scheduling
cheap: no weight access, just page-id set intersections).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Deque, Dict, Iterable, List, Optional, Set

__all__ = ["ScheduledBatch", "BatchScheduler", "FifoScheduler",
           "RoundRobinScheduler", "DedupAffinityScheduler",
           "SCHEDULERS", "make_scheduler"]


@dataclasses.dataclass
class ScheduledBatch:
    """One queued batch: payload + the page working set it was
    estimated to touch (for affinity scheduling and lookahead)."""
    model: str
    payload: object                    # engine-specific (docs, prompts, ...)
    seq: int                           # global arrival order
    pages: Optional[frozenset] = None  # estimated page working set
    pages_gen: Optional[int] = None    # packing generation pages came from
    shard: Optional[int] = None        # routed shard (sharded serving)


class BatchScheduler:
    """Queue of submitted batches + a policy for what runs next."""

    name = "base"

    def __init__(self) -> None:
        self._seq = 0

    # -- submission ----------------------------------------------------------
    def submit(self, model: str, payload, pages: Optional[Iterable] = None,
               pages_gen: Optional[int] = None,
               shard: Optional[int] = None) -> ScheduledBatch:
        """``pages_gen`` records which ``ModelStore.pack_generation`` the
        page ids were minted under; engines use it to spot batches whose
        cached working set a later repack has invalidated.  ``shard`` is
        the router's placement decision for the batch (sharded serving);
        it is advisory — the server re-derives it at run time so a
        repack between submit and run cannot misroute."""
        b = ScheduledBatch(model, payload, self._seq,
                           frozenset(pages) if pages is not None else None,
                           pages_gen, shard)
        self._seq += 1
        self._enqueue(b)
        return b

    # -- policy interface ----------------------------------------------------
    def _enqueue(self, batch: ScheduledBatch) -> None:
        raise NotImplementedError

    def next_batch(self, resident: Optional[Set] = None
                   ) -> Optional[ScheduledBatch]:
        """Pop the next batch to run; ``resident`` is the buffer pool's
        current resident page set (affinity policies use it)."""
        raise NotImplementedError

    def pending(self) -> int:
        raise NotImplementedError

    def pending_batches(self) -> List[ScheduledBatch]:
        """Queued batches in arrival order, *without* dequeuing — the
        queue-aware prefetcher plans lookahead from these page sets
        before spending any idle budget on λ speculation.  Default: an
        empty view, so a scheduler subclass written before this hook
        existed simply gets no lookahead (pure-λ prefetch) instead of a
        crash."""
        return []

    def __bool__(self) -> bool:
        return self.pending() > 0


class FifoScheduler(BatchScheduler):
    """Arrival-order baseline: next batch = oldest batch."""
    name = "fifo"

    def __init__(self) -> None:
        super().__init__()
        self._q: Deque[ScheduledBatch] = deque()

    def _enqueue(self, batch: ScheduledBatch) -> None:
        self._q.append(batch)

    def next_batch(self, resident=None):
        return self._q.popleft() if self._q else None

    def pending(self) -> int:
        return len(self._q)

    def pending_batches(self) -> List[ScheduledBatch]:
        return list(self._q)


class RoundRobinScheduler(BatchScheduler):
    """One batch per model per sweep, models in first-submission order —
    exactly the old ``EmbeddingServingEngine.run`` drain order."""

    name = "round_robin"

    def __init__(self) -> None:
        super().__init__()
        self._queues: "OrderedDict[str, Deque[ScheduledBatch]]" = OrderedDict()
        self._cursor = 0

    def _enqueue(self, batch: ScheduledBatch) -> None:
        self._queues.setdefault(batch.model, deque()).append(batch)

    def next_batch(self, resident=None):
        order = list(self._queues)
        n = len(order)
        for i in range(n):
            j = (self._cursor + i) % n
            if self._queues[order[j]]:
                self._cursor = (j + 1) % n
                return self._queues[order[j]].popleft()
        return None

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def pending_batches(self) -> List[ScheduledBatch]:
        return sorted((b for q in self._queues.values() for b in q),
                      key=lambda b: b.seq)


class DedupAffinityScheduler(BatchScheduler):
    """Pick the queue head whose page set overlaps the resident set most.

    Score = |batch.pages ∩ resident| / |batch.pages| (absolute overlap
    breaks down when models have different working-set sizes).  Ties and
    the cold start fall back to arrival order.  A batch deferred more
    than ``max_defer`` times is forced, bounding starvation.
    """

    name = "dedup_affinity"

    def __init__(self, max_defer: int = 16) -> None:
        super().__init__()
        self.max_defer = max_defer
        self._queues: "OrderedDict[str, Deque[ScheduledBatch]]" = OrderedDict()
        self._deferrals: Dict[str, int] = {}

    def _enqueue(self, batch: ScheduledBatch) -> None:
        self._queues.setdefault(batch.model, deque()).append(batch)

    def _score(self, batch: ScheduledBatch, resident: Set) -> float:
        if not batch.pages:
            return 0.0
        return len(batch.pages & resident) / len(batch.pages)

    def next_batch(self, resident=None):
        heads = [(m, q[0]) for m, q in self._queues.items() if q]
        if not heads:
            return None
        # starvation bound: run anything deferred too long, oldest first
        starved = [(m, b) for m, b in heads
                   if self._deferrals.get(m, 0) >= self.max_defer]
        if starved:
            model, _ = min(starved, key=lambda mb: mb[1].seq)
        elif resident:
            model, _ = max(
                heads, key=lambda mb: (self._score(mb[1], resident),
                                       -mb[1].seq))
        else:
            model, _ = min(heads, key=lambda mb: mb[1].seq)
        for m, q in self._queues.items():
            if q:
                self._deferrals[m] = 0 if m == model \
                    else self._deferrals.get(m, 0) + 1
        return self._queues[model].popleft()

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def pending_batches(self) -> List[ScheduledBatch]:
        return sorted((b for q in self._queues.values() for b in q),
                      key=lambda b: b.seq)


SCHEDULERS = {
    "fifo": FifoScheduler,
    "round_robin": RoundRobinScheduler,
    "dedup_affinity": DedupAffinityScheduler,
}


def make_scheduler(policy, **kwargs) -> BatchScheduler:
    """Resolve a policy name (or pass through an instance) to a
    :class:`BatchScheduler`."""
    if isinstance(policy, BatchScheduler):
        return policy
    if policy not in SCHEDULERS:
        raise ValueError(f"unknown scheduler policy {policy!r}; "
                         f"have {sorted(SCHEDULERS)}")
    return SCHEDULERS[policy](**kwargs)
