"""Sharded page-pool serving: partition the dedup page pool across a
device mesh with dedup-aware placement and cross-shard borrowing.

The paper's argument one level up the hierarchy: dedup-aware storage
keeps a database serving when the working set exceeds one tier's
memory; when the deduplicated page pool exceeds a *single
accelerator's* HBM, the pool should shard across a device mesh instead
of thrashing one slab (DESIGN.md §5).

Three pieces:

  * **Placement** — a total, deterministic ``page -> shards``
    assignment, rebuilt per packing generation.  ``hash`` is the
    baseline (``pid % num_shards``, single owner, no replication).
    ``sharers`` is dedup-aware: it uses ``ModelStore.page_sharers()``
    statistics to *replicate* the hottest shared pages on every shard
    (bounded by ``replicate_frac`` of a shard's capacity — these are
    the pages every co-served variant touches, so local copies kill
    cross-shard traffic) and to *partition* the remaining pages by
    model affinity: each model's singleton pages land together on the
    model's home shard (greedy balanced bin-pack), so a batch routes to
    a shard that owns nearly all of its cover set.
  * **Per-shard pools** — each shard has its own
    :class:`~repro.core.bufferpool.BufferPool` (shard-local eviction,
    same Eq.-1/Eq.-2 policies) driving its own
    :class:`~repro.serving.device_pool.DevicePagePool` slab, optionally
    pinned to one device of a serving mesh.  The PR-2 residency
    invariant becomes per-shard: *each shard's slab == its pool's
    resident set*, plus the global placement invariant: *a page is only
    ever resident on shards its placement assigned it* (``on_load``
    raises otherwise).
  * **Borrow staging** — the minority pages of a routed batch (owned
    elsewhere; see ``serving/router.py``) are never loaded into the
    executing shard's slab.  Their bytes are staged from an *owning*
    shard's host mirror into a fixed borrow slab appended past the
    executing pool's slots (``capacity + stage_idx``), so one extended
    remap serves the whole batch through the same dedup kernels.  A
    borrowed page absent everywhere is first demand-faulted into its
    owning shard (so the owner's pool warms and future borrows hit the
    mirror); the caller charges owner faults to storage and mirror
    copies to the interconnect — all on the fetch channel, like any
    other miss.

:class:`ShardedWeightServer` packages this behind the exact
:class:`~repro.serving.engine.WeightServer` surface the engines drive,
so ``EmbeddingServingEngine`` / ``LMServingEngine`` serve sharded
without modification; at ``shards=1`` routing is the identity, nothing
is ever borrowed, and behavior matches the single-slab device backend.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.bufferpool import BufferPool
from ..core.store import ModelStore, VirtualTensor
from ..obs import get_tracer
from .device_pool import DevicePagePool
from .engine import ServeStats, StorageModel, WeightServer
from .router import RouteDecision, ShardRouter

__all__ = ["PLACEMENTS", "Placement", "hash_placement", "sharers_placement",
           "make_placement", "ShardedPagePool", "ShardedWeightServer"]

PLACEMENTS = ("hash", "sharers")


# --------------------------------------------------------------- placement --
@dataclasses.dataclass(frozen=True)
class Placement:
    """Total, deterministic page->shards assignment for one packing."""
    num_shards: int
    policy: str
    owners: Tuple[Tuple[int, ...], ...]   # pid -> sorted owning shards
    owned_sets: Tuple[frozenset, ...]     # shard -> pages it owns
    replicated: frozenset                 # pages with >1 owner
    pack_generation: int

    def shards_of(self, pid: int) -> Tuple[int, ...]:
        return self.owners[pid]

    def primary(self, pid: int) -> int:
        return self.owners[pid][0]


def _finalize(owners: List[Tuple[int, ...]], num_shards: int, policy: str,
              generation: int) -> Placement:
    owned: List[set] = [set() for _ in range(num_shards)]
    for pid, ss in enumerate(owners):
        assert ss, f"placement left page {pid} unowned"
        for s in ss:
            owned[s].add(pid)
    replicated = frozenset(p for p, ss in enumerate(owners) if len(ss) > 1)
    return Placement(num_shards, policy, tuple(owners),
                     tuple(frozenset(s) for s in owned), replicated,
                     generation)


def hash_placement(num_pages: int, num_shards: int,
                   generation: int = 0) -> Placement:
    """Baseline: ``pid % num_shards``.  Total, deterministic, single
    owner, placement-oblivious — every batch borrows ~(S-1)/S of its
    cover set."""
    owners = [(pid % num_shards,) for pid in range(num_pages)]
    return _finalize(owners, num_shards, "hash", generation)


def sharers_placement(num_pages: int, num_shards: int,
                      sharers: Dict[int, frozenset],
                      replicate_budget: Optional[int] = None,
                      generation: int = 0) -> Placement:
    """Dedup-aware placement from ``ModelStore.page_sharers()``.

    Pages shared by >= 2 models are replicated on every shard, hottest
    (most sharers) first, up to ``replicate_budget`` pages (None:
    unbounded) — these are the pages every co-served variant touches,
    so a local copy on each shard kills the cross-shard traffic they
    would otherwise generate on every batch.  The rest partitions by
    model affinity: singleton pages anchor to their one sharer, models
    are greedily bin-packed (descending page weight) onto the
    least-loaded shard, and each over-budget shared page lands on the
    least-loaded *home shard of one of its sharers* (so it stays local
    to at least one of the models that reuse it).  Ties break
    deterministically (page id / model name / shard id), so two
    rebuilds over the same packing always agree.
    """
    owners: List[Optional[Tuple[int, ...]]] = [None] * num_pages
    shared: List[int] = []
    if num_shards > 1:
        shared = sorted((p for p in range(num_pages)
                         if len(sharers.get(p, ())) >= 2),
                        key=lambda p: (-len(sharers[p]), p))
        budget = len(shared) if replicate_budget is None \
            else max(0, int(replicate_budget))
        for p in shared[:budget]:
            owners[p] = tuple(range(num_shards))
        shared = shared[budget:]                 # partitioned below
    # singleton pages anchor their one sharer; model homes bin-pack
    shared_set = set(shared)
    singles = [p for p in range(num_pages)
               if owners[p] is None and p not in shared_set]
    anchor: Dict[int, Optional[str]] = {}
    weight: Dict[Optional[str], int] = {}
    for p in singles:
        ms = sharers.get(p)
        a = min(ms) if ms else None
        anchor[p] = a
        weight[a] = weight.get(a, 0) + 1
    load = [0] * num_shards
    home: Dict[Optional[str], int] = {}
    for m in sorted(weight, key=lambda m: (-weight[m], str(m))):
        s = min(range(num_shards), key=lambda i: (load[i], i))
        home[m] = s
        load[s] += weight[m]
    for p in singles:
        owners[p] = (home[anchor[p]],)
    # over-budget shared pages: least-loaded home among their sharers
    for p in shared:
        cand = sorted({home[m] for m in sharers.get(p, ()) if m in home})
        if not cand:
            cand = list(range(num_shards))
        s = min(cand, key=lambda i: (load[i], i))
        owners[p] = (s,)
        load[s] += 1
    return _finalize(owners, num_shards, "sharers", generation)  # type: ignore[arg-type]


def make_placement(policy: str, store: ModelStore, num_shards: int,
                   replicate_budget: Optional[int] = None) -> Placement:
    """Build a placement for the store's *current* packing."""
    if policy not in PLACEMENTS:
        raise ValueError(f"unknown placement {policy!r}; have {PLACEMENTS}")
    pk = store.packing                     # settle the packing first: the
    gen = store.pack_generation            # getter may repack (gen bump)
    if policy == "hash":
        return hash_placement(pk.num_pages, num_shards, gen)
    return sharers_placement(pk.num_pages, num_shards, store.page_sharers(),
                             replicate_budget, gen)


# -------------------------------------------------------------- shard pool --
class ShardedPagePool:
    """N per-shard (BufferPool, DevicePagePool) pairs + placement +
    borrow staging.  Also quacks like a single ``DevicePagePool`` for
    aggregate reporting (``capacity`` / ``loads`` / ``evicts``)."""

    def __init__(self, store: ModelStore, num_shards: int,
                 capacity_per_shard: int, placement: str = "sharers",
                 policy: str = "optimized_mru", kernel_mode: str = "auto",
                 replicate_frac: float = 0.5,
                 borrow_capacity: Optional[int] = None,
                 devices: Optional[Sequence] = None,
                 transfer: str = "grouped"):
        if placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r}; "
                             f"have {PLACEMENTS}")
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if transfer not in WeightServer.TRANSFERS:
            raise ValueError(f"unknown transfer mode {transfer!r}; "
                             f"have {WeightServer.TRANSFERS}")
        self.store = store
        self.num_shards = int(num_shards)
        self.capacity_per_shard = int(capacity_per_shard)
        self.placement_policy = placement
        self.replicate_frac = float(replicate_frac)
        self.transfer = transfer
        self.borrow_capacity = int(borrow_capacity
                                   if borrow_capacity is not None
                                   else capacity_per_shard)
        devs = list(devices) if devices else []
        # stage_rows: each shard's slab carries a borrow-staging TAIL
        # past its resident slots, so extended remaps read one stable
        # buffer — no per-compute-call slab concatenation.
        self.pools: List[DevicePagePool] = [
            DevicePagePool(store, self.capacity_per_shard,
                           kernel_mode=kernel_mode,
                           device=devs[s % len(devs)] if devs else None,
                           stage_rows=self.borrow_capacity)
            for s in range(self.num_shards)]
        bh, bw = store.cfg.dedup.block_shape
        l = store.cfg.blocks_per_page
        self._stage_host = [np.zeros((self.borrow_capacity, l, bh, bw),
                                     np.float32)
                            for _ in range(self.num_shards)]
        self._staged: List[Dict[int, int]] = [dict()
                                              for _ in range(self.num_shards)]
        # Slab tails are synced from _stage_host once per staging
        # *change* (dirty flag), never once per compute call.
        self._stage_dirty: List[bool] = [True] * self.num_shards
        self._placement_obj: Optional[Placement] = None
        self.buffer_pools: List[BufferPool] = [
            store.make_buffer_pool(
                self.capacity_per_shard, policy,
                on_load=self._mk_on_load(s),
                on_evict=self.pools[s].evict,
                on_load_group=(self._mk_on_load_group(s)
                               if transfer == "grouped" else None))
            for s in range(self.num_shards)]
        self.view = _ShardedPoolView(self)
        self.borrow_mirror_hits = 0
        self.borrow_store_faults = 0
        self.borrow_coalesced = 0
        # Failover state (DESIGN.md §8): dead shards take no traffic,
        # hold no pages, and their owned pages serve via the borrow
        # staging path from surviving owners or the store.
        self.dead: Set[int] = set()
        self.failovers = 0

    def _check_owner(self, shard: int, pid: int) -> None:
        owners = self.placement().shards_of(pid)
        if shard not in owners:
            raise RuntimeError(
                f"placement invariant violated: page {pid} loading on "
                f"shard {shard} but placement assigned {owners}")

    def _mk_on_load(self, shard: int):
        def on_load(pid):
            pid = int(pid)
            self._check_owner(shard, pid)
            self.pools[shard].load(pid)
        return on_load

    def _mk_on_load_group(self, shard: int):
        def on_load_group(pids):
            pids = [int(p) for p in pids]
            for pid in pids:
                self._check_owner(shard, pid)
            self.pools[shard].load_group(pids)
        return on_load_group

    # ----------------------------------------------------------- placement --
    def placement(self) -> Placement:
        self.store.packing                 # may repack: read before gen
        gen = self.store.pack_generation
        pl = self._placement_obj
        if pl is not None and pl.pack_generation == gen:
            return pl
        budget = None
        if self.placement_policy == "sharers":
            budget = max(0, int(self.replicate_frac
                                * self.capacity_per_shard))
        pl = make_placement(self.placement_policy, self.store,
                            self.num_shards, replicate_budget=budget)
        self._placement_obj = pl
        return pl

    def flush(self) -> None:
        """Store repacked: every shard slab, staging slab, and the
        placement itself refer to dead page ids."""
        for p in self.pools:
            p.flush()
        for d in self._staged:
            d.clear()
        self._stage_dirty = [True] * self.num_shards
        self._placement_obj = None

    # ------------------------------------------------------------ failover --
    def fail_shard(self, shard: int) -> None:
        """Mark ``shard`` dead: its slab contents are gone (residency
        dropped, staged borrows cleared), the router stops choosing it,
        and pages it owned serve through the borrow-staging path from
        surviving owners' mirrors or straight from the store.  Idempotent
        for an already-dead shard."""
        s = int(shard)
        if not 0 <= s < self.num_shards:
            raise ValueError(f"no shard {s} (have {self.num_shards})")
        if s in self.dead:
            return
        self.dead.add(s)
        self.failovers += 1
        # invalidate fires on_evict, so the slab slots free too — the
        # per-shard residency invariant holds through the failure
        self.buffer_pools[s].invalidate_resident()
        self._staged[s].clear()
        self._stage_dirty[s] = True

    def revive_shard(self, shard: int) -> None:
        """Re-place a recovered shard back into the rotation.  It comes
        back *empty* (demand faulting refills it); routing sees it again
        immediately."""
        self.dead.discard(int(shard))

    def alive_shards(self) -> List[int]:
        return [s for s in range(self.num_shards) if s not in self.dead]

    # ------------------------------------------------------------- borrows --
    def staged(self, shard: int) -> Dict[int, int]:
        return self._staged[shard]

    # The borrow fetch is charged by the caller (ShardedWeightServer.
    # _borrow puts the seconds on the storage/interconnect channels);
    # this method owns only the bytes.  # repro: allow-uncharged
    def stage_borrows(self, shard: int, pages, model
                      ) -> Optional[Tuple[Dict[int, int], int, int, int]]:
        """Stage ``pages`` (owned elsewhere) into ``shard``'s borrow slab.

        **Coalesced across batches**: pages already staged on this shard
        by an earlier batch are *reused* (page bytes are immutable per
        packing, so a staged copy never goes stale within one
        generation) — the consecutive-same-shard-batch win the ROADMAP
        names.  Stale staged entries the current batch doesn't need are
        dropped to free staging slots.

        **Batched within a batch**: new pages are grouped by owning
        shard; each owner's missing pages demand-fault through that
        owner's pool as ONE pinned group (one grouped transfer on the
        owner), and each owner's mirror rows copy into the staging slab
        with one vectorized gather instead of a per-page loop.

        Returns ``(staged map, mirror_hits, owner_faults, reused)``, or
        None when the borrow set cannot fit the staging slab (caller
        falls back to the host)."""
        pages = sorted(set(int(p) for p in pages))
        st = self._staged[shard]
        if not pages:
            return dict(st), 0, 0, 0
        if len(pages) > self.borrow_capacity:
            st.clear()
            self._stage_dirty[shard] = True
            return None
        pl = self.placement()
        buf = self._stage_host[shard]
        pset = set(pages)
        reused = [p for p in pages if p in st]
        new = [p for p in pages if p not in st]
        if new:
            # drop stale entries (not in this batch) to free their slots
            for p in [p for p in st if p not in pset]:
                del st[p]
            free = sorted(set(range(self.borrow_capacity)) - set(st.values()),
                          reverse=True)
            for pid in new:
                st[pid] = free.pop()
            # owner resolution + mirror hits FIRST: their bytes are
            # copied before any fault below can evict them
            fault_by_owner: Dict[int, List[int]] = {}
            hit_by_owner: Dict[int, List[int]] = {}
            orphaned: List[int] = []       # every owner dead: store-direct
            hits = 0
            for pid in new:
                owners = pl.shards_of(pid)
                assert shard not in owners, \
                    f"page {pid} is owned by shard {shard}; not a borrow"
                alive = [o for o in owners if o not in self.dead]
                owner = next((o for o in alive
                              if pid in self.pools[o].slot_of), None)
                if owner is not None:
                    hit_by_owner.setdefault(owner, []).append(pid)
                    hits += 1
                elif alive:
                    fault_by_owner.setdefault(alive[0], []).append(pid)
                else:
                    orphaned.append(pid)
            for owner, pids in hit_by_owner.items():
                # one vectorized mirror->stage copy per owning shard
                mirror = self.pools[owner].host_slab
                # repro: allow-host (index array for the mirror copy)
                slots = np.asarray([self.pools[owner].slot_of[p]
                                    for p in pids])
                # repro: allow-host — mirror->stage copy is host work
                buf[np.asarray([st[p] for p in pids])] = mirror[slots]
            faults = 0
            for owner, pids in sorted(fault_by_owner.items()):
                bp = self.buffer_pools[owner]
                with bp.deferred_loads():        # ONE transfer on the owner
                    for pid in pids:
                        bp.access(model, pid)
                        faults += 1
                # copy after the flush; a page the fault window itself
                # evicted again (thrashing owner pool) sources its —
                # identical — bytes straight from the store instead
                pool_o = self.pools[owner]
                live = [p for p in pids if p in pool_o.slot_of]
                if live:
                    # repro: allow-host — store-sourced fallback copy
                    slots = np.asarray([pool_o.slot_of[p] for p in live])
                    # repro: allow-host
                    buf[np.asarray([st[p] for p in live])] = \
                        pool_o.host_slab[slots]
                for p in pids:
                    if p not in pool_o.slot_of:
                        buf[st[p]] = self.store.page_array(
                            p, dtype=np.float32)
            if orphaned:
                # failover tail: every owning shard is dead, so the
                # bytes come straight from the storage tier (counted as
                # store faults — the caller charges them accordingly)
                self.store.fault_pages(orphaned)
                for p in orphaned:
                    buf[st[p]] = self.store.page_array(p, dtype=np.float32)
                faults += len(orphaned)
            self._stage_dirty[shard] = True
        else:
            hits = faults = 0
        self.borrow_mirror_hits += hits
        self.borrow_store_faults += faults
        self.borrow_coalesced += len(reused)
        return dict(st), hits, faults, len(reused)

    # --------------------------------------------------------------- remap --
    def remap(self, shard: int, vt: VirtualTensor,
              key: Optional[Tuple[str, str]] = None, strict: bool = True
              ) -> Tuple[Optional[np.ndarray], bool]:
        """Extended slot remap for ``shard``: owned pages resolve to the
        shard's slab slots, staged borrows to ``capacity + stage_idx``.
        Returns ``(dev_map, uses_extra)``; a map that touches staged
        slots is rebuilt per batch (staging indices are transient), maps
        with no staged pages delegate to the shard pool's cached remap.
        """
        staged = self._staged[shard]
        pool = self.pools[shard]
        touched = [p for p in vt.page_ids if p in staged] if staged else []
        if not touched:
            return pool.remap(vt, key=key, strict=strict), False
        l = pool.blocks_per_page
        ext = pool._page_to_slot.copy()
        for pid in touched:
            if ext[pid] < 0:
                ext[pid] = pool.capacity + staged[pid]
        slots = ext[vt.block_map // l]
        holes = slots < 0
        dev_map = np.where(holes, -1,
                           slots * l + vt.block_map % l).astype(np.int32)
        if strict and holes.any():
            return None, True
        return dev_map, True

    # ------------------------------------------------------------- compute --
    def _sync_stage(self, shard: int) -> None:
        """Flush the shard's staging buffer into its slab TAIL (the
        ``stage_rows`` past ``capacity``) — host mirror always, device
        slab via one fixed-shape ``dynamic_update_slice`` — once per
        staging *change*, so compute calls read one stable buffer."""
        if not self._stage_dirty[shard]:
            return
        pool = self.pools[shard]
        buf = self._stage_host[shard]
        pool.host_slab[pool.capacity:] = buf
        if pool.mode() != "host":
            import jax
            import jax.numpy as jnp
            pool.slab = jax.lax.dynamic_update_slice(
                pool.slab, pool._put(jnp.asarray(buf, pool.dtype)),
                (pool.capacity, 0, 0, 0))
        self._stage_dirty[shard] = False

    def _unpin(self, shard: int, out):
        """Results computed on a pinned shard device come back committed
        there; move them to the process default device so downstream
        consumers (head matmuls, decode steps) can mix results from
        different shards without cross-device placement errors.
        (``jax.device_put`` with no target is the identity on committed
        arrays — the target must be explicit.)"""
        if out is None or self.pools[shard].device is None \
                or isinstance(out, np.ndarray):
            return out
        import jax
        return jax.device_put(out, jax.devices()[0])

    def gather_rows(self, shard: int, dev_map, grid, rows, pad: bool = False,
                    uses_extra: bool = False):
        if uses_extra:
            self._sync_stage(shard)
        return self._unpin(shard, self.pools[shard].gather_rows(
            dev_map, grid, rows, pad=pad))

    def virtual_matmul(self, shard: int, dev_map, grid, x,
                       uses_extra: bool = False):
        if uses_extra:
            self._sync_stage(shard)
        return self._unpin(shard, self.pools[shard].virtual_matmul(
            dev_map, grid, x))

    def unblock(self, shard: int, dev_map, grid, uses_extra: bool = False):
        if uses_extra:
            self._sync_stage(shard)
        return self._unpin(shard, self.pools[shard].unblock(
            dev_map, grid))

    # ----------------------------------------------------------- reporting --
    @property
    def capacity(self) -> int:
        return sum(p.capacity for p in self.pools)

    @property
    def loads(self) -> int:
        return sum(p.loads for p in self.pools)

    @property
    def evicts(self) -> int:
        return sum(p.evicts for p in self.pools)

    def resident_pages(self) -> Set[int]:
        out: Set[int] = set()
        for p in self.pools:
            out |= p.resident_pages()
        return out

    def stacked_slab(self, mesh=None):
        """Global mesh view of the pool: the per-shard slabs stacked to
        ``[num_shards, capacity, blocks_per_page, bh, bw]`` and laid out
        with ``NamedSharding(P("shard", ...))`` when a serving mesh is
        given (``launch.mesh.make_shard_mesh``) — the sharded lowering
        the dry-run variants exercise at pod scale.  None in host mode
        (no device slabs exist there)."""
        import jax
        import jax.numpy as jnp
        if any(p.slab is None for p in self.pools):
            return None
        # stage through the host: the per-shard slabs are committed to
        # different devices, so stacking them directly would mix devices
        # (the transient borrow-staging tails are not part of the pool)
        stacked = np.stack([np.asarray(p.slab)[:p.capacity]
                            for p in self.pools])
        if mesh is None:
            return jnp.asarray(stacked)
        from ..distributed.sharding import slab_sharding
        return jax.device_put(stacked, slab_sharding(mesh, stacked.shape))

    def check_invariants(self) -> None:
        """Per-shard residency invariant (slab == pool members, slots
        consistent) plus the global placement invariant (no page
        resident on a shard placement didn't assign it).  Raises
        AssertionError on violation — the churn tests call this after
        every access."""
        pl = self.placement()
        for s in range(self.num_shards):
            dev, bp = self.pools[s], self.buffer_pools[s]
            assert bp.resident_pages() == dev.resident_pages(), \
                f"shard {s}: pool resident set != slab occupancy"
            occ = dev.occupied_slots()
            assert len(occ) == len(dev.slot_of), f"shard {s}: slot aliasing"
            assert len(occ) + len(dev._free) == dev.capacity
            for pid in dev.resident_pages():
                assert s in pl.shards_of(pid), \
                    f"page {pid} resident on shard {s}, owned by " \
                    f"{pl.shards_of(pid)}"
        for s in self.dead:
            assert not self.pools[s].resident_pages(), \
                f"dead shard {s} still holds resident pages"
            assert not self._staged[s], \
                f"dead shard {s} still has staged borrows"


class _ShardedPoolView:
    """Union read-view over the per-shard buffer pools — quacks enough
    like one :class:`BufferPool` for the engines (scheduler residency),
    benchmarks (hit stats) and the λ-prefetcher (placement-routed
    admission)."""

    def __init__(self, sharded: ShardedPagePool):
        self._s = sharded

    def resident_pages(self) -> Set[int]:
        out: Set[int] = set()
        for bp in self._s.buffer_pools:
            out |= bp.resident_pages()
        return out

    def _sum(self, attr: str) -> int:
        return sum(getattr(bp, attr) for bp in self._s.buffer_pools)

    @property
    def hits(self) -> int:
        return self._sum("hits")

    @property
    def misses(self) -> int:
        return self._sum("misses")

    @property
    def evictions(self) -> int:
        return self._sum("evictions")

    @property
    def prefetches(self) -> int:
        return self._sum("prefetches")

    @property
    def prefetch_declined(self) -> int:
        return self._sum("prefetch_declined")

    @property
    def hit_ratio(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def reset_stats(self) -> None:
        for bp in self._s.buffer_pools:
            bp.reset_stats()

    @contextlib.contextmanager
    def deferred_loads(self):
        """Batch physical loads across every shard pool: whichever shard
        a page routes to, its loads flush as one grouped transfer per
        shard on exit (the prefetcher wraps its issuing loop in this)."""
        with contextlib.ExitStack() as stack:
            for bp in self._s.buffer_pools:
                stack.enter_context(bp.deferred_loads())
            yield

    def model_rates(self) -> Dict:
        """Per-model λ estimates summed over shards (each shard sees a
        slice of the model's demand stream)."""
        out: Dict = {}
        for bp in self._s.buffer_pools:
            for m, lam in bp.model_rates().items():
                out[m] = out.get(m, 0.0) + lam
        return out

    def prefetch(self, model, page) -> bool:
        """Placement-routed speculative admission: a page prefetches into
        its primary owning shard (never a non-owner), declined when
        already resident on any owner."""
        pid = int(page)
        pl = self._s.placement()
        owners = [o for o in pl.shards_of(pid) if o not in self._s.dead]
        if not owners:                    # every owner failed: no home
            return False
        if any(pid in self._s.pools[o].slot_of for o in owners):
            return False
        return self._s.buffer_pools[owners[0]].prefetch(model, pid)


# ----------------------------------------------------------- sharded server --
class ShardedWeightServer(WeightServer):
    """Page-granular weight access across a sharded device page pool.

    Drop-in for ``WeightServer(backend="device")``: the engines call the
    same ``access_pages`` / ``access_pages_grouped`` / ``device_*``
    surface.  Each batch is routed to the shard owning the majority of
    its cover pages; owned pages fault through that shard's buffer pool
    (storage-charged), minority pages are borrowed from their owning
    shards' host mirrors into the executing shard's staging slab
    (interconnect-charged) — both on the fetch channel.

    ``capacity_pages`` is PER SHARD (one accelerator's slab), so adding
    shards adds aggregate capacity, which is the point: a working set
    that thrashes one slab partitions across the mesh.
    """

    def __init__(self, store: ModelStore, capacity_pages: int,
                 policy: str = "optimized_mru",
                 storage: Optional[StorageModel] = None,
                 shards: int = 2, placement: str = "sharers",
                 kernel_mode: str = "auto",
                 interconnect: Optional[StorageModel] = None,
                 replicate_frac: float = 0.5,
                 borrow_capacity: Optional[int] = None,
                 devices: Optional[Sequence] = None,
                 transfer: str = "grouped",
                 charge_transfer: bool = False,
                 hbm: Optional[StorageModel] = None,
                 balance_replicas: bool = True):
        self.store = store
        self.backend = "device"
        self.transfer = transfer
        self.charge_transfer = charge_transfer
        self.hbm_channel = hbm
        self.sharded = ShardedPagePool(
            store, shards, capacity_pages, placement=placement,
            policy=policy, kernel_mode=kernel_mode,
            replicate_frac=replicate_frac, borrow_capacity=borrow_capacity,
            devices=devices, transfer=transfer)
        self.device_pool = self.sharded        # aggregate reporting view
        self.pool = self.sharded.view          # union view for the engines
        self.router = ShardRouter(self.sharded.placement,
                                  balance_replicas=balance_replicas,
                                  dead_fn=lambda: self.sharded.dead)
        self.storage = storage or StorageModel("ssd", channel="storage")
        # Borrow transfers move host-mirror bytes across the mesh, not
        # through the storage tier: charged at host-DRAM/interconnect
        # rates unless told otherwise.
        self.interconnect = interconnect or StorageModel("dram", channel="interconnect")
        bh, bw = store.cfg.dedup.block_shape
        self.page_bytes = store.cfg.blocks_per_page * bh * bw \
            * store.native_page_dtype().itemsize
        self.stats = ServeStats()
        self._pool_arr: Optional[np.ndarray] = None
        self._pool_gen = store.pack_generation
        self._route: Optional[RouteDecision] = None
        self._fault_snap = store.fault_stats.snapshot()

    @property
    def num_shards(self) -> int:
        return self.sharded.num_shards

    def shard_resident_pages(self, shard: Optional[int] = None):
        """Resident page ids of ONE shard's pool (``None``: the union
        view).  The frontend's admission probe scores a candidate batch
        against the residency of the shard the router would place it on
        — not the union — so cross-shard dedup affinity is never
        overcounted."""
        if shard is None:
            return self.pool.resident_pages()
        return self.sharded.buffer_pools[int(shard)].resident_pages()

    # ------------------------------------------------------------- failover --
    def fail_shard(self, shard: int) -> None:
        """Fail a shard mid-run: traffic re-routes to survivors, its
        owned pages serve via borrow staging (mirror or store), and the
        cached route is dropped if it pointed there."""
        self.sharded.fail_shard(shard)
        self.stats.failovers = self.sharded.failovers
        if self._route is not None and self._route.shard == int(shard):
            self._route = None

    def revive_shard(self, shard: int) -> None:
        self.sharded.revive_shard(shard)

    # -------------------------------------------------------- invalidation --
    def _sync_store(self) -> None:
        self.store.packing                     # force repack if stale
        if self._pool_gen == self.store.pack_generation:
            return
        for bp in self.sharded.buffer_pools:
            bp.invalidate_resident()           # fires on_evict -> shard slab
        self.sharded.flush()
        sharers, locality = self.store.page_metadata()
        for bp in self.sharded.buffer_pools:
            bp.page_sharers = sharers
            bp.page_locality = locality
            bp.meta.clear()
        self._pool_arr = None
        self._route = None
        self._pool_gen = self.store.pack_generation

    # -------------------------------------------------------------- routing --
    def _resolve_route(self, pages) -> RouteDecision:
        """The device compute paths re-derive their routing instead of
        trusting ambient state: a page subset of the last *accessed*
        batch reuses that batch's shard (so an LM model-switch assembles
        every tensor on the one shard its pages were faulted/staged on);
        anything else recomputes the deterministic decision."""
        pl = self.sharded.placement()
        ps = set(int(p) for p in pages)
        r = self._route
        if r is not None and r.pack_generation == pl.pack_generation \
                and r.shard not in self.sharded.dead \
                and ps <= r.page_set:
            owned, borrowed = self.router.split(ps, r.shard)
            return RouteDecision(r.shard, tuple(owned), tuple(borrowed),
                                 pl.pack_generation)
        return self.router.route(ps, record=False)

    # --------------------------------------------------------------- access --
    def _record_route(self, route: RouteDecision) -> None:
        self._route = route
        self.stats.shard_batches[route.shard] = \
            self.stats.shard_batches.get(route.shard, 0) + 1

    def access_pages(self, model: str, page_ids) -> float:
        """Serial access: owned pages one at a time through the routed
        shard's pool (every miss pays its own seek), then the borrow
        staging; returns total virtual seconds."""
        self._sync_store()
        route = self.router.route(list(page_ids))
        self._record_route(route)
        bp = self.sharded.buffer_pools[route.shard]
        try:                      # pinned, like the single-slab server:
            flags = bp.access_group(model, list(route.owned))
        except ValueError:        # group can't co-reside: unpinned
            flags = [bp.access(model, p) for p in route.owned]
        t = 0.0
        misses = 0
        for hit in flags:
            if not hit:
                t += self.storage.fetch_seconds(self.page_bytes)
                misses += 1
                self.stats.pages_fetched += 1
        t += self._charge_hbm(misses)
        t += self._borrow(route, model, grouped=False)
        t += self._charge_faults()
        self.stats.fetch_seconds += t
        return t

    def access_pages_grouped(self, model: str, page_ids) -> float:
        """Grouped access: the routed shard's owned misses share one
        seek (pinned as a group so same-batch faults cannot tear the
        shard slab), borrows ride one grouped mirror fetch."""
        self._sync_store()
        pages = list(page_ids)
        with get_tracer().span("fault_group", kind="storage", model=model,
                               pages=len(pages)) as sp:
            self.store.fault_pages(pages)
            route = self.router.route(pages)
            self._record_route(route)
            bp = self.sharded.buffer_pools[route.shard]
            try:
                flags = bp.access_group(model, list(route.owned))
            except ValueError:
                flags = [bp.access(model, p) for p in route.owned]
            misses = sum(not h for h in flags)
            t = self.storage.fetch_group_seconds(self.page_bytes, misses)
            t += self._charge_hbm(misses)
            self.stats.pages_fetched += misses
            t += self._borrow(route, model, grouped=True)
            t += self._charge_faults()
            sp.set(shard=route.shard, misses=misses,
                   borrowed=len(route.borrowed), seconds=t)
        self.stats.fetch_seconds += t
        return t

    def _borrow(self, route: RouteDecision, model: str,
                grouped: bool) -> float:
        """Run the borrow protocol for a routed batch's minority pages;
        returns the virtual seconds charged to the fetch channel
        (owner-side storage faults + mirror->stage interconnect copies).
        """
        tr = get_tracer()
        with tr.span("borrow_stage", kind="borrow", shard=route.shard,
                     pages=len(route.borrowed)) as sp:
            res = self.sharded.stage_borrows(route.shard, route.borrowed,
                                             model)
            if res is not None:
                _, mh, of, ru = res
                sp.set(mirror_hits=mh, owner_faults=of, reused=ru)
            else:
                sp.set(refused=True)
        if res is None:
            # Oversized borrow set: staging refused, compute will fall
            # back to the host — which still has to READ those pages, so
            # charge them as storage misses (never a free ride, or the
            # benchmark's worst-case regime undercounts exactly where it
            # matters).
            n = len(route.borrowed)
            if grouped:
                t = self.storage.fetch_group_seconds(self.page_bytes, n)
            else:
                t = n * self.storage.fetch_seconds(self.page_bytes)
            self.stats.pages_fetched += n
            self.stats.borrow_seconds += t
            return t
        staged, mirror_hits, owner_faults, reused = res
        # coalesced borrows (already staged by a previous same-shard
        # batch) move no bytes and pay no interconnect charge — only the
        # freshly staged pages do
        n = mirror_hits + owner_faults
        self.stats.borrow_coalesced += reused
        if not n:
            return 0.0
        if grouped:
            t = self.storage.fetch_group_seconds(self.page_bytes,
                                                 owner_faults) \
                + self.interconnect.fetch_group_seconds(self.page_bytes, n)
        else:
            t = owner_faults * self.storage.fetch_seconds(self.page_bytes) \
                + n * self.interconnect.fetch_seconds(self.page_bytes)
        self.stats.pages_fetched += owner_faults
        self.stats.borrow_pages += n
        self.stats.borrow_seconds += t
        self.stats.borrow_mirror_hits += mirror_hits
        self.stats.borrow_store_faults += owner_faults
        return t

    # ---------------------------------------------- transfer double buffer --
    def _hbm(self) -> StorageModel:
        """Host<->HBM channel calibrated from shard 0's transfer engine
        (the shards' slabs are identical in shape and placement class)."""
        if self.hbm_channel is None:
            self.hbm_channel = self.sharded.pools[0].transfer.storage_model()
        return self.hbm_channel

    def prestage(self, page_ids) -> None:
        """Stage the next batch's *owned* missing pages on the shard it
        will route to (borrowed pages move through the staging slab, not
        the transfer engine, so they are not prestaged)."""
        if self.transfer != "grouped":
            return
        self._sync_store()
        route = self.router.route(list(page_ids), record=False)
        if route.owned:
            self.sharded.pools[route.shard].transfer.stage(route.owned)

    def transfer_snapshot(self):
        out = {"seconds": 0.0, "pages": 0, "bytes": 0, "groups": 0,
               "overlapped_bytes": 0}
        for p in self.sharded.pools:
            s = p.transfer.stats
            out["seconds"] += s.seconds
            out["pages"] += s.pages
            out["bytes"] += s.bytes
            out["groups"] += s.groups
            out["overlapped_bytes"] += s.overlapped_bytes
        return out

    # ------------------------------------------------- device (HBM) path --
    def device_gather_rows(self, model: str, tensor: str, rows,
                           pad: bool = False, pages=None):
        self._sync_store()
        vt = self.store.virtual_tensor(model, tensor)
        route = self._resolve_route(pages if pages is not None
                                    else vt.page_ids)
        s = route.shard
        staged = self.sharded.staged(s)
        if any(p not in staged for p in route.borrowed):
            return None
        if not self.sharded.pools[s].pages_resident(route.owned):
            return None
        dev_map, uses_extra = self.sharded.remap(
            s, vt, key=(model, tensor), strict=pages is None)
        if dev_map is None:
            return None
        return self.sharded.gather_rows(s, dev_map, vt.grid, rows, pad=pad,
                                        uses_extra=uses_extra)

    def _device_map_sharded(self, model: str, tensor: str):
        vt = self.store.virtual_tensor(model, tensor)
        route = self._resolve_route(vt.page_ids)
        s = route.shard
        staged = self.sharded.staged(s)
        if any(p not in staged for p in route.borrowed) \
                or not self.sharded.pools[s].pages_resident(route.owned):
            return vt, s, None, False
        dev_map, uses_extra = self.sharded.remap(s, vt,
                                                 key=(model, tensor),
                                                 strict=True)
        return vt, s, dev_map, uses_extra

    def device_matmul(self, model: str, tensor: str, x):
        self._sync_store()
        vt, s, dev_map, uses_extra = self._device_map_sharded(model, tensor)
        if dev_map is None:
            return None
        return self.sharded.virtual_matmul(s, dev_map, vt.grid, x,
                                           uses_extra=uses_extra)

    def device_tensor(self, model: str, tensor: str):
        self._sync_store()
        vt, s, dev_map, uses_extra = self._device_map_sharded(model, tensor)
        if dev_map is None:
            return None
        return self.sharded.unblock(s, dev_map, vt.grid,
                                    uses_extra=uses_extra)
