"""Paged KV-cache allocator (block tables), mirroring the paper's page
abstraction on the *activation* side: sequence positions are grouped into
fixed-size blocks, requests own block lists, and freeing a request
returns its blocks to the pool — so a multi-request decode batch shares
one physical cache pool with no per-request max-length reservation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class BlockTable:
    """One request's KV block list + how many positions are filled."""
    request_id: str
    blocks: List[int]
    length: int = 0                 # filled token positions


class PagedKVCache:
    """Paged KV-cache allocator: fixed-size blocks handed out from a
    free list per request, vLLM-style, so cache memory fragments by
    block rather than by max-sequence reservation (ROADMAP: unify
    with the dedup page pool)."""

    def __init__(self, num_blocks: int, block_size: int):
        self.block_size = block_size
        self.free: List[int] = list(range(num_blocks))[::-1]
        self.tables: Dict[str, BlockTable] = {}
        self.peak_used = 0

    @property
    def used_blocks(self) -> int:
        return sum(len(t.blocks) for t in self.tables.values())

    def can_allocate(self, tokens: int) -> bool:
        need = -(-tokens // self.block_size)
        return len(self.free) >= need

    def allocate(self, request_id: str, tokens: int) -> BlockTable:
        if request_id in self.tables:
            # overwriting would orphan the old table's blocks: they never
            # return to the free list, shrinking the pool permanently
            raise ValueError(f"request {request_id!r} already has a block "
                             "table; release() it first")
        need = -(-tokens // self.block_size)
        if len(self.free) < need:
            raise MemoryError(f"KV pool exhausted: need {need} blocks, "
                              f"{len(self.free)} free")
        table = BlockTable(request_id, [self.free.pop() for _ in range(need)],
                           tokens)
        self.tables[request_id] = table
        self.peak_used = max(self.peak_used, self.used_blocks)
        return table

    def extend(self, request_id: str, new_tokens: int = 1) -> BlockTable:
        t = self.tables[request_id]
        old_length = t.length
        old_blocks = len(t.blocks)
        t.length += new_tokens
        while t.length > len(t.blocks) * self.block_size:
            if not self.free:
                # roll back: a half-applied extend would leave length
                # claiming positions no block covers (position_to_slot
                # would IndexError later) and leak the appended blocks
                self.free.extend(t.blocks[old_blocks:])
                del t.blocks[old_blocks:]
                t.length = old_length
                raise MemoryError("KV pool exhausted on extend")
            t.blocks.append(self.free.pop())
        self.peak_used = max(self.peak_used, self.used_blocks)
        return t

    def release(self, request_id: str) -> None:
        t = self.tables.pop(request_id, None)
        if t:
            self.free.extend(t.blocks)

    def position_to_slot(self, request_id: str, pos: int) -> int:
        t = self.tables[request_id]
        return t.blocks[pos // self.block_size] * self.block_size \
            + pos % self.block_size
