"""Open-loop request traffic on the virtual clock.

The paper's serving claims (Sec. 8, Fig. 8) are about latency under
*load*: individual requests arriving over time, not pre-built batches.
This module supplies the missing request stream:

  * :class:`Request` — one typed arrival: ``(model, payload,
    arrival_t, deadline)`` stamped in virtual seconds.
  * :class:`OpenLoopTraffic` — a seeded open-loop generator: Poisson
    interarrivals at a fixed offered rate (arrivals never wait for the
    server — that is what makes the loop *open*), model popularity
    drawn Zipf(α) so a few variants are hot and the tail is cold, the
    regime dedup-aware caching is built for.
  * :class:`VirtualClock` — the frontend's single-channel discrete
    event clock.  Every second of simulated time is charged to a named
    channel (``storage`` / ``compute`` / ``idle`` / ...), mirroring the
    :class:`~repro.serving.engine.StorageModel` channel discipline, so
    "no free latency" is auditable after the fact.
  * :class:`TrafficSpec` — the ``launch/serve.py --traffic`` grammar
    (``"rate=200,zipf=1.1,slo_ms=50,seed=0"``), same comma key=value
    spelling as :class:`~repro.storage.faults.FaultSpec`.

Everything is deterministic under a fixed seed: one
``np.random.default_rng(seed)`` stream drives interarrivals, model
choice and payload synthesis, so a traffic trace — and every latency
measured through it — is exactly reproducible.  No wall time anywhere
(the ``wallclock`` lint bans it; the ``frontend-clock`` lint
additionally pins this module and the frontend to the virtual clock).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["Request", "TrafficSpec", "VirtualClock", "OpenLoopTraffic",
           "zipf_weights", "zoo_popularity"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One arrival in the open-loop stream.  ``payload`` is whatever
    the target engine's ``submit`` takes (a docs array for the
    embedding engine, ``(prompts, steps)`` for the LM engine);
    ``deadline = arrival_t + slo`` is the latest acceptable completion
    on the virtual clock."""
    rid: int
    model: str
    payload: object
    arrival_t: float
    deadline: float

    def slack(self, now: float) -> float:
        """Virtual seconds until this request blows its SLO."""
        return self.deadline - now


# ------------------------------------------------------------- spec ------
_FLOAT_FIELDS = ("rate", "zipf", "slo_ms")
_INT_FIELDS = ("seed", "requests", "max_batch")


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """The ``--traffic`` CLI grammar: offered rate (requests per
    virtual second), Zipf popularity exponent, per-request SLO, seed,
    stream length and the frontend's batch-size cap."""
    rate: float = 200.0
    zipf: float = 1.1
    slo_ms: float = 50.0
    seed: int = 0
    requests: int = 200
    max_batch: int = 8

    @classmethod
    def parse(cls, text: "str | TrafficSpec | None") -> "TrafficSpec":
        """``"rate=500,zipf=1.2,slo_ms=25,seed=7"`` -> TrafficSpec;
        the empty string parses to the defaults."""
        if isinstance(text, TrafficSpec):
            return text
        kw = {}
        for part in (text or "").split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"bad traffic spec item {part!r} "
                                 "(expected key=value)")
            k, v = part.split("=", 1)
            k = k.strip()
            if k in _FLOAT_FIELDS:
                kw[k] = float(v)
            elif k in _INT_FIELDS:
                kw[k] = int(v)
            else:
                raise ValueError(
                    f"unknown traffic spec key {k!r} (expected one of "
                    f"{_FLOAT_FIELDS + _INT_FIELDS})")
        spec = cls(**kw)
        if spec.rate <= 0:
            raise ValueError("traffic rate must be > 0")
        if spec.slo_ms <= 0:
            raise ValueError("traffic slo_ms must be > 0")
        return spec

    def __str__(self) -> str:
        default = TrafficSpec()
        items = [f"{f.name}={getattr(self, f.name)}"
                 for f in dataclasses.fields(self)
                 if getattr(self, f.name) != getattr(default, f.name)]
        return ",".join(items) or "default"


# ------------------------------------------------------------- clock -----
class VirtualClock:
    """Single-lane virtual clock with named-channel attribution.

    ``now`` only moves through :meth:`advance` (charge ``seconds`` to a
    named channel) or :meth:`tick_to` (idle forward to an absolute
    time), so after a run ``sum(channels.values()) == now`` — every
    simulated second is accounted to storage, compute, idle or another
    named channel, never conjured."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)
        self._start = float(start)
        self.channels: Dict[str, float] = {}

    def advance(self, seconds: float, channel: str) -> float:
        """Charge ``seconds`` of ``channel`` time; returns the new now."""
        if seconds < 0:
            raise ValueError(f"cannot advance the clock by {seconds!r}s")
        self.channels[channel] = self.channels.get(channel, 0.0) + seconds
        self.now += seconds
        return self.now

    def tick_to(self, t: float, channel: str = "idle") -> float:
        """Idle forward to absolute virtual time ``t`` (no-op when
        ``t`` is in the past); returns the new now."""
        if t > self.now:
            self.advance(t - self.now, channel)
        return self.now

    def spent(self, channel: str) -> float:
        """Seconds charged to ``channel`` so far."""
        return self.channels.get(channel, 0.0)

    def snapshot(self) -> Dict:
        """JSON-safe state for warm restart: ``from_snapshot`` rebuilds
        a clock with the same now/start/channel ledger, so conservation
        (and every latency measured against ``now``) carries across a
        process death."""
        return {"now": self.now, "start": self._start,
                "channels": dict(self.channels)}

    @classmethod
    def from_snapshot(cls, snap: Dict) -> "VirtualClock":
        """Rebuild a clock from :meth:`snapshot` output."""
        clock = cls(float(snap.get("start", 0.0)))
        clock.channels = {str(k): float(v)
                          for k, v in snap["channels"].items()}
        clock.now = float(snap["now"])
        return clock

    def assert_conserved(self, tol: float = 1e-9) -> None:
        """Fail loudly if any simulated second escaped the channel
        ledger: ``sum(channels) == now - start`` within ``tol``.  A
        future un-charged mutation of ``now`` shows up here instead of
        silently skewing idle-time attribution."""
        booked = sum(self.channels.values())
        elapsed = self.now - self._start
        if abs(booked - elapsed) > tol:
            raise AssertionError(
                f"virtual clock leaked time: channels sum to "
                f"{booked!r}s but now-start is {elapsed!r}s "
                f"(channels={self.channels!r})")


# ------------------------------------------------------- popularity ------
def zipf_weights(n: int, alpha: float) -> np.ndarray:
    """Zipf(α) probability vector over ``n`` ranks: weight of rank k is
    ∝ 1 / k**α (α=0 degenerates to uniform)."""
    if n <= 0:
        raise ValueError("need at least one model")
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** float(alpha)
    return w / w.sum()


def zoo_popularity(alpha: float = 1.1) -> Dict[str, float]:
    """Zipf(α) popularity over the full ``configs/`` model zoo (the
    reduced-shape architectures ``list_archs`` knows), rank order =
    registry order.  The handful of head archs soak up most of the
    traffic — the mixed-zoo regime the dedup store is meant to serve."""
    from ..configs import list_archs
    archs = list_archs()
    return dict(zip(archs, zipf_weights(len(archs), alpha).tolist()))


# -------------------------------------------------------- generator ------
class OpenLoopTraffic:
    """Seeded open-loop request generator.

    ``models``: the serveable model names, hottest first (rank order is
    Zipf rank order).  ``rate``: offered load in requests per virtual
    second — arrivals are Poisson, so interarrival gaps are Exp(rate)
    draws.  ``slo_s``: each request's deadline is ``arrival + slo_s``.
    ``payload_fn(model, rid, rng) -> payload`` synthesizes the request
    body from the generator's own rng stream (one stream: trace and
    payloads reproduce together); ``None`` leaves payloads ``None``
    for tests that only study arrival dynamics.
    """

    def __init__(self, models: Sequence[str], rate: float,
                 zipf_alpha: float = 1.1, slo_s: float = 0.05,
                 seed: int = 0,
                 payload_fn: Optional[Callable] = None):
        if rate <= 0:
            raise ValueError("offered rate must be > 0")
        self.models = list(models)
        self.rate = float(rate)
        self.slo_s = float(slo_s)
        self.weights = zipf_weights(len(self.models), zipf_alpha)
        self.payload_fn = payload_fn
        self.rng = np.random.default_rng(seed)
        self._next_rid = 0
        self._t = 0.0

    def generate(self, n: int) -> List[Request]:
        """The next ``n`` arrivals of the stream (call again to
        continue it: the clock and rng carry over)."""
        out: List[Request] = []
        for _ in range(n):
            self._t += float(self.rng.exponential(1.0 / self.rate))
            model = self.models[int(self.rng.choice(len(self.models),
                                                    p=self.weights))]
            rid = self._next_rid
            self._next_rid += 1
            payload = self.payload_fn(model, rid, self.rng) \
                if self.payload_fn is not None else None
            out.append(Request(rid=rid, model=model, payload=payload,
                               arrival_t=self._t,
                               deadline=self._t + self.slo_s))
        return out
