"""Page prefetcher: queue-aware lookahead + λ-driven speculation.

Two planning tiers, consumed in order:

1. **Queue-aware lookahead** (deterministic): the scheduler exposes the
   pending batches' page working sets (``BatchScheduler.
   pending_batches``, estimated at submit time), so the prefetcher
   *knows* what is about to be demanded.  Those pages — deduped against
   the pool's resident set and gated on the packing generation they
   were minted under — are pulled first.
2. **λ-driven speculation** (paper Eq. 2): the buffer pool estimates
   each model's arrival rate online; the hottest models' missing pages
   are most likely to be demanded next, so any *remaining* idle budget
   goes to them.

Either way the virtual storage time lands on the fetch channel, where
the engine's double-buffered timeline overlaps it with compute.
Admission goes through :meth:`BufferPool.prefetch`, which never counts
a hit/miss (demand-traffic stats stay clean) and refuses to displace
pages the eviction policy rates hotter.

``PrefetchStats.lookahead_hits`` is the proof stat: pages issued by the
lookahead tier that a later demand access actually hit (the engines
report each batch's demand set via :meth:`Prefetcher.note_demand`).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["PrefetchStats", "Prefetcher"]


@dataclasses.dataclass
class PrefetchStats:
    """Prefetcher proof counters (issued/declined/lookahead hits)."""
    issued: int = 0            # pages actually loaded ahead of demand
    declined: int = 0          # offers the pool's admission refused
    seconds: float = 0.0       # virtual storage time spent prefetching
    lookahead_issued: int = 0  # of issued: planned from queued batches
    lookahead_hits: int = 0    # lookahead pages a demand access then hit


class Prefetcher:
    """Plans and issues page prefetches for a :class:`WeightServer`.

    ``hot_models``: how many of the highest-lambda models to prefetch for.
    ``max_pages_per_step``: page budget per :meth:`step` call (one call
    per served batch keeps the fetch channel from drowning in
    speculation).
    ``lookahead``: how many queued batches to scan for the queue-aware
    tier (0 disables it).  The engines attach their scheduler via
    :meth:`attach_scheduler`; without one the prefetcher is pure-λ, the
    pre-lookahead behavior.
    """

    def __init__(self, server, hot_models: int = 2,
                 max_pages_per_step: int = 4, lookahead: int = 8):
        self.server = server
        self.hot_models = hot_models
        self.max_pages_per_step = max_pages_per_step
        self.lookahead = lookahead
        self.scheduler = None
        self._rate_fn = None
        self.stats = PrefetchStats()
        self._gen = None
        self._plan_lookahead: Set[int] = set()   # lookahead pages, last plan
        self._outstanding: Set[int] = set()      # issued, not yet demanded
        self._refresh()

    def attach_scheduler(self, scheduler) -> None:
        """Give the prefetcher visibility into the pending queue (the
        engines call this at construction)."""
        self.scheduler = scheduler

    def attach_rates(self, rate_fn) -> None:
        """Override the λ source with *observed* arrival rates: a
        zero-arg callable returning ``{model: requests/s}`` (the
        serving frontend attaches its EMA over request arrivals on the
        virtual clock).  The pool's access-count rates — a trailing
        proxy measured after batching — are then only the fallback
        while the feed is empty, so the speculative tier re-targets as
        soon as the arrival mix shifts instead of waiting for the new
        mix to dominate the access history."""
        self._rate_fn = rate_fn

    def _refresh(self) -> None:
        """(Re)derive the per-model page working sets from the store's
        *current* packing.  Keyed on ``pack_generation`` so a model
        update/repack mid-serve can never leave the prefetcher pulling
        page ids from the previous packing (which now name other bytes —
        or nothing)."""
        self.server.store.packing                # force repack if stale
        gen = self.server.store.pack_generation
        if gen == self._gen:
            return
        # model -> its page working set, from the store's packing
        self._model_pages: Dict[str, List[int]] = {
            m: self.server.store.model_pages(m)
            for m in self.server.store.dedup.models}
        counts = self.server.store.page_sharer_counts()
        self._n_sharers = {p: int(c) for p, c in enumerate(counts)}
        self._outstanding.clear()                # stale page ids
        self._gen = gen

    # -- planning ------------------------------------------------------------
    def plan(self) -> List[Tuple[str, int]]:
        """(model, page) prefetch candidates: queued batches' pages
        first (arrival order), then the λ tier — hottest model first,
        most-shared pages first within a model (they serve several
        queues)."""
        self._refresh()
        resident = self.server.pool.resident_pages()
        out: List[Tuple[str, int]] = []
        seen = set()
        self._plan_lookahead = set()
        # tier 1: what the queue says is coming
        if self.scheduler is not None and self.lookahead > 0:
            gen = self.server.store.pack_generation
            for b in self.scheduler.pending_batches()[: self.lookahead]:
                if b.pages is None or b.pages_gen != gen:
                    continue                     # stale or unknown set
                for p in sorted(b.pages):
                    if p in resident or p in seen:
                        continue
                    out.append((b.model, p))
                    seen.add(p)
                    self._plan_lookahead.add(p)
                    if len(out) >= self.max_pages_per_step:
                        return out
        # tier 2: λ speculation with whatever budget remains; observed
        # arrival rates (frontend feed) beat the pool's access-count
        # proxy whenever the feed has seen traffic
        rates = self._rate_fn() if self._rate_fn is not None else {}
        if not rates:
            rates = self.server.pool.model_rates()
        hot = sorted(rates, key=rates.get, reverse=True)[: self.hot_models]
        for m in hot:
            missing = [p for p in self._model_pages.get(m, ())
                       if p not in resident and p not in seen]
            missing.sort(key=lambda p: (-self._n_sharers.get(p, 1), p))
            for p in missing:
                out.append((m, p))
                seen.add(p)
                if len(out) >= self.max_pages_per_step:
                    return out
        return out

    # -- accounting ----------------------------------------------------------
    def note_demand(self, pages) -> None:
        """The engines report each batch's demand page set here; pages
        the lookahead tier issued that now get demanded are the
        lookahead *hits* — the stat proving the queue-aware tier pulled
        the right pages."""
        if not self._outstanding:
            return
        hit = self._outstanding.intersection(int(p) for p in pages)
        if hit:
            self.stats.lookahead_hits += len(hit)
            self._outstanding -= hit

    # -- execution -----------------------------------------------------------
    def step(self, budget_s: Optional[float] = None) -> float:
        """Issue one planning round of prefetches; returns the virtual
        storage seconds consumed (the engine charges them to the fetch
        channel, overlapped with compute).

        ``budget_s`` caps the storage time spent.  The *actual* (jittered)
        cost is accumulated page by page and issuing stops as soon as the
        next expected transfer would overrun, so a slow draw can exceed
        the budget by at most one in-flight page transfer — the engine
        passes the fetch channel's idle headroom, keeping speculation off
        the demand path.  The round still amortizes like ONE grouped
        fetch: a single seek, then seek-less per-page transfers —
        page-at-a-time prefetching would pay a seek per page and lose to
        the demand path's own group amortization.

        The *physical* reads group the same way the accounting does:
        admission runs inside the pool's ``deferred_loads`` window, so
        every page the policy admits this round flushes as ONE grouped
        backend read + ONE host->HBM transfer (``on_load_group``) —
        never a per-page ``store.page_array`` -> ``get_pages`` round
        trip per admitted page."""
        from ..obs import get_tracer
        storage = self.server.storage
        base_transfer = self.server.page_bytes / storage.bw
        issued = 0
        t = 0.0
        deferred = getattr(self.server.pool, "deferred_loads",
                           contextlib.nullcontext)
        with get_tracer().span("prefetch_step", kind="policy",
                               budget_s=budget_s) as sp:
            with deferred():
                for model, page in self.plan():
                    cost_floor = (storage.seek if issued == 0 else 0.0) \
                        + base_transfer
                    if budget_s is not None and t + cost_floor > budget_s:
                        break
                    if self.server.pool.prefetch(model, page):
                        if issued == 0:
                            t += storage.fetch_seconds(
                                self.server.page_bytes)
                        else:
                            t += storage.transfer_seconds(
                                self.server.page_bytes)
                        issued += 1
                        if page in self._plan_lookahead:
                            self.stats.lookahead_issued += 1
                            self._outstanding.add(int(page))
                    else:
                        self.stats.declined += 1
            sp.set(issued=issued, seconds=t,
                   lookahead_hits=self.stats.lookahead_hits)
        self.stats.issued += issued
        self.stats.seconds += t
        return t
