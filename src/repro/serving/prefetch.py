"""λ-driven page prefetcher (paper Eq. 2, used *ahead* of demand).

The buffer pool already estimates each model's arrival rate lambda_i
online (it feeds Eq. 2's superposed-Poisson reuse probability).  The
prefetcher reuses those same estimates in the other direction: the
hottest models are the ones whose pages are most likely to be demanded
next, so during a batch's *compute* phase it pulls their missing pages
into the pool — the virtual storage time lands on the fetch channel,
where the engine's double-buffered timeline overlaps it with compute.

Admission goes through :meth:`BufferPool.prefetch`, which never counts a
hit/miss (demand-traffic stats stay clean) and refuses to displace pages
the eviction policy rates hotter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["PrefetchStats", "Prefetcher"]


@dataclasses.dataclass
class PrefetchStats:
    issued: int = 0            # pages actually loaded ahead of demand
    declined: int = 0          # offers the pool's admission refused
    seconds: float = 0.0       # virtual storage time spent prefetching


class Prefetcher:
    """Plans and issues page prefetches for a :class:`WeightServer`.

    ``hot_models``: how many of the highest-lambda models to prefetch for.
    ``max_pages_per_step``: page budget per :meth:`step` call (one call
    per served batch keeps the fetch channel from drowning in
    speculation).
    """

    def __init__(self, server, hot_models: int = 2,
                 max_pages_per_step: int = 4):
        self.server = server
        self.hot_models = hot_models
        self.max_pages_per_step = max_pages_per_step
        self.stats = PrefetchStats()
        self._gen = None
        self._refresh()

    def _refresh(self) -> None:
        """(Re)derive the per-model page working sets from the store's
        *current* packing.  Keyed on ``pack_generation`` so a model
        update/repack mid-serve can never leave the prefetcher pulling
        page ids from the previous packing (which now name other bytes —
        or nothing)."""
        self.server.store.packing                # force repack if stale
        gen = self.server.store.pack_generation
        if gen == self._gen:
            return
        # model -> its page working set, from the store's packing
        self._model_pages: Dict[str, List[int]] = {
            m: self.server.store.model_pages(m)
            for m in self.server.store.dedup.models}
        sharers = self.server.store.page_sharers()
        self._n_sharers = {p: len(ms) for p, ms in sharers.items()}
        self._gen = gen

    # -- planning ------------------------------------------------------------
    def plan(self) -> List[Tuple[str, int]]:
        """(model, page) prefetch candidates, hottest model first; within
        a model, most-shared pages first (they serve several queues)."""
        self._refresh()
        rates = self.server.pool.model_rates()
        if not rates:
            return []
        hot = sorted(rates, key=rates.get, reverse=True)[: self.hot_models]
        resident = self.server.pool.resident_pages()
        out: List[Tuple[str, int]] = []
        seen = set()
        for m in hot:
            missing = [p for p in self._model_pages.get(m, ())
                       if p not in resident and p not in seen]
            missing.sort(key=lambda p: (-self._n_sharers.get(p, 1), p))
            for p in missing:
                out.append((m, p))
                seen.add(p)
                if len(out) >= self.max_pages_per_step:
                    return out
        return out

    # -- execution -----------------------------------------------------------
    def step(self, budget_s: Optional[float] = None) -> float:
        """Issue one planning round of prefetches; returns the virtual
        storage seconds consumed (the engine charges them to the fetch
        channel, overlapped with compute).

        ``budget_s`` caps the storage time spent.  The *actual* (jittered)
        cost is accumulated page by page and issuing stops as soon as the
        next expected transfer would overrun, so a slow draw can exceed
        the budget by at most one in-flight page transfer — the engine
        passes the fetch channel's idle headroom, keeping speculation off
        the demand path.  The round still amortizes like ONE grouped
        fetch: a single seek, then seek-less per-page transfers —
        page-at-a-time prefetching would pay a seek per page and lose to
        the demand path's own group amortization."""
        storage = self.server.storage
        base_transfer = self.server.page_bytes / storage.bw
        issued = 0
        t = 0.0
        for model, page in self.plan():
            cost_floor = (storage.seek if issued == 0 else 0.0) \
                + base_transfer
            if budget_s is not None and t + cost_floor > budget_s:
                break
            if self.server.pool.prefetch(model, page):
                if issued == 0:
                    t += storage.fetch_seconds(self.server.page_bytes)
                else:
                    t += storage.transfer_seconds(self.server.page_bytes)
                issued += 1
            else:
                self.stats.declined += 1
        self.stats.issued += issued
        self.stats.seconds += t
        return t
