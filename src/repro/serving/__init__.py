from .engine import (EmbeddingServingEngine, LMServingEngine, ServeStats,
                     StorageModel, WeightServer)
from .kvcache import PagedKVCache

__all__ = ["EmbeddingServingEngine", "LMServingEngine", "ServeStats",
           "StorageModel", "WeightServer", "PagedKVCache"]
