from .device_pool import DevicePagePool
from .engine import (EmbeddingServingEngine, FetchComputeTimeline,
                     LMServingEngine, ServeStats, StorageModel, WeightServer)
from .kvcache import PagedKVCache
from .prefetch import Prefetcher, PrefetchStats
from .scheduler import (SCHEDULERS, BatchScheduler, DedupAffinityScheduler,
                        FifoScheduler, RoundRobinScheduler, ScheduledBatch,
                        make_scheduler)

__all__ = ["DevicePagePool", "EmbeddingServingEngine",
           "FetchComputeTimeline", "LMServingEngine", "ServeStats",
           "StorageModel", "WeightServer", "PagedKVCache", "Prefetcher",
           "PrefetchStats", "SCHEDULERS", "BatchScheduler",
           "DedupAffinityScheduler", "FifoScheduler", "RoundRobinScheduler",
           "ScheduledBatch", "make_scheduler"]
