from .device_pool import DevicePagePool
from .engine import (EmbeddingServingEngine, FetchComputeTimeline,
                     LMServingEngine, ServeStats, StorageModel, WeightServer)
from .frontend import BatchComputeModel, RequestLedger, ServingFrontend
from .kvcache import PagedKVCache
from .prefetch import Prefetcher, PrefetchStats
from .router import RouteDecision, ShardRouter
from .scheduler import (SCHEDULERS, BatchScheduler, DedupAffinityScheduler,
                        FifoScheduler, RoundRobinScheduler, ScheduledBatch,
                        make_scheduler)
from .shard_pool import (PLACEMENTS, Placement, ShardedPagePool,
                         ShardedWeightServer, hash_placement, make_placement,
                         sharers_placement)
from .traffic import (OpenLoopTraffic, Request, TrafficSpec, VirtualClock,
                      zipf_weights, zoo_popularity)

__all__ = ["DevicePagePool", "EmbeddingServingEngine",
           "FetchComputeTimeline", "LMServingEngine", "ServeStats",
           "StorageModel", "WeightServer", "BatchComputeModel",
           "RequestLedger", "ServingFrontend", "PagedKVCache", "Prefetcher",
           "PrefetchStats", "SCHEDULERS", "BatchScheduler",
           "DedupAffinityScheduler", "FifoScheduler", "RoundRobinScheduler",
           "ScheduledBatch", "make_scheduler",
           "RouteDecision", "ShardRouter", "PLACEMENTS", "Placement",
           "ShardedPagePool", "ShardedWeightServer", "hash_placement",
           "make_placement", "sharers_placement",
           "OpenLoopTraffic", "Request", "TrafficSpec", "VirtualClock",
           "zipf_weights", "zoo_popularity"]
