"""Multi-model serving engine backed by the deduplicated page store.

This is the paper's runtime loop transposed to the TPU memory hierarchy
(DESIGN.md §2): the **page store** (host DRAM / checkpoint) holds the
deduplicated pages; the **buffer pool** decides which pages are
device-resident (HBM); inference touches pages through the pool, so
shared pages hit for *every* model variant that uses them.

Components:
  * :class:`StorageModel` — virtual-clock latency model for the backing
    tier (ssd / hdd / nvme / host-dram), used when a page misses.
  * :class:`WeightServer` — ModelStore + BufferPool + storage sim; tracks
    per-model arrival rates (the lambda_i of Eq. 2 flow straight into the
    pool's eviction policy).  Optional hedged fetches for stragglers.
  * :class:`EmbeddingServingEngine` — the paper's word2vec / text-
    classification scenario: requests are token batches; inference
    gathers embedding rows (touching only the pages their row blocks
    live on), mean-pools, applies the classifier head.
  * :class:`LMServingEngine` — serves a (reduced) LM via prefill/decode
    with per-model weight fetch through the pool; used by the e2e example.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.bufferpool import BufferPool
from ..core.store import ModelStore

# ------------------------------------------------------------------ storage --
STORAGE_PRESETS = {
    # (bandwidth B/s, seek seconds)
    "hdd": (150e6, 8e-3),
    "ssd": (500e6, 1e-4),
    "nvme": (3e9, 2e-5),
    "dram": (20e9, 1e-6),
}


@dataclasses.dataclass
class StorageModel:
    kind: str = "ssd"
    hedge_after: Optional[float] = None    # straggler hedging deadline (s)
    jitter: float = 0.0                    # lognormal sigma for tail latency
    seed: int = 0

    def __post_init__(self):
        self.bw, self.seek = STORAGE_PRESETS[self.kind]
        self._rng = np.random.default_rng(self.seed)

    def fetch_seconds(self, nbytes: int) -> float:
        base = self.seek + nbytes / self.bw
        if self.jitter:
            draw = base * float(self._rng.lognormal(0.0, self.jitter))
            if self.hedge_after is not None and draw > self.hedge_after:
                # hedged duplicate fetch: take min of two draws
                draw = min(draw,
                           self.hedge_after
                           + base * float(self._rng.lognormal(0.0,
                                                              self.jitter)))
            return draw
        return base


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    fetch_seconds: float = 0.0       # virtual storage time
    compute_seconds: float = 0.0     # wall compute time
    pages_fetched: int = 0
    latencies: List[float] = dataclasses.field(default_factory=list)

    @property
    def total_seconds(self) -> float:
        return self.fetch_seconds + self.compute_seconds

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies, p)) if self.latencies \
            else 0.0


# ------------------------------------------------------------- weight serve --
class WeightServer:
    """Page-granular weight access through the dedup-aware buffer pool."""

    def __init__(self, store: ModelStore, capacity_pages: int,
                 policy: str = "optimized_mru",
                 storage: Optional[StorageModel] = None):
        self.store = store
        self.pool: BufferPool = store.make_buffer_pool(capacity_pages, policy)
        self.storage = storage or StorageModel("ssd")
        bh, bw = store.cfg.dedup.block_shape
        self.page_bytes = store.cfg.blocks_per_page * bh * bw * 4
        self.stats = ServeStats()
        self._page_cache: Dict[int, np.ndarray] = {}
        self._pool_arr: Optional[np.ndarray] = None

    def _pages(self) -> np.ndarray:
        if self._pool_arr is None:
            self._pool_arr = self.store.page_pool()
        return self._pool_arr

    def access_pages(self, model: str, page_ids) -> float:
        """Touch pages through the pool; returns virtual fetch seconds."""
        t = 0.0
        for pid in page_ids:
            hit = self.pool.access(model, pid)
            if not hit:
                t += self.storage.fetch_seconds(self.page_bytes)
                self.stats.pages_fetched += 1
        self.stats.fetch_seconds += t
        return t

    def tensor_pages(self, model: str, tensor: str) -> List[int]:
        return self.store.packing.tensor_pages[(model, tensor)]

    def fetch_tensor(self, model: str, tensor: str) -> np.ndarray:
        """Access all pages of a tensor, then materialize it."""
        self.access_pages(model, self.tensor_pages(model, tensor))
        return self.store.materialize(model, tensor)

    def embedding_rows_pages(self, model: str, tensor: str,
                             rows: np.ndarray) -> List[int]:
        """Pages containing the row blocks touched by ``rows`` (the
        paper's locality win: a batch only faults its own row blocks)."""
        vt = self.store.virtual_tensor(model, tensor)
        bh = self.store.cfg.dedup.block_shape[0]
        gw = vt.grid.grid[1]
        l = self.store.cfg.blocks_per_page
        row_blocks = np.unique(rows // bh)
        logical = (row_blocks[:, None] * gw
                   + np.arange(gw)[None, :]).reshape(-1)
        slots = vt.block_map[logical]
        return sorted(set(int(s) // l for s in slots))


# ------------------------------------------------------- embedding serving --
class EmbeddingServingEngine:
    """Paper Sec. 7.1.1/7.1.2 scenario: many embedding-model variants."""

    def __init__(self, server: WeightServer,
                 heads: Dict[str, np.ndarray],
                 embed_tensor: str = "embedding"):
        self.server = server
        self.heads = heads
        self.embed_tensor = embed_tensor
        self.queues: Dict[str, deque] = defaultdict(deque)
        self.stats = ServeStats()

    def submit(self, model: str, docs: np.ndarray) -> None:
        self.queues[model].append(docs)

    def _infer(self, model: str, docs: np.ndarray) -> np.ndarray:
        rows = np.unique(docs)
        pages = self.server.embedding_rows_pages(model, self.embed_tensor,
                                                 rows)
        fetch_t = self.server.access_pages(model, pages)
        t0 = time.perf_counter()
        emb_rows = self.server.store.materialize_rows(
            model, self.embed_tensor, rows)
        idx = np.searchsorted(rows, docs)
        feats = emb_rows[idx].mean(axis=1)
        logits = feats @ self.heads[model]
        compute_t = time.perf_counter() - t0
        self.stats.fetch_seconds += fetch_t
        self.stats.compute_seconds += compute_t
        self.stats.latencies.append(fetch_t + compute_t)
        self.stats.requests += len(docs)
        self.stats.batches += 1
        return logits.argmax(axis=1)

    def run(self, max_batches: Optional[int] = None) -> ServeStats:
        """Round-robin across model queues (each queue's drain rate is the
        lambda_i feeding Eq. 2 inside the buffer pool)."""
        n = 0
        while any(self.queues.values()):
            for model in list(self.queues):
                if not self.queues[model]:
                    continue
                self._infer(model, self.queues[model].popleft())
                n += 1
                if max_batches and n >= max_batches:
                    return self.stats
        return self.stats


# --------------------------------------------------------------- LM serving --
class LMServingEngine:
    """Serve (reduced) LM variants with batched prefill/decode; weights are
    faulted in per-tensor through the dedup page pool on model switch."""

    def __init__(self, server: WeightServer, apis: Dict[str, object],
                 params_template: Dict[str, dict]):
        self.server = server
        self.apis = apis
        self.templates = params_template     # model -> params pytree (np)
        self.stats = ServeStats()
        self._resident_model: Optional[str] = None
        self._params = None

    def _load_model(self, model: str):
        if self._resident_model == model:
            return self._params
        tensors = {}
        for name in self.server.store.dedup.models[model].tensors:
            tensors[name] = self.server.fetch_tensor(model, name)
        self._params = self.templates[model], tensors
        self._resident_model = model
        return self._params

    def generate(self, model: str, prompts: np.ndarray,
                 steps: int = 8) -> Tuple[np.ndarray, float]:
        import jax.numpy as jnp
        template, tensors = self._load_model(model)
        rebuild, api = template["rebuild"], self.apis[model]
        params = rebuild(tensors)
        t0 = time.perf_counter()
        logits, cache = api.prefill(params,
                                    {"tokens": jnp.asarray(prompts)},
                                    prompts.shape[1] + steps)
        out = [np.asarray(logits.argmax(-1))]
        for _ in range(steps - 1):
            logits, cache = api.decode(params, cache,
                                       jnp.asarray(out[-1]).astype("int32"))
            out.append(np.asarray(logits.argmax(-1)))
        dt = time.perf_counter() - t0
        self.stats.compute_seconds += dt
        self.stats.latencies.append(dt)
        self.stats.requests += len(prompts)
        self.stats.batches += 1
        return np.concatenate(out, axis=1), dt
