"""Multi-model serving engine backed by the deduplicated page store.

This is the paper's runtime loop transposed to the TPU memory hierarchy
(DESIGN.md §2): the **page store** (host DRAM / checkpoint) holds the
deduplicated pages; the **buffer pool** decides which pages are
device-resident (HBM); inference touches pages through the pool, so
shared pages hit for *every* model variant that uses them.

Components:
  * :class:`StorageModel` — virtual-clock latency model for the backing
    tier (ssd / hdd / nvme / host-dram), used when a page misses.  Group
    fetches amortize the seek across a batch's misses.
  * :class:`FetchComputeTimeline` — double-buffered virtual clock: batch
    t's group fetch occupies the storage channel while batch t-1 still
    computes, so Eq. 1/Eq. 2 hit-ratio wins translate into latency wins.
  * :class:`WeightServer` — ModelStore + BufferPool + storage sim; tracks
    per-model arrival rates (the lambda_i of Eq. 2 flow straight into the
    pool's eviction policy).  Optional hedged fetches for stragglers.
  * :class:`EmbeddingServingEngine` — the paper's word2vec / text-
    classification scenario, now scheduler-driven: batch order is a
    policy (fifo / round_robin / dedup_affinity, see
    ``serving/scheduler.py``), and an optional λ-driven
    :class:`~repro.serving.prefetch.Prefetcher` pulls hot models' pages
    ahead of demand.
  * :class:`LMServingEngine` — serves (reduced) LM variants via
    prefill/decode with per-model weight fetch through the pool; the
    same scheduler/timeline machinery applies per model-switch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.bufferpool import BufferPool
from ..core.store import ModelStore
from .scheduler import BatchScheduler, ScheduledBatch, make_scheduler

# ------------------------------------------------------------------ storage --
STORAGE_PRESETS = {
    # (bandwidth B/s, seek seconds)
    "hdd": (150e6, 8e-3),
    "ssd": (500e6, 1e-4),
    "nvme": (3e9, 2e-5),
    "dram": (20e9, 1e-6),
}


@dataclasses.dataclass
class StorageModel:
    kind: str = "ssd"
    hedge_after: Optional[float] = None    # straggler hedging deadline (s)
    jitter: float = 0.0                    # lognormal sigma for tail latency
    seed: int = 0

    def __post_init__(self):
        self.bw, self.seek = STORAGE_PRESETS[self.kind]
        self._rng = np.random.default_rng(self.seed)

    def _draw(self, base: float) -> float:
        if self.jitter:
            draw = base * float(self._rng.lognormal(0.0, self.jitter))
            if self.hedge_after is not None and draw > self.hedge_after:
                # hedged duplicate fetch: take min of two draws
                draw = min(draw,
                           self.hedge_after
                           + base * float(self._rng.lognormal(0.0,
                                                              self.jitter)))
            return draw
        return base

    def fetch_seconds(self, nbytes: int) -> float:
        return self._draw(self.seek + nbytes / self.bw)

    def fetch_group_seconds(self, nbytes: int, n: int) -> float:
        """Virtual time for ``n`` pages issued as ONE grouped request:
        a single seek plus pipelined transfers (the scheduler issues a
        batch's misses together instead of page-at-a-time)."""
        if n <= 0:
            return 0.0
        return self._draw(self.seek + n * nbytes / self.bw)

    def transfer_seconds(self, nbytes: int) -> float:
        """One seek-less pipelined transfer (a follow-on page inside an
        already-open group); jitter/hedging apply per transfer."""
        return self._draw(nbytes / self.bw)


@dataclasses.dataclass
class FetchComputeTimeline:
    """Two-channel virtual clock.  The fetch channel serializes storage
    traffic (demand groups + prefetches); a batch's compute starts once
    both its fetch group completed and the previous compute finished —
    i.e. fetch(t) overlaps compute(t-1), the classic double buffer."""
    fetch_clock: float = 0.0
    compute_clock: float = 0.0

    def advance(self, fetch_t: float, compute_t: float
                ) -> Tuple[float, float]:
        """Account one batch; returns (issue_time, completion_time)."""
        issue = self.fetch_clock
        self.fetch_clock += fetch_t
        start_compute = max(self.fetch_clock, self.compute_clock)
        self.compute_clock = start_compute + compute_t
        return issue, self.compute_clock

    def charge_fetch(self, seconds: float) -> None:
        """Occupy the fetch channel without a compute phase (prefetch)."""
        self.fetch_clock += seconds

    @property
    def makespan(self) -> float:
        return max(self.fetch_clock, self.compute_clock)


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    fetch_seconds: float = 0.0       # virtual storage time (demand)
    compute_seconds: float = 0.0     # wall compute time
    prefetch_seconds: float = 0.0    # virtual storage time (speculative)
    pages_fetched: int = 0
    prefetch_pages: int = 0
    timeline_seconds: float = 0.0    # double-buffered makespan (async runs)
    latencies: List[float] = dataclasses.field(default_factory=list)

    @property
    def total_seconds(self) -> float:
        """Serial cost: every storage second plus every compute second."""
        return self.fetch_seconds + self.prefetch_seconds \
            + self.compute_seconds

    @property
    def makespan_seconds(self) -> float:
        """End-to-end virtual time: the overlapped timeline when the
        engine ran async, the serial sum otherwise."""
        return self.timeline_seconds or self.total_seconds

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies, p)) if self.latencies \
            else 0.0


# ------------------------------------------------------------- weight serve --
class WeightServer:
    """Page-granular weight access through the dedup-aware buffer pool."""

    def __init__(self, store: ModelStore, capacity_pages: int,
                 policy: str = "optimized_mru",
                 storage: Optional[StorageModel] = None):
        self.store = store
        self.pool: BufferPool = store.make_buffer_pool(capacity_pages, policy)
        self.storage = storage or StorageModel("ssd")
        bh, bw = store.cfg.dedup.block_shape
        self.page_bytes = store.cfg.blocks_per_page * bh * bw * 4
        self.stats = ServeStats()
        self._page_cache: Dict[int, np.ndarray] = {}
        self._pool_arr: Optional[np.ndarray] = None

    def _pages(self) -> np.ndarray:
        if self._pool_arr is None:
            self._pool_arr = self.store.page_pool()
        return self._pool_arr

    def access_pages(self, model: str, page_ids) -> float:
        """Touch pages through the pool one at a time (serial baseline:
        every miss pays its own seek, inline); returns virtual seconds."""
        t = 0.0
        for pid in page_ids:
            hit = self.pool.access(model, pid)
            if not hit:
                t += self.storage.fetch_seconds(self.page_bytes)
                self.stats.pages_fetched += 1
        self.stats.fetch_seconds += t
        return t

    def access_pages_grouped(self, model: str, page_ids) -> float:
        """Touch pages through the pool, issuing all misses as ONE group
        fetch (single seek, pipelined transfer) — the async scheduler's
        per-batch demand fetch.  Returns the group's virtual seconds."""
        misses = 0
        for pid in page_ids:
            if not self.pool.access(model, pid):
                misses += 1
        t = self.storage.fetch_group_seconds(self.page_bytes, misses)
        self.stats.pages_fetched += misses
        self.stats.fetch_seconds += t
        return t

    def tensor_pages(self, model: str, tensor: str) -> List[int]:
        return self.store.packing.tensor_pages[(model, tensor)]

    def fetch_tensor(self, model: str, tensor: str) -> np.ndarray:
        """Access all pages of a tensor, then materialize it."""
        self.access_pages(model, self.tensor_pages(model, tensor))
        return self.store.materialize(model, tensor)

    def embedding_rows_pages(self, model: str, tensor: str,
                             rows: np.ndarray) -> List[int]:
        """Pages containing the row blocks touched by ``rows`` (the
        paper's locality win: a batch only faults its own row blocks)."""
        vt = self.store.virtual_tensor(model, tensor)
        bh = self.store.cfg.dedup.block_shape[0]
        gw = vt.grid.grid[1]
        l = self.store.cfg.blocks_per_page
        row_blocks = np.unique(rows // bh)
        logical = (row_blocks[:, None] * gw
                   + np.arange(gw)[None, :]).reshape(-1)
        slots = vt.block_map[logical]
        return sorted(set(int(s) // l for s in slots))


# ------------------------------------------------------- embedding serving --
class _PrefetchingEngine:
    """Shared scheduler-engine plumbing: the per-batch prefetch step.
    Subclasses provide ``prefetcher``, ``overlap``, ``timeline``,
    ``stats``."""

    def _maybe_prefetch(self) -> None:
        """Speculative I/O rides the fetch channel *under* compute,
        budgeted to the channel's idle headroom (compute clock minus
        fetch clock) so it never delays a demand fetch by more than one
        in-flight page transfer.  On a serial engine there is no idle
        channel to hide speculation in — every prefetched second would
        add to the makespan — so a prefetcher without ``overlap`` is
        deliberately inert."""
        if self.prefetcher is None or not self.overlap:
            return
        budget = self.timeline.compute_clock - self.timeline.fetch_clock
        if budget <= 0:
            return
        pf_t = self.prefetcher.step(budget)
        self.timeline.charge_fetch(pf_t)
        self.stats.prefetch_seconds += pf_t
        self.stats.prefetch_pages = self.prefetcher.stats.issued


class EmbeddingServingEngine(_PrefetchingEngine):
    """Paper Sec. 7.1.1/7.1.2 scenario: many embedding-model variants.

    ``scheduler``: a policy name (``fifo`` / ``round_robin`` /
    ``dedup_affinity``) or a :class:`BatchScheduler` instance.
    ``overlap=True`` switches demand fetches to grouped issue and runs
    them on the double-buffered timeline (fetch(t) ∥ compute(t-1));
    ``prefetcher`` (optional) additionally pulls hot models' pages during
    compute.  Defaults reproduce the old serial round-robin engine.
    """

    def __init__(self, server: WeightServer,
                 heads: Dict[str, np.ndarray],
                 embed_tensor: str = "embedding",
                 scheduler="round_robin",
                 prefetcher=None,
                 overlap: bool = False):
        self.server = server
        self.heads = heads
        self.embed_tensor = embed_tensor
        self.scheduler: BatchScheduler = make_scheduler(scheduler)
        self.prefetcher = prefetcher
        self.overlap = overlap
        self.timeline = FetchComputeTimeline()
        self.stats = ServeStats()

    def submit(self, model: str, docs: np.ndarray) -> None:
        """Queue a request batch; its page working set is estimated here
        (pure page-map arithmetic, no weight access) so the scheduler can
        do affinity placement without touching storage."""
        rows = np.unique(docs)
        pages = self.server.embedding_rows_pages(model, self.embed_tensor,
                                                 rows)
        self.scheduler.submit(model, docs, pages=pages)

    def _infer(self, batch: ScheduledBatch) -> np.ndarray:
        model, docs = batch.model, batch.payload
        rows = np.unique(docs)
        pages = sorted(batch.pages) if batch.pages is not None else \
            self.server.embedding_rows_pages(model, self.embed_tensor, rows)
        if self.overlap:
            fetch_t = self.server.access_pages_grouped(model, pages)
        else:
            fetch_t = self.server.access_pages(model, pages)
        t0 = time.perf_counter()
        emb_rows = self.server.store.materialize_rows(
            model, self.embed_tensor, rows)
        idx = np.searchsorted(rows, docs)
        feats = emb_rows[idx].mean(axis=1)
        logits = feats @ self.heads[model]
        compute_t = time.perf_counter() - t0

        if self.overlap:
            issue, done = self.timeline.advance(fetch_t, compute_t)
            self.stats.latencies.append(done - issue)
        else:
            # serial: fetch then compute on one channel; the timeline is
            # left untouched so makespan_seconds falls back to the sum
            self.stats.latencies.append(fetch_t + compute_t)
        self.stats.fetch_seconds += fetch_t
        self.stats.compute_seconds += compute_t
        self.stats.requests += len(docs)
        self.stats.batches += 1
        return logits.argmax(axis=1)

    def run(self, max_batches: Optional[int] = None) -> ServeStats:
        """Drain the scheduler (each queue's drain rate is the lambda_i
        feeding Eq. 2 inside the buffer pool)."""
        n = 0
        while self.scheduler.pending():
            batch = self.scheduler.next_batch(
                self.server.pool.resident_pages())
            if batch is None:
                break
            self._infer(batch)
            self._maybe_prefetch()
            n += 1
            if max_batches and n >= max_batches:
                break
        if self.overlap:
            self.stats.timeline_seconds = self.timeline.makespan
        return self.stats


# --------------------------------------------------------------- LM serving --
class LMServingEngine(_PrefetchingEngine):
    """Serve (reduced) LM variants with batched prefill/decode; weights are
    faulted in through the dedup page pool on model switch.

    ``generate`` keeps the direct call path; ``submit``/``run`` drive the
    same scheduler/timeline machinery as the embedding engine, with a
    model switch's whole page working set issued as one fetch group."""

    def __init__(self, server: WeightServer, apis: Dict[str, object],
                 params_template: Dict[str, dict],
                 scheduler="fifo", prefetcher=None, overlap: bool = False):
        self.server = server
        self.apis = apis
        self.templates = params_template     # model -> params pytree (np)
        self.scheduler: BatchScheduler = make_scheduler(scheduler)
        self.prefetcher = prefetcher
        self.overlap = overlap
        self.timeline = FetchComputeTimeline()
        self.stats = ServeStats()
        self._resident_model: Optional[str] = None
        self._params = None

    def _load_model(self, model: str, grouped: bool = False) -> float:
        """Fault the model's weights through the pool; returns the
        virtual fetch seconds (0 when already resident)."""
        if self._resident_model == model:
            return 0.0
        if grouped:
            fetch_t = self.server.access_pages_grouped(
                model, self.server.store.model_pages(model))
            tensors = {
                name: self.server.store.materialize(model, name)
                for name in self.server.store.dedup.models[model].tensors}
        else:
            t0 = self.server.stats.fetch_seconds
            tensors = {}
            for name in self.server.store.dedup.models[model].tensors:
                tensors[name] = self.server.fetch_tensor(model, name)
            fetch_t = self.server.stats.fetch_seconds - t0
        self._params = self.templates[model], tensors
        self._resident_model = model
        return fetch_t

    def _compute(self, model: str, prompts: np.ndarray, steps: int
                 ) -> Tuple[np.ndarray, float]:
        import jax.numpy as jnp
        template, tensors = self._params
        rebuild, api = template["rebuild"], self.apis[model]
        params = rebuild(tensors)
        t0 = time.perf_counter()
        logits, cache = api.prefill(params,
                                    {"tokens": jnp.asarray(prompts)},
                                    prompts.shape[1] + steps)
        out = [np.asarray(logits.argmax(-1))]
        for _ in range(steps - 1):
            logits, cache = api.decode(params, cache,
                                       jnp.asarray(out[-1]).astype("int32"))
            out.append(np.asarray(logits.argmax(-1)))
        dt = time.perf_counter() - t0
        return np.concatenate(out, axis=1), dt

    def generate(self, model: str, prompts: np.ndarray,
                 steps: int = 8) -> Tuple[np.ndarray, float]:
        self._load_model(model)
        out, dt = self._compute(model, prompts, steps)
        self.stats.compute_seconds += dt
        self.stats.latencies.append(dt)
        self.stats.requests += len(prompts)
        self.stats.batches += 1
        return out, dt

    # -- scheduler-driven serving -------------------------------------------
    def submit(self, model: str, prompts: np.ndarray, steps: int = 8) -> None:
        self.scheduler.submit(model, (prompts, steps),
                              pages=self.server.store.model_pages(model))

    def run(self, max_batches: Optional[int] = None) -> ServeStats:
        n = 0
        results = []
        while self.scheduler.pending():
            batch = self.scheduler.next_batch(
                self.server.pool.resident_pages())
            if batch is None:
                break
            prompts, steps = batch.payload
            fetch_t = self._load_model(batch.model, grouped=self.overlap)
            out, compute_t = self._compute(batch.model, prompts, steps)
            if self.overlap:
                issue, done = self.timeline.advance(fetch_t, compute_t)
                self.stats.latencies.append(done - issue)
            else:
                self.stats.latencies.append(fetch_t + compute_t)
            self.stats.fetch_seconds += fetch_t
            self.stats.compute_seconds += compute_t
            self.stats.requests += len(prompts)
            self.stats.batches += 1
            results.append(out)
            self._maybe_prefetch()
            n += 1
            if max_batches and n >= max_batches:
                break
        if self.overlap:
            self.stats.timeline_seconds = self.timeline.makespan
        return self.stats
