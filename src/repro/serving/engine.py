"""Multi-model serving engine backed by the deduplicated page store.

This is the paper's runtime loop transposed to the TPU memory hierarchy
(DESIGN.md §2): the **page store** (host DRAM / checkpoint) holds the
deduplicated pages; the **buffer pool** decides which pages are
device-resident (HBM); inference touches pages through the pool, so
shared pages hit for *every* model variant that uses them.

Components:
  * :class:`StorageModel` — virtual-clock latency model for the backing
    tier (ssd / hdd / nvme / host-dram), used when a page misses.  Group
    fetches amortize the seek across a batch's misses.
  * :class:`FetchComputeTimeline` — double-buffered virtual clock: batch
    t's group fetch occupies the storage channel while batch t-1 still
    computes, so Eq. 1/Eq. 2 hit-ratio wins translate into latency wins.
  * :class:`WeightServer` — ModelStore + BufferPool + storage sim; tracks
    per-model arrival rates (the lambda_i of Eq. 2 flow straight into the
    pool's eviction policy).  Optional hedged fetches for stragglers.
    ``backend="device"`` attaches a :class:`~repro.serving.device_pool.
    DevicePagePool`: buffer-pool loads/evicts become real host->HBM page
    transfers into a preallocated slab, and the engines compute through
    the Pallas dedup kernels against the resident slab instead of
    re-densifying weights in numpy (DESIGN.md §3).
  * :class:`EmbeddingServingEngine` — the paper's word2vec / text-
    classification scenario, now scheduler-driven: batch order is a
    policy (fifo / round_robin / dedup_affinity, see
    ``serving/scheduler.py``), and an optional λ-driven
    :class:`~repro.serving.prefetch.Prefetcher` pulls hot models' pages
    ahead of demand.
  * :class:`LMServingEngine` — serves (reduced) LM variants via
    prefill/decode with per-model weight fetch through the pool; the
    same scheduler/timeline machinery applies per model-switch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.bufferpool import BufferPool
from ..core.store import ModelStore
from ..obs import get_tracer
from ..storage.faults import StorageFaultError
from .scheduler import BatchScheduler, ScheduledBatch, make_scheduler

# ------------------------------------------------------------------ storage --
STORAGE_PRESETS = {
    # (bandwidth B/s, seek seconds)
    "hdd": (150e6, 8e-3),
    "ssd": (500e6, 1e-4),
    "nvme": (3e9, 2e-5),
    "dram": (20e9, 1e-6),
}


@dataclasses.dataclass
class StorageModel:
    """Virtual-clock latency model of the page-backing tier.

    Either a named preset (``kind`` in :data:`STORAGE_PRESETS`) or
    explicit ``bandwidth``/``seek`` parameters — typically calibrated
    from a live backend's :meth:`~repro.storage.PageBackend.microbench`
    via :meth:`from_backend`, so misses are charged what the tier
    actually costs instead of a hardcoded hdd/ssd/nvme guess.
    """
    kind: str = "ssd"
    hedge_after: Optional[float] = None    # straggler hedging deadline (s)
    jitter: float = 0.0                    # lognormal sigma for tail latency
    seed: int = 0
    bandwidth: Optional[float] = None      # B/s override (calibrated)
    seek: Optional[float] = None           # seconds override (calibrated)
    channel: str = "storage"               # named virtual-clock channel

    def __post_init__(self):
        if self.bandwidth is None or self.seek is None:
            try:
                bw, seek = STORAGE_PRESETS[self.kind]
            except KeyError:
                raise ValueError(
                    f"unknown storage kind {self.kind!r} and no explicit "
                    f"bandwidth/seek given; presets: "
                    f"{sorted(STORAGE_PRESETS)}") from None
            self.bandwidth = bw if self.bandwidth is None else self.bandwidth
            self.seek = seek if self.seek is None else self.seek
        self.bw = self.bandwidth
        self._rng = np.random.default_rng(self.seed)

    @classmethod
    def from_backend(cls, backend, page_bytes: int = 128 * 1024,
                     **kw) -> "StorageModel":
        """Calibrate from a backend microbenchmark: the returned model
        charges misses with the measured (seek, bandwidth) of the tier
        the pages actually live in."""
        prof = backend.microbench(page_bytes=page_bytes)
        return cls(kind=f"calibrated:{prof.backend}",
                   bandwidth=prof.bandwidth, seek=prof.seek, **kw)

    def _draw(self, base: float) -> float:
        if self.jitter:
            draw = base * float(self._rng.lognormal(0.0, self.jitter))
            if self.hedge_after is not None and draw > self.hedge_after:
                # hedged duplicate fetch: take min of two draws
                draw = min(draw,
                           self.hedge_after
                           + base * float(self._rng.lognormal(0.0,
                                                              self.jitter)))
            return draw
        return base

    def fetch_seconds(self, nbytes: int) -> float:
        return self._draw(self.seek + nbytes / self.bw)

    def fetch_group_seconds(self, nbytes: int, n: int) -> float:
        """Virtual time for ``n`` pages issued as ONE grouped request:
        a single seek plus pipelined transfers (the scheduler issues a
        batch's misses together instead of page-at-a-time)."""
        if n <= 0:
            return 0.0
        return self._draw(self.seek + n * nbytes / self.bw)

    def transfer_seconds(self, nbytes: int) -> float:
        """One seek-less pipelined transfer (a follow-on page inside an
        already-open group); jitter/hedging apply per transfer."""
        return self._draw(nbytes / self.bw)


@dataclasses.dataclass
class FetchComputeTimeline:
    """Two-channel virtual clock.  The fetch channel serializes storage
    traffic (demand groups + prefetches); a batch's compute starts once
    both its fetch group completed and the previous compute finished —
    i.e. fetch(t) overlaps compute(t-1), the classic double buffer."""
    fetch_clock: float = 0.0
    compute_clock: float = 0.0

    def advance(self, fetch_t: float, compute_t: float
                ) -> Tuple[float, float]:
        """Account one batch; returns (issue_time, completion_time)."""
        issue = self.fetch_clock
        self.fetch_clock += fetch_t
        start_compute = max(self.fetch_clock, self.compute_clock)
        self.compute_clock = start_compute + compute_t
        return issue, self.compute_clock

    def charge_fetch(self, seconds: float) -> None:
        """Occupy the fetch channel without a compute phase (prefetch)."""
        self.fetch_clock += seconds

    @property
    def makespan(self) -> float:
        return max(self.fetch_clock, self.compute_clock)


@dataclasses.dataclass
class ServeStats:
    """Per-engine serving counters (virtual fetch seconds, wall
    compute seconds, transfer/overlap/borrow accounting)."""
    requests: int = 0
    batches: int = 0
    fetch_seconds: float = 0.0       # virtual storage time (demand)
    compute_seconds: float = 0.0     # wall compute time
    prefetch_seconds: float = 0.0    # virtual storage time (speculative)
    pages_fetched: int = 0
    prefetch_pages: int = 0
    timeline_seconds: float = 0.0    # double-buffered makespan (async runs)
    overlapped: bool = False         # engine ran with overlap=True
    device_batches: int = 0          # batches computed against the HBM slab
    dense_fallbacks: int = 0         # device batches that fell back to host
    # -- host->HBM transfer engine (serving/transfer.py) --
    transfer_seconds: float = 0.0    # issue-side wall seconds moving pages
    #                                  host->HBM (dispatch is async)
    transfer_pages: int = 0          # pages moved
    transfer_groups: int = 0         # physical transfer operations issued
    transfer_bytes: int = 0          # bytes moved
    transfer_overlapped_bytes: int = 0   # of those: staged under compute
    group_sizes: List[float] = dataclasses.field(default_factory=list)
    # ^ per batch: pages moved / transfer ops (1.0 = per-page path)
    # -- sharded serving (serving/shard_pool.py) --
    borrow_pages: int = 0            # minority pages staged cross-shard
    borrow_seconds: float = 0.0      # virtual fetch-channel time on borrows
    borrow_mirror_hits: int = 0      # borrows served from an owner's mirror
    borrow_store_faults: int = 0     # borrows that first faulted the owner
    borrow_coalesced: int = 0        # borrows reused from a prior batch's
    #                                  staging (consecutive-batch coalescing)
    shard_batches: Dict[int, int] = dataclasses.field(default_factory=dict)
    # -- fault recovery (storage/faults.py, DESIGN.md §8) --
    retries: int = 0                 # transient backend errors retried
    corrupt_detected: int = 0        # pages failing sha256 verification
    refetch_pages: int = 0           # quarantined pages re-fetched grouped
    failovers: int = 0               # shards failed over mid-run
    degraded_batches: int = 0        # batches that degraded to the host
    #                                  path after a device-path fault
    fault_backoff_seconds: float = 0.0   # virtual clock: retry backoff +
    #                                      injected latency (its own named
    #                                      channel so BENCH stays honest)
    latencies: List[float] = dataclasses.field(default_factory=list)
    # per-batch virtual fetch-channel seconds (storage + interconnect):
    # deterministic, so placement policies compare free of wall noise
    fetch_latencies: List[float] = dataclasses.field(default_factory=list)
    # -- request-level serving (serving/frontend.py) --
    offered_requests: int = 0        # arrivals presented to the frontend
    shed_requests: int = 0           # admission-shed (never served)
    slo_misses: int = 0              # served, but past their deadline
    queue_latencies: List[float] = dataclasses.field(default_factory=list)
    # ^ per served request: arrival -> dispatch (virtual seconds)
    service_latencies: List[float] = dataclasses.field(default_factory=list)
    # ^ per served request: dispatch -> done (its batch's service time)
    request_latencies: List[float] = dataclasses.field(default_factory=list)
    # ^ per served request: arrival -> done (queue + service)
    readmitted_requests: int = 0     # re-queued by a warm restart
    # ^ queued + in-flight ids a ServingFrontend.restore put back
    #   (DESIGN.md §11): at-most-once delivery, deterministic recompute

    @property
    def total_seconds(self) -> float:
        """Serial cost: every storage second plus every compute second."""
        return self.fetch_seconds + self.prefetch_seconds \
            + self.compute_seconds

    @property
    def makespan_seconds(self) -> float:
        """End-to-end virtual time: the overlapped timeline when the
        engine ran async, the serial sum otherwise.  An overlapped run
        whose timeline never advanced is a bug in the engine loop — it
        must never be papered over with the serial sum."""
        if self.overlapped:
            if self.batches and self.timeline_seconds <= 0.0:
                raise RuntimeError(
                    "overlap=True but the fetch/compute timeline never "
                    "advanced; refusing to report the serial sum as an "
                    "overlapped makespan")
            return self.timeline_seconds
        return self.total_seconds

    @property
    def overlap_fraction(self) -> float:
        """Fraction of host->HBM bytes whose transfer was staged ahead
        of demand (issued under the previous batch's compute — the
        double-buffered path)."""
        return self.transfer_overlapped_bytes / self.transfer_bytes \
            if self.transfer_bytes else 0.0

    @property
    def mean_group_size(self) -> float:
        return float(np.mean(self.group_sizes)) if self.group_sizes else 0.0

    @property
    def goodput(self) -> float:
        """Fraction of *offered* requests served within their SLO
        (sheds and deadline misses both count against it); 0.0 before
        any request-level traffic has been offered."""
        if not self.offered_requests:
            return 0.0
        ok = len(self.request_latencies) - self.slo_misses
        return ok / self.offered_requests

    def percentile(self, p: float) -> float:
        """p-th percentile of per-batch latencies.  Raises
        ``ValueError`` when no batch has been served yet — a silent
        0.0 reads as an impossibly fast tail in reports; callers that
        want a default must guard explicitly."""
        if not self.latencies:
            raise ValueError(
                "percentile() on an empty latency list (no batches "
                "served); guard on stats.latencies for a default")
        return float(np.percentile(self.latencies, p))

    def request_percentile(self, p: float) -> float:
        """p-th percentile of per-request total latencies (frontend
        traffic); raises ``ValueError`` when no request was served."""
        if not self.request_latencies:
            raise ValueError(
                "request_percentile() on an empty request-latency list "
                "(no frontend traffic served); guard on "
                "stats.request_latencies for a default")
        return float(np.percentile(self.request_latencies, p))

    def register_into(self, registry, namespace: str = "serve") -> None:
        """Register every field as a live view in a
        :class:`~repro.obs.metrics.MetricsRegistry` (numbers become
        counters, lists histograms, dicts gauges).  Views read the
        dataclass attributes directly, so the existing attribute API
        stays the single source of truth."""
        registry.register_object(
            namespace, self, [f.name for f in dataclasses.fields(self)])


# ------------------------------------------------------------- weight serve --
class WeightServer:
    """Page-granular weight access through the dedup-aware buffer pool.

    ``backend="numpy"`` (default) keeps the pool as a policy simulator
    and materializes weights on the host.  ``backend="device"`` attaches
    a :class:`DevicePagePool`: every pool load/evict moves a real page
    into/out of a preallocated HBM slab, and the ``device_*`` accessors
    compute through the Pallas dedup kernels against that slab.
    ``kernel_mode`` is forwarded to the device pool ("auto": Pallas on
    TPU, host-mirror numpy gathers elsewhere; "pallas" forces
    interpret-mode kernels on CPU — the equivalence-test path; "xla"
    jitted XLA gathers, for GPUs.  See DevicePagePool's docstring).
    """

    TRANSFERS = ("per_page", "grouped")

    def __init__(self, store: ModelStore, capacity_pages: int,
                 policy: str = "optimized_mru",
                 storage: Optional[StorageModel] = None,
                 backend: str = "numpy", kernel_mode: str = "auto",
                 transfer: str = "grouped",
                 charge_transfer: bool = False,
                 hbm: Optional[StorageModel] = None):
        if backend not in ("numpy", "device"):
            raise ValueError(f"unknown backend {backend!r}")
        if transfer not in self.TRANSFERS:
            raise ValueError(f"unknown transfer mode {transfer!r}; "
                             f"have {self.TRANSFERS}")
        self.store = store
        self.backend = backend
        self.transfer = transfer
        self.device_pool = None
        on_load = on_evict = on_load_group = None
        if backend == "device":
            from .device_pool import DevicePagePool
            self.device_pool = DevicePagePool(store, capacity_pages,
                                              kernel_mode=kernel_mode)
            on_load = self.device_pool.load
            on_evict = self.device_pool.evict
            if transfer == "grouped":
                on_load_group = self.device_pool.load_group
        self.pool: BufferPool = store.make_buffer_pool(
            capacity_pages, policy, on_load=on_load, on_evict=on_evict,
            on_load_group=on_load_group)
        self.storage = storage or StorageModel("ssd", channel="storage")
        # Host<->HBM channel of the virtual clock.  When ``charge_
        # transfer`` is set, misses additionally pay this channel —
        # per-page seeks on the per_page path, one seek per group on the
        # grouped path — calibrated lazily from the transfer engine's
        # *measured* bandwidth unless an explicit model is given.
        self.charge_transfer = charge_transfer
        self.hbm_channel = hbm
        bh, bw = store.cfg.dedup.block_shape
        # a page's cost on the wire is its *persisted* size (fp16 stores
        # move half the bytes of fp32 ones)
        self.page_bytes = store.cfg.blocks_per_page * bh * bw \
            * store.native_page_dtype().itemsize
        self.stats = ServeStats()
        self._pool_arr: Optional[np.ndarray] = None
        self._pool_gen = store.pack_generation   # make_buffer_pool packed
        self._fault_snap = store.fault_stats.snapshot()

    def _sync_store(self) -> None:
        """Detect a repack (model registered/updated/removed since the
        last access) and drop every stale consumer: the cached host pool
        array, the pool's resident set and the device slab all refer to
        page ids from the previous packing."""
        self.store.packing                       # force repack if stale
        if self._pool_gen == self.store.pack_generation:
            return
        self.pool.invalidate_resident()          # fires on_evict -> slab
        if self.device_pool is not None:
            self.device_pool.flush()
        sharers, locality = self.store.page_metadata()
        self.pool.page_sharers = sharers
        self.pool.page_locality = locality
        self.pool.meta.clear()                   # per-page meta is stale too
        self._pool_arr = None
        self._pool_gen = self.store.pack_generation

    def _pages(self) -> np.ndarray:
        self._sync_store()
        if self._pool_arr is None:
            self._pool_arr = self.store.page_pool()
        return self._pool_arr

    def _access(self, model: str, page_ids) -> List[bool]:
        """Device backend touches a batch's pages as a pinned group so
        same-batch misses cannot tear the slab-resident working set; a
        group too large for the pool falls back to unpinned access (the
        compute path then falls back to the host)."""
        if self.backend == "device":
            try:
                return self.pool.access_group(model, page_ids)
            except ValueError:
                # group exceeds the pool: unpinned per-page access, the
                # compute path will fall back to the host
                return [self.pool.access(model, pid) for pid in page_ids]
        return [self.pool.access(model, pid) for pid in page_ids]

    def _charge_faults(self) -> float:
        """Fold the store recovery layer's work since the last fold into
        the stats; returns the virtual seconds it cost (retry backoff +
        injected latency — the ``fault`` channel of the clock, kept
        distinct from storage fetch time so BENCH numbers stay honest).
        A cursor snapshot makes each recovery event count exactly once
        no matter which access or compute path triggered it."""
        d = self.store.fault_stats.since(self._fault_snap)
        self._fault_snap = self.store.fault_stats.snapshot()
        self.stats.retries += d.retries
        self.stats.corrupt_detected += d.corrupt_detected
        self.stats.refetch_pages += d.refetch_pages
        t = d.backoff_seconds + d.latency_seconds
        self.stats.fault_backoff_seconds += t
        return t

    def _hbm(self) -> StorageModel:
        """The host<->HBM channel model, calibrated on first use from
        the transfer engine's measured group-transfer bandwidth."""
        if self.hbm_channel is None:
            if self.device_pool is not None:
                self.hbm_channel = self.device_pool.transfer.storage_model()
            else:
                self.hbm_channel = StorageModel("dram", channel="hbm")
        return self.hbm_channel

    def _charge_hbm(self, misses: int) -> float:
        """Virtual host->HBM seconds for ``misses`` pages, per the
        server's transfer mode: the per_page path pays a seek per page,
        the grouped path one seek for the whole group."""
        if not self.charge_transfer or not misses \
                or self.backend != "device":
            return 0.0
        hbm = self._hbm()
        if self.transfer == "grouped":
            return hbm.fetch_group_seconds(self.page_bytes, misses)
        # drawn per page (not misses * one draw) so a jittered channel
        # tails properly — each per-page transfer is its own sample
        return float(sum(hbm.fetch_seconds(self.page_bytes)
                         for _ in range(misses)))

    def access_pages(self, model: str, page_ids) -> float:
        """Touch pages through the pool one at a time (serial baseline:
        every miss pays its own seek, inline); returns virtual seconds."""
        self._sync_store()
        page_ids = list(page_ids)
        t = 0.0
        misses = 0
        for hit in self._access(model, page_ids):
            if not hit:
                t += self.storage.fetch_seconds(self.page_bytes)
                misses += 1
                self.stats.pages_fetched += 1
        t += self._charge_hbm(misses)
        t += self._charge_faults()
        self.stats.fetch_seconds += t
        return t

    def access_pages_grouped(self, model: str, page_ids) -> float:
        """Touch pages through the pool, issuing all misses as ONE group
        fetch (single seek, pipelined transfer) — the async scheduler's
        per-batch demand fetch.  Returns the group's virtual seconds.

        On a backend-attached store the group's not-yet-resident pages
        are faulted out of the backend in one grouped ``get_pages`` call
        *before* the pool access, so every per-page ``on_load`` (e.g. a
        device-slab transfer) hits host memory instead of issuing its
        own backend round trip."""
        self._sync_store()
        page_ids = list(page_ids)
        with get_tracer().span("fault_group", kind="storage", model=model,
                               channel_name=self.storage.channel,
                               pages=len(page_ids)) as sp:
            self.store.fault_pages(page_ids)
            misses = sum(not hit for hit in self._access(model, page_ids))
            t = self.storage.fetch_group_seconds(self.page_bytes, misses)
            t += self._charge_hbm(misses)
            t += self._charge_faults()
            sp.set(misses=misses, bytes=misses * self.page_bytes,
                   seconds=t)
        self.stats.pages_fetched += misses
        self.stats.fetch_seconds += t
        return t

    # ---------------------------------------------- transfer double buffer --
    def prestage(self, page_ids) -> None:
        """Issue the host->HBM staging transfer for ``page_ids``'s
        missing pages *now* (async), ahead of the buffer pool admitting
        them: the engines call this for the next queued batch right
        before computing the current one, so the copy overlaps compute
        (JAX async dispatch) and the eventual commit finds the bytes
        already device-side."""
        if self.device_pool is None or self.transfer != "grouped":
            return
        self._sync_store()
        self.device_pool.transfer.stage(page_ids)

    def transfer_snapshot(self) -> Optional[Dict[str, float]]:
        """Cumulative transfer-engine counters (None on the numpy
        backend); the engines diff consecutive snapshots to attribute
        movement to batches in ``ServeStats``."""
        if self.device_pool is None:
            return None
        s = self.device_pool.transfer.stats
        return {"seconds": s.seconds, "pages": s.pages, "bytes": s.bytes,
                "groups": s.groups,
                "overlapped_bytes": s.overlapped_bytes}

    def shard_resident_pages(self, shard: Optional[int] = None):
        """Resident page ids of one shard's pool — the admission
        probe's view of dedup affinity.  A single-slab server has
        exactly one 'shard'; :class:`~repro.serving.shard_pool.
        ShardedWeightServer` overrides this with the per-shard pools so
        a routed batch is scored against the residency of the shard it
        would actually land on."""
        return self.pool.resident_pages()

    def tensor_pages(self, model: str, tensor: str) -> List[int]:
        return self.store.packing.tensor_pages[(model, tensor)]

    def fetch_tensor(self, model: str, tensor: str) -> np.ndarray:
        """Access all pages of a tensor, then materialize it."""
        with get_tracer().span("fetch_tensor", kind="storage",
                               model=model, tensor=tensor):
            self.access_pages(model, self.tensor_pages(model, tensor))
            return self.store.materialize(model, tensor)

    def embedding_rows_pages(self, model: str, tensor: str,
                             rows: np.ndarray) -> List[int]:
        """Pages containing the row blocks touched by ``rows`` (the
        paper's locality win: a batch only faults its own row blocks)."""
        vt = self.store.virtual_tensor(model, tensor)
        bh = self.store.cfg.dedup.block_shape[0]
        gw = vt.grid.grid[1]
        l = self.store.cfg.blocks_per_page
        row_blocks = np.unique(rows // bh)
        logical = (row_blocks[:, None] * gw
                   + np.arange(gw)[None, :]).reshape(-1)
        slots = vt.block_map[logical]
        return sorted(set(int(s) // l for s in slots))

    # ------------------------------------------------- device (HBM) path --
    def _device_map(self, model: str, tensor: str):
        vt = self.store.virtual_tensor(model, tensor)
        dev_map = self.device_pool.remap(vt, key=(model, tensor))
        return vt, dev_map

    def device_gather_rows(self, model: str, tensor: str, rows,
                           pad: bool = False, pages=None):
        """[n, width] rows of the tensor gathered straight from the HBM
        slab via the dedup-embedding kernel path; None when the required
        pages are not resident (caller falls back to the host).

        ``pages``: the page set covering ``rows`` (what the caller just
        faulted).  When given, only those pages must be resident — the
        working set may exceed the slab as long as each batch fits; when
        omitted, the tensor's whole page set must be resident."""
        self._sync_store()
        vt = self.store.virtual_tensor(model, tensor)
        if pages is not None:
            if not self.device_pool.pages_resident(pages):
                return None
            dev_map = self.device_pool.remap(vt, key=(model, tensor),
                                             strict=False)
        else:
            dev_map = self.device_pool.remap(vt, key=(model, tensor))
            if dev_map is None:
                return None
        return self.device_pool.gather_rows(dev_map, vt.grid, rows, pad=pad)

    def device_matmul(self, model: str, tensor: str, x):
        """``x @ W_virtual`` through dedup_matmul against the slab; None
        when the tensor's pages are not all resident."""
        self._sync_store()
        vt, dev_map = self._device_map(model, tensor)
        if dev_map is None:
            return None
        return self.device_pool.virtual_matmul(dev_map, vt.grid, x)

    def device_tensor(self, model: str, tensor: str):
        """Whole tensor reassembled on device from resident slab blocks
        (LM model-switch path: no host densification); None when not all
        pages are resident."""
        self._sync_store()
        vt, dev_map = self._device_map(model, tensor)
        if dev_map is None:
            return None
        return self.device_pool.unblock(dev_map, vt.grid)


# ------------------------------------------------------- embedding serving --
def jnp_asarray(x):
    """Device-put ``x`` lazily (keeps jax imports off module load)."""
    import jax.numpy as jnp
    return jnp.asarray(x)


_TOK_LOGITS = None


def _tok_logits(emb_tokens, head):
    """Jitted mean-pool + head for the device path: one fused XLA program
    instead of separate host passes.  Built lazily so importing the
    engine never pulls in jax."""
    global _TOK_LOGITS
    if _TOK_LOGITS is None:
        import jax

        @jax.jit
        def f(emb_tokens, head):
            return emb_tokens.mean(axis=1) @ head

        _TOK_LOGITS = f
    return _TOK_LOGITS(emb_tokens, head)


class _PrefetchingEngine:
    """Shared scheduler-engine plumbing: the per-batch prefetch step,
    transfer-stat attribution, and next-batch prestaging.  Subclasses
    provide ``prefetcher``, ``overlap``, ``timeline``, ``stats``,
    ``scheduler``, ``server``."""

    def _transfer_snap(self):
        return self.server.transfer_snapshot()

    def _add_transfer_delta(self, snap) -> None:
        """Fold the transfer engine's movement since ``snap`` into the
        stats (per-batch attribution; group_sizes gets this batch's
        pages-per-operation ratio: 1.0 on the per_page path)."""
        cur = self.server.transfer_snapshot()
        if snap is None or cur is None:
            return
        d_groups = cur["groups"] - snap["groups"]
        d_pages = cur["pages"] - snap["pages"]
        self.stats.transfer_seconds += cur["seconds"] - snap["seconds"]
        self.stats.transfer_bytes += cur["bytes"] - snap["bytes"]
        self.stats.transfer_overlapped_bytes += \
            cur["overlapped_bytes"] - snap["overlapped_bytes"]
        self.stats.transfer_pages += d_pages
        self.stats.transfer_groups += d_groups
        if d_groups > 0:
            self.stats.group_sizes.append(d_pages / d_groups)

    def _prestage_next(self) -> None:
        """Double buffer: issue the NEXT queued batch's host->HBM staging
        transfer before computing the current batch, so the copy rides
        under compute (JAX async dispatch).  Approximation: the head of
        the pending queue in arrival order — exact for fifo, a best
        guess for rotating schedulers (a wrong guess only wastes one
        staging buffer, it can never corrupt residency)."""
        if not self.overlap:
            return
        gen = self.server.store.pack_generation
        for b in self.scheduler.pending_batches()[:1]:
            if b.pages is None or b.pages_gen != gen:
                continue
            self.server.prestage(sorted(b.pages))

    def _maybe_prefetch(self) -> None:
        """Speculative I/O rides the fetch channel *under* compute,
        budgeted to the channel's idle headroom (compute clock minus
        fetch clock) so it never delays a demand fetch by more than one
        in-flight page transfer.  On a serial engine there is no idle
        channel to hide speculation in — every prefetched second would
        add to the makespan — so a prefetcher without ``overlap`` is
        deliberately inert."""
        if self.prefetcher is None or not self.overlap:
            return
        budget = self.timeline.compute_clock - self.timeline.fetch_clock
        if budget <= 0:
            return
        snap = self._transfer_snap()
        pf_t = self.prefetcher.step(budget)
        self._add_transfer_delta(snap)
        self.timeline.charge_fetch(pf_t)
        self.stats.prefetch_seconds += pf_t
        self.stats.prefetch_pages = self.prefetcher.stats.issued


class EmbeddingServingEngine(_PrefetchingEngine):
    """Paper Sec. 7.1.1/7.1.2 scenario: many embedding-model variants.

    ``scheduler``: a policy name (``fifo`` / ``round_robin`` /
    ``dedup_affinity``) or a :class:`BatchScheduler` instance.
    ``overlap=True`` switches demand fetches to grouped issue and runs
    them on the double-buffered timeline (fetch(t) ∥ compute(t-1));
    ``prefetcher`` (optional) additionally pulls hot models' pages during
    compute.  Defaults reproduce the old serial round-robin engine.
    """

    def __init__(self, server: WeightServer,
                 heads: Dict[str, np.ndarray],
                 embed_tensor: str = "embedding",
                 scheduler="round_robin",
                 prefetcher=None,
                 overlap: bool = False):
        self.server = server
        self.heads = heads
        self.embed_tensor = embed_tensor
        self.scheduler: BatchScheduler = make_scheduler(scheduler)
        self.prefetcher = prefetcher
        if prefetcher is not None and hasattr(prefetcher, "attach_scheduler"):
            prefetcher.attach_scheduler(self.scheduler)
        self.overlap = overlap
        self.timeline = FetchComputeTimeline()
        self.stats = ServeStats(overlapped=overlap)
        self.last_logits: Optional[np.ndarray] = None  # test/debug hook
        self._dev_heads: Dict[str, object] = {}        # model -> jnp head

    def submit(self, model: str, docs: np.ndarray) -> None:
        """Queue a request batch; its page working set is estimated here
        (pure page-map arithmetic, no weight access) so the scheduler can
        do affinity placement without touching storage.  On a sharded
        server the router's placement decision rides along too (advisory:
        the server re-routes at run time, identically unless a repack
        intervened)."""
        rows = np.unique(docs)
        pages = self.server.embedding_rows_pages(model, self.embed_tensor,
                                                 rows)
        router = getattr(self.server, "router", None)
        shard = router.route(pages, record=False).shard \
            if router is not None else None
        self.scheduler.submit(model, docs, pages=pages,
                              pages_gen=self.server.store.pack_generation,
                              shard=shard)

    def _head_dev(self, model: str):
        head = self._dev_heads.get(model)
        if head is None:
            head = self._dev_heads[model] = jnp_asarray(self.heads[model])
        return head

    def _infer(self, batch: ScheduledBatch) -> np.ndarray:
        model, docs = batch.model, batch.payload
        # Page ids cached at submit() die with the packing they were
        # minted under: recompute after any repack (model update between
        # submit and run) instead of faulting ids that now name other
        # bytes — or nothing.  The generation travels on the batch, so
        # later submits can't alias an older batch's ids as current.
        if batch.pages is not None and batch.pages_gen is not None \
                and self.server.store.packing_current(batch.pages_gen):
            pages = sorted(batch.pages)
        else:
            pages = self.server.embedding_rows_pages(
                model, self.embed_tensor, np.unique(docs))
        snap = self._transfer_snap()
        degraded = False
        tr = get_tracer()
        with tr.span("fetch", kind="engine", model=model,
                     pages=len(pages)) as fsp:
            try:
                if self.overlap:
                    fetch_t = self.server.access_pages_grouped(model, pages)
                else:
                    fetch_t = self.server.access_pages(model, pages)
            except StorageFaultError:
                # device-path access failed past its retry budget: degrade
                # this batch to the host backend (the materialize path below
                # retries with a fresh budget) instead of aborting the run
                degraded = True
                self.stats.degraded_batches += 1
                fetch_t = self.server._charge_faults()
            fsp.set(seconds=fetch_t, degraded=degraded)
        if self.prefetcher is not None:
            self.prefetcher.note_demand(pages)     # lookahead hit accounting
        # double buffer: next batch's host->HBM copy issues now, rides
        # under this batch's compute (async dispatch), commits next turn
        self._prestage_next()
        t0 = time.perf_counter()
        logits = None
        with tr.span("compute", kind="engine", model=model,
                     rows=int(docs.size)) as csp:
            if self.server.backend == "device" and not degraded:
                # Hot path: the batch's token rows come straight off the
                # resident slab through the dedup kernel path — no unique/
                # scatter bookkeeping, no host materialization of any weight.
                flat = docs.reshape(-1)
                try:
                    emb = self.server.device_gather_rows(
                        model, self.embed_tensor, flat, pad=True,
                        pages=pages)
                except StorageFaultError:
                    emb = None
                    self.stats.degraded_batches += 1
                if emb is None:
                    self.stats.dense_fallbacks += 1
                else:
                    emb = emb[:flat.size].reshape(docs.shape
                                                  + (emb.shape[-1],))
                    if isinstance(emb, np.ndarray):
                        logits = emb.mean(axis=1) @ self.heads[model]
                    else:
                        # repro: allow-host (batch boundary: logits leave)
                        logits = np.asarray(_tok_logits(
                            emb, self._head_dev(model)))
                    self.stats.device_batches += 1
            csp.set(device=logits is not None)
            if logits is None:
                rows = np.unique(docs)
                emb_rows = self.server.store.materialize_rows(
                    model, self.embed_tensor, rows)
                idx = np.searchsorted(rows, docs)
                feats = emb_rows[idx].mean(axis=1)
                logits = feats @ self.heads[model]
        compute_t = time.perf_counter() - t0
        # recovery work triggered by compute-side materialization (host
        # fallback re-faulting pages) is charged here, not lost
        fetch_t += self.server._charge_faults()
        self.last_logits = logits
        self._add_transfer_delta(snap)

        if self.overlap:
            issue, done = self.timeline.advance(fetch_t, compute_t)
            self.stats.latencies.append(done - issue)
            self.stats.timeline_seconds = self.timeline.makespan
        else:
            # serial: fetch then compute on one channel; the timeline is
            # left untouched so makespan_seconds falls back to the sum
            self.stats.latencies.append(fetch_t + compute_t)
        self.stats.fetch_latencies.append(fetch_t)
        self.stats.fetch_seconds += fetch_t
        self.stats.compute_seconds += compute_t
        self.stats.requests += len(docs)
        self.stats.batches += 1
        return logits.argmax(axis=1)

    def run(self, max_batches: Optional[int] = None) -> ServeStats:
        """Drain the scheduler (each queue's drain rate is the lambda_i
        feeding Eq. 2 inside the buffer pool)."""
        tr = get_tracer()
        n = 0
        while self.scheduler.pending():
            batch = self.scheduler.next_batch(
                self.server.pool.resident_pages())
            if batch is None:
                break
            if tr.enabled:
                tr.event("schedule", kind="policy",
                         policy=self.scheduler.name, model=batch.model)
            self._infer(batch)
            self._maybe_prefetch()
            n += 1
            if max_batches and n >= max_batches:
                break
        if self.overlap:
            self.stats.timeline_seconds = self.timeline.makespan
        return self.stats


# --------------------------------------------------------------- LM serving --
class LMServingEngine(_PrefetchingEngine):
    """Serve (reduced) LM variants with batched prefill/decode; weights are
    faulted in through the dedup page pool on model switch.

    ``generate`` keeps the direct call path; ``submit``/``run`` drive the
    same scheduler/timeline machinery as the embedding engine, with a
    model switch's whole page working set issued as one fetch group."""

    def __init__(self, server: WeightServer, apis: Dict[str, object],
                 params_template: Dict[str, dict],
                 scheduler="fifo", prefetcher=None, overlap: bool = False):
        self.server = server
        self.apis = apis
        self.templates = params_template     # model -> params pytree (np)
        self.scheduler: BatchScheduler = make_scheduler(scheduler)
        self.prefetcher = prefetcher
        if prefetcher is not None and hasattr(prefetcher, "attach_scheduler"):
            prefetcher.attach_scheduler(self.scheduler)
        self.overlap = overlap
        self.timeline = FetchComputeTimeline()
        self.stats = ServeStats(overlapped=overlap)
        self.last_tokens: Optional[np.ndarray] = None  # test/frontend hook
        self._resident_model: Optional[str] = None
        self._params = None
        self._params_gen = -1          # packing generation of _params

    def _load_model(self, model: str, grouped: bool = False) -> float:
        """Fault the model's weights through the pool; returns the
        virtual fetch seconds (0 when already resident).

        On the device backend the model switch never densifies on the
        host: the page working set is faulted into the HBM slab and each
        tensor is reassembled *on device* from resident slab blocks
        (``WeightServer.device_tensor``).  Falls back to host
        materialization only if the slab cannot hold the working set."""
        if self._resident_model == model and \
                self.server.store.packing_current(self._params_gen):
            return 0.0
        names = list(self.server.store.dedup.models[model].tensors)
        with get_tracer().span("model_switch", kind="engine",
                               model=model, grouped=grouped) as sp:
            if self.server.backend == "device":
                pages = self.server.store.model_pages(model)
                try:
                    if grouped:
                        fetch_t = self.server.access_pages_grouped(model,
                                                                   pages)
                    else:
                        fetch_t = self.server.access_pages(model, pages)
                    tensors = {}
                    for name in names:
                        dt = self.server.device_tensor(model, name)
                        if dt is None:
                            tensors = None
                            break
                        tensors[name] = dt
                except StorageFaultError:
                    # device-path switch failed past its retry budget:
                    # degrade this model switch to host materialization
                    # (fresh retry budget) instead of aborting the run
                    self.stats.degraded_batches += 1
                    fetch_t = self.server._charge_faults()
                    tensors = None
                if tensors is None:
                    self.stats.dense_fallbacks += 1
                    tensors = {name: self.server.store.materialize(model,
                                                                   name)
                               for name in names}
                    fetch_t += self.server._charge_faults()
                else:
                    self.stats.device_batches += 1
            elif grouped:
                fetch_t = self.server.access_pages_grouped(
                    model, self.server.store.model_pages(model))
                tensors = {name: self.server.store.materialize(model, name)
                           for name in names}
            else:
                t0 = self.server.stats.fetch_seconds
                tensors = {}
                for name in names:
                    tensors[name] = self.server.fetch_tensor(model, name)
                fetch_t = self.server.stats.fetch_seconds - t0
            sp.set(seconds=fetch_t, tensors=len(names))
        self._params = self.templates[model], tensors
        self._resident_model = model
        self._params_gen = self.server.store.pack_generation
        return fetch_t

    def _compute(self, model: str, prompts: np.ndarray, steps: int
                 ) -> Tuple[np.ndarray, float]:
        import jax.numpy as jnp
        template, tensors = self._params
        rebuild, api = template["rebuild"], self.apis[model]
        params = rebuild(tensors)
        t0 = time.perf_counter()
        logits, cache = api.prefill(params,
                                    {"tokens": jnp.asarray(prompts)},
                                    prompts.shape[1] + steps)
        # decode loop feeds tokens back through host; real serving
        # would keep them on device (ROADMAP)  # repro: allow-host
        out = [np.asarray(logits.argmax(-1))]
        for _ in range(steps - 1):
            logits, cache = api.decode(params, cache,
                                       jnp.asarray(out[-1]).astype("int32"))
            out.append(np.asarray(logits.argmax(-1)))  # repro: allow-host
        dt = time.perf_counter() - t0
        return np.concatenate(out, axis=1), dt

    def generate(self, model: str, prompts: np.ndarray,
                 steps: int = 8) -> Tuple[np.ndarray, float]:
        snap = self._transfer_snap()
        fetch_t = self._load_model(model)
        out, dt = self._compute(model, prompts, steps)
        self.last_tokens = out
        self._add_transfer_delta(snap)
        if self.overlap:
            # keep the timeline live on the direct call path too, so
            # makespan_seconds stays well-defined for overlap engines
            self.timeline.advance(fetch_t, dt)
            self.stats.timeline_seconds = self.timeline.makespan
        self.stats.compute_seconds += dt
        self.stats.latencies.append(dt)
        self.stats.requests += len(prompts)
        self.stats.batches += 1
        return out, dt

    # -- scheduler-driven serving -------------------------------------------
    def submit(self, model: str, prompts: np.ndarray, steps: int = 8) -> None:
        pages = self.server.store.model_pages(model)
        router = getattr(self.server, "router", None)
        shard = router.route(pages, record=False).shard \
            if router is not None else None
        self.scheduler.submit(model, (prompts, steps), pages=pages,
                              pages_gen=self.server.store.pack_generation,
                              shard=shard)

    def run(self, max_batches: Optional[int] = None) -> ServeStats:
        tr = get_tracer()
        n = 0
        results = []
        while self.scheduler.pending():
            batch = self.scheduler.next_batch(
                self.server.pool.resident_pages())
            if batch is None:
                break
            if tr.enabled:
                tr.event("schedule", kind="policy",
                         policy=self.scheduler.name, model=batch.model)
            prompts, steps = batch.payload
            snap = self._transfer_snap()
            fetch_t = self._load_model(batch.model, grouped=self.overlap)
            if self.prefetcher is not None:
                self.prefetcher.note_demand(
                    self.server.store.model_pages(batch.model))
            self._prestage_next()       # next model's pages ∥ this compute
            out, compute_t = self._compute(batch.model, prompts, steps)
            self.last_tokens = out
            self._add_transfer_delta(snap)
            if self.overlap:
                issue, done = self.timeline.advance(fetch_t, compute_t)
                self.stats.latencies.append(done - issue)
                self.stats.timeline_seconds = self.timeline.makespan
            else:
                self.stats.latencies.append(fetch_t + compute_t)
            self.stats.fetch_latencies.append(fetch_t)
            self.stats.fetch_seconds += fetch_t
            self.stats.compute_seconds += compute_t
            self.stats.requests += len(prompts)
            self.stats.batches += 1
            results.append(out)
            self._maybe_prefetch()
            n += 1
            if max_batches and n >= max_batches:
                break
        if self.overlap:
            self.stats.timeline_seconds = self.timeline.makespan
        return self.stats
