"""PagedKVCache: block-table round-trips, free-list conservation, and
regressions for the duplicate-allocate and extend-rollback bugs."""
import pytest

from repro.serving.kvcache import BlockTable, PagedKVCache


def test_allocate_round_trip():
    kv = PagedKVCache(num_blocks=8, block_size=4)
    t = kv.allocate("r0", tokens=10)             # ceil(10/4) = 3 blocks
    assert isinstance(t, BlockTable)
    assert len(t.blocks) == 3 and t.length == 10
    assert kv.used_blocks == 3 and len(kv.free) == 5
    kv.release("r0")
    assert kv.used_blocks == 0 and len(kv.free) == 8


def test_free_list_reuse_and_conservation():
    kv = PagedKVCache(num_blocks=4, block_size=2)
    a = kv.allocate("a", tokens=4)
    held = list(a.blocks)
    kv.release("a")
    b = kv.allocate("b", tokens=4)
    # LIFO free list: the released blocks are handed right back
    assert set(b.blocks) == set(held)
    kv.release("b")
    # conservation: every block accounted for, no duplicates minted
    assert sorted(kv.free) == list(range(4))


def test_block_table_positions_round_trip():
    kv = PagedKVCache(num_blocks=8, block_size=4)
    t = kv.allocate("r", tokens=9)
    slots = [kv.position_to_slot("r", p) for p in range(9)]
    assert len(set(slots)) == 9                  # distinct physical slots
    for p in range(9):
        blk = t.blocks[p // 4]
        assert slots[p] == blk * 4 + p % 4


def test_can_allocate_and_exhaustion():
    kv = PagedKVCache(num_blocks=2, block_size=4)
    assert kv.can_allocate(8) and not kv.can_allocate(9)
    kv.allocate("r", tokens=8)
    with pytest.raises(MemoryError):
        kv.allocate("s", tokens=1)
    assert "s" not in kv.tables                  # failed alloc left no table


def test_extend_grows_by_block():
    kv = PagedKVCache(num_blocks=4, block_size=2)
    t = kv.allocate("r", tokens=2)
    assert len(t.blocks) == 1
    kv.extend("r", 1)                            # 3 tokens -> 2 blocks
    assert len(t.blocks) == 2 and t.length == 3
    kv.extend("r", 1)                            # 4 tokens still 2 blocks
    assert len(t.blocks) == 2


def test_peak_used_tracks_high_water():
    kv = PagedKVCache(num_blocks=8, block_size=2)
    kv.allocate("a", tokens=6)                   # 3 blocks
    kv.allocate("b", tokens=4)                   # +2 = 5
    kv.release("a")
    kv.allocate("c", tokens=2)                   # 3 resident, peak stays 5
    assert kv.peak_used == 5


def test_duplicate_allocate_rejected():
    """Regression: re-allocating an id used to orphan the old table's
    blocks (they never returned to the free list)."""
    kv = PagedKVCache(num_blocks=4, block_size=2)
    kv.allocate("r", tokens=4)
    with pytest.raises(ValueError, match="already has a block table"):
        kv.allocate("r", tokens=2)
    kv.release("r")
    assert sorted(kv.free) == list(range(4))     # nothing leaked


def test_extend_rollback_on_exhaustion():
    """Regression: a failed extend used to leave ``length`` claiming
    positions no block covers and leak the partially-appended blocks."""
    kv = PagedKVCache(num_blocks=2, block_size=2)
    t = kv.allocate("r", tokens=4)               # pool fully used
    with pytest.raises(MemoryError):
        kv.extend("r", new_tokens=8)
    assert t.length == 4 and len(t.blocks) == 2  # state rolled back
    assert kv.used_blocks == 2 and kv.free == []
    # the table still works: every covered position resolves
    assert {kv.position_to_slot("r", p) for p in range(4)} == set(range(4))
    kv.release("r")
    assert sorted(kv.free) == list(range(2))     # no block leaked
