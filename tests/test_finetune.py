import numpy as np

from repro.core.dedup import DedupConfig, Deduplicator
from repro.core.finetune import (apply_masks, gradient_mask, gradient_masks,
                                 private_block_mask)
from repro.core.lsh import LSHConfig


def _dedup_pair():
    cfg = DedupConfig(block_shape=(8, 8),
                      lsh=LSHConfig(num_bands=8, rows_per_band=2, r=8.0,
                                    collision_threshold=6),
                      validate=False)
    d = Deduplicator(cfg)
    rng = np.random.default_rng(0)
    base = rng.standard_normal((32, 32)).astype(np.float32)
    var = base.copy()
    var[:8, :8] += 5.0                      # one clearly-private block
    d.add_model("base", {"w": base})
    d.add_model("var", {"w": var})
    return d, base, var


def test_private_mask_marks_only_private_blocks():
    d, base, var = _dedup_pair()
    mask = private_block_mask(d, "var", "w")
    bm = d.models["var"].tensors["w"].block_map
    for bid, m in enumerate(mask):
        owners = d.owners[int(bm[bid])]
        models = {mm for (mm, _t) in owners}
        assert (m == 1.0) == (models == {"var"})


def test_gradient_mask_freezes_shared_blocks():
    d, base, var = _dedup_pair()
    gm = gradient_mask(d, "var", "w")
    assert gm.shape == (32, 32)
    # the perturbed block is private -> trainable
    assert gm[:8, :8].min() == 1.0
    # shared blocks frozen
    assert gm.mean() < 1.0
    grads = {"w": np.ones((32, 32), np.float32)}
    masked = apply_masks(grads, gradient_masks(d, "var"))
    assert np.array_equal(masked["w"], gm)


def test_finetune_preserves_shared_pages():
    """Simulated fine-tune: masked updates leave shared blocks bit-equal."""
    d, base, var = _dedup_pair()
    gm = gradient_mask(d, "var", "w")
    current = d.materialize("var", "w")
    updated = current - 0.1 * gm * np.ones_like(current)
    # shared regions unchanged
    assert np.array_equal(updated[gm == 0], current[gm == 0])
    assert not np.array_equal(updated[gm == 1], current[gm == 1])
