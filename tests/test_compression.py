import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (compress_with_feedback,
                                           dequantize_leaf,
                                           init_error_state, quantize_leaf)


def test_quantize_roundtrip_bounded():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, s = quantize_leaf(g)
    deq = dequantize_leaf(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.abs(deq - g).max()) <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_time():
    """With a constant gradient, EF-compressed updates average to the true
    gradient (error does not accumulate unboundedly)."""
    g = {"w": jnp.asarray([0.003, -1.0, 0.49], jnp.float32)}
    err = init_error_state(g)
    total = jnp.zeros(3)
    n = 50
    for _ in range(n):
        deq, err = compress_with_feedback(g, err)
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]),
                               atol=1e-3)


def test_compressed_training_converges():
    from repro.optim import adamw
    target = jnp.asarray(np.random.default_rng(1)
                         .standard_normal((6, 6)), jnp.float32)
    params = {"w": jnp.zeros((6, 6))}
    opt = adamw(lr=5e-2)
    state = opt.init(params)
    err = init_error_state(params)
    losses = []
    for _ in range(80):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        grads, err = compress_with_feedback(grads, err)
        params, state, _ = opt.update(grads, state, params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.1
