import numpy as np
import pytest

from repro.core.dedup import (DedupConfig, Deduplicator, exact_dedup,
                              minhash_dedup, pairwise_dedup)
from repro.core.lsh import LSHConfig


def _cfg(**kw):
    base = dict(
        block_shape=(8, 8),
        lsh=LSHConfig(num_bands=8, rows_per_band=2, r=1.0,
                      collision_threshold=6, seed=0),
        validate_every_k=4,
        accuracy_drop_threshold=0.1,
        validate=False,
    )
    base.update(kw)
    return DedupConfig(**base)


def _model(seed, shape=(32, 32), scale=1.0):
    return {"w": (np.random.default_rng(seed)
                  .standard_normal(shape) * scale).astype(np.float32)}


def test_identical_models_fully_dedup():
    d = Deduplicator(_cfg())
    m = _model(0)
    r1 = d.add_model("m1", m)
    r2 = d.add_model("m2", dict(m))
    assert r2.deduped_blocks == r2.total_blocks
    assert d.num_distinct == r1.total_blocks - r1.deduped_blocks
    assert np.allclose(d.materialize("m2", "w"), m["w"])


def test_mapping_is_total_partition():
    """Every logical block maps to exactly one distinct block (Sec. 4.1
    conditions 1-2)."""
    d = Deduplicator(_cfg())
    d.add_model("a", _model(1))
    d.add_model("b", _model(2))
    for m in ("a", "b"):
        bm = d.models[m].tensors["w"].block_map
        assert (bm >= 0).all()
        for did in bm:
            assert d.distinct[int(did)] is not None


def test_accuracy_guard_stops_dedup():
    """Mock evaluator that tanks when any block changes: Alg. 1 must stop
    and keep remaining blocks distinct."""
    base = _model(3, shape=(64, 64))
    var = {"w": base["w"] + 1e-3}

    def evaluator(tensors):
        # accuracy tanks the moment any block is replaced by base's rep
        return 1.0 if np.allclose(tensors["w"], var["w"], atol=1e-4) \
            else 0.0

    d = Deduplicator(_cfg(validate=True, validate_every_k=2,
                          accuracy_drop_threshold=0.05,
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=50.0, collision_threshold=1)))
    d.add_model("base", base, evaluator=lambda t: 1.0)
    # near-duplicate model; huge r + low threshold force aggressive matching
    r = d.add_model("var", var, evaluator=evaluator)
    assert r.stopped
    # after stopping, remaining blocks are distinct (not replaced)
    rec = d.materialize("var", "w")
    n_changed = (np.abs(rec - var["w"]) > 1e-6).sum()
    assert n_changed < rec.size            # some blocks kept private


def test_accuracy_tolerant_evaluator_allows_full_dedup():
    base = _model(4)
    d = Deduplicator(_cfg(validate=True,
                          accuracy_drop_threshold=0.5,
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=50.0, collision_threshold=1)))
    d.add_model("base", base, evaluator=lambda t: 1.0)
    r = d.add_model("var", {"w": base["w"] + 1e-3},
                    evaluator=lambda t: 1.0)
    assert not r.stopped
    assert r.deduped_blocks == r.total_blocks


def test_remove_model_releases_blocks():
    d = Deduplicator(_cfg())
    d.add_model("a", _model(5))
    n_after_a = d.num_distinct
    d.add_model("b", _model(6))
    d.remove_model("b")
    assert d.num_distinct == n_after_a
    assert "b" not in d.models


def test_update_model_approaches_agree():
    base = _model(7)
    for approach in (1, 2):
        d = Deduplicator(_cfg())
        d.add_model("m", base)
        updated = {"w": base["w"] + 0.5}
        d.update_model("m", updated, approach=approach)
        assert np.allclose(d.materialize("m", "w"), updated["w"], atol=1e-5)


def test_owners_track_sharing():
    d = Deduplicator(_cfg())
    m = _model(8)
    d.add_model("a", m)
    d.add_model("b", dict(m))
    owners = d.block_owners()
    shared = [o for o in owners.values() if len(o) > 1]
    assert shared, "identical models must share distinct blocks"


# ------------------------------------------------------------ baselines ---
def test_exact_dedup_only_exact():
    rng = np.random.default_rng(9)
    a = rng.standard_normal((4, 4)).astype(np.float32)
    blocks = np.stack([a, a.copy(), a + 1e-6])
    bmap, n, _ = exact_dedup(blocks)
    assert n == 2
    assert bmap[0] == bmap[1] != bmap[2]


def test_pairwise_dedup_threshold():
    rng = np.random.default_rng(10)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    blocks = np.stack([a, a + 1e-4, a + 10.0])
    bmap, n, _ = pairwise_dedup(blocks, dist_threshold=0.1)
    assert n == 2
    assert bmap[0] == bmap[1] != bmap[2]


def test_minhash_dedup_runs():
    rng = np.random.default_rng(11)
    a = rng.standard_normal((4, 4)).astype(np.float32)
    blocks = np.stack([a, a.copy(), rng.standard_normal((4, 4)) * 5])
    bmap, n, dt = minhash_dedup(blocks, num_perm=8)
    assert bmap[0] == bmap[1]
    assert n <= 3 and dt >= 0
