"""End-to-end behaviour tests for the paper's system: the full Fig.-3
pipeline (dedup detection -> page packing -> caching) with real accuracy
signals, plus the validation-based Alg. 1 on a live classifier."""
import numpy as np
import pytest

from repro.core import (DedupConfig, LSHConfig, ModelStore, StoreConfig,
                        check_coverage)
from repro.core.lsh import estimate_r
from repro.data.pipeline import SyntheticTextTask


def _task_store(num_models=4, validate=False, threshold=8, seed=0,
                drop_t=0.035):
    task = SyntheticTextTask(vocab=1024, d=32, seed=seed)
    from repro.core.blocks import block_tensor
    blocks, _ = block_tensor(task.base_embed, (32, 32))
    r = estimate_r(blocks, quantile=0.5)
    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(32, 32),
                          lsh=LSHConfig(num_bands=16, rows_per_band=4,
                                        r=r, collision_threshold=threshold),
                          validate=validate, validate_every_k=8,
                          accuracy_drop_threshold=drop_t),
        blocks_per_page=4))
    heads, evals = {}, {}
    for v in range(num_models):
        name = f"v{v}"
        emb = task.variant_embedding(v)
        head = task.train_head(emb, variant=v)
        docs, labels = task.sample(256, variant=v, seed=seed + 31 + v)
        heads[name] = head

        def make_eval(head=head, docs=docs, labels=labels):
            return lambda tensors: task.accuracy(tensors["embedding"],
                                                 head, docs, labels)
        evals[name] = make_eval()
        store.register(name, {"embedding": emb},
                       evaluator=evals[name] if validate else None)
    return task, store, heads, evals


def test_full_pipeline_no_validation():
    task, store, heads, evals = _task_store(validate=False)
    pk = store.repack()
    check_coverage(pk, store.dedup.tensor_sets(), 4)
    assert store.storage_bytes() < store.dense_bytes()
    # every model's accuracy within the paper's 3.5% budget
    for name, ev in evals.items():
        acc = ev({"embedding": store.materialize(name, "embedding")})
        emb = task.variant_embedding(int(name[1:]))
        acc0 = ev({"embedding": emb})
        assert acc0 - acc < 0.035, (name, acc0, acc)


def test_full_pipeline_with_periodic_validation():
    """Alg. 1 with a live evaluator: accuracy drop bounded by construction
    (up to one k-batch of slack, no rollback — Sec. 7.3)."""
    task, store, heads, evals = _task_store(validate=True, threshold=4,
                                            drop_t=0.05)
    for name, ev in evals.items():
        res = store.dedup.models[name]
        if res.accuracy_before is not None and res.accuracy_after is not None:
            # stopped models keep remaining blocks distinct; the recorded
            # drop may exceed t by at most the last k-batch before the stop
            assert res.accuracy_before - res.accuracy_after < 0.05 + 0.1


def test_validation_stops_limit_dedup():
    """A stricter accuracy budget must never dedup *more* blocks."""
    _, strict, _, _ = _task_store(validate=True, threshold=2, drop_t=0.001,
                                  seed=3)
    _, loose, _, _ = _task_store(validate=True, threshold=2, drop_t=0.5,
                                 seed=3)
    d_strict = sum(m.deduped_blocks for m in strict.dedup.models.values())
    d_loose = sum(m.deduped_blocks for m in loose.dedup.models.values())
    assert d_strict <= d_loose


def test_more_models_better_amortization():
    """Storage per model shrinks as more similar variants register."""
    _, s2, _, _ = _task_store(num_models=2, seed=5)
    _, s6, _, _ = _task_store(num_models=6, seed=5)
    per2 = s2.storage_bytes() / 2
    per6 = s6.storage_bytes() / 6
    assert per6 < per2


def test_compression_composition_table9():
    """Dedup composes with pruning/quantization (Sec. 7.6.2)."""
    from repro.core.compress import prune_model, quantize_model
    task, store, heads, evals = _task_store(num_models=3, seed=7)
    base_pages = store.num_pages()

    store_q = ModelStore(store.cfg)
    for v in range(3):
        emb = quantize_model({"embedding": task.variant_embedding(v)})
        store_q.register(f"v{v}", emb)
    # quantization snaps values -> dedup keeps working
    assert store_q.num_pages() <= base_pages * 1.2

    store_p = ModelStore(store.cfg)
    for v in range(3):
        emb = prune_model({"embedding": task.variant_embedding(v)}, 0.5)
        store_p.register(f"v{v}", emb)
    assert store_p.num_pages() <= base_pages * 1.2
