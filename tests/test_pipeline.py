"""Pipeline-parallel (pod axis) schedule test: spawns the module's
self-check on 8 host devices (main process keeps 1 device)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_pipeline_self_check():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_PP_DEVICES"] = "8"
    r = subprocess.run([sys.executable, "-m", "repro.distributed.pipeline"],
                       capture_output=True, text=True, env=env, timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ok" in r.stdout
