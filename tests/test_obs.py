"""Observability stack tests (obs/ + the instrumented request path):
tracer semantics, the EXACT per-channel conservation invariant through
real frontend runs (1 and 2 shards), request-span stage identities,
ring retention, exporters + trace_report, the metrics registry, and
the zero-perturbation guarantee — logits and bench-style JSON are
bit-identical with tracing on vs off.
"""
import dataclasses
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.launch.serve import REPORT_FIELDS, build_store
from repro.obs import (NULL_TRACER, MetricsRegistry, Tracer, get_tracer,
                       to_chrome_trace, use_tracer,
                       validate_chrome_trace, write_trace)
from repro.obs.export import load_trace
from repro.data.pipeline import SyntheticTextTask
from repro.serving import (BatchComputeModel, EmbeddingServingEngine,
                           OpenLoopTraffic, ServeStats, ServingFrontend,
                           ShardedWeightServer, StorageModel,
                           VirtualClock, WeightServer)
from repro.storage.faults import RecoveryStats

ROOT = Path(__file__).resolve().parent.parent


def _scenario(vocab=512, d=32, num_models=3, block=(32, 32), l=4, seed=0):
    task = SyntheticTextTask(vocab=vocab, d=d, seed=seed)
    store, heads = build_store(task, num_models=num_models,
                               block_shape=block, blocks_per_page=l)
    return task, store, heads


def _doc_payload(task, docs_per_req=3, seed_base=700):
    def payload(model, rid, rng):
        v = int(model.rsplit("-v", 1)[1])
        docs, _ = task.sample(docs_per_req, variant=v,
                              seed=seed_base + rid)
        return docs
    return payload


def _frontend(task, store, heads, shards=1):
    if shards == 1:
        server = WeightServer(store, max(2, store.num_pages() // 2),
                              storage=StorageModel("dram"))
    else:
        server = ShardedWeightServer(store,
                                     max(4, store.num_pages() - 2),
                                     storage=StorageModel("dram"),
                                     shards=shards, placement="sharers")
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo")
    return ServingFrontend(engine, max_batch=4,
                           compute_model=BatchComputeModel())


def _traced_run(shards=1, n=40, rate=400.0, tracer=None):
    task, store, heads = _scenario(num_models=3)
    fe = _frontend(task, store, heads, shards=shards)
    gen = OpenLoopTraffic([f"word2vec-v{v}" for v in range(3)],
                          rate=rate, zipf_alpha=1.1, slo_s=0.5, seed=5,
                          payload_fn=_doc_payload(task))
    if tracer is None:
        tracer = Tracer(clock=fe.clock)
    with use_tracer(tracer):
        st = fe.run(gen.generate(n))
    return fe, st, tracer


# ------------------------------------------------------------ tracer core --
def test_null_tracer_is_default_and_allocates_nothing():
    tr = get_tracer()
    assert tr is NULL_TRACER and tr.enabled is False
    h1, h2 = tr.span("a"), tr.span("b", kind="x", pages=3)
    assert h1 is h2                            # one shared handle
    with h1 as sp:
        assert sp.set(bytes=1) is sp           # shared inert span
    assert tr.spans() == [] and tr.emit("r", 0.0, 1.0) is None


def test_span_channel_and_charge_must_travel_together():
    tr = Tracer()
    with pytest.raises(ValueError):
        tr.span("fetch", channel="storage")
    with pytest.raises(ValueError):
        tr.span("fetch", charge=0.1)


def test_span_nesting_parents_and_out_of_order_close():
    tr = Tracer()
    with tr.span("outer") as o:
        with tr.span("inner") as i:
            assert i.parent == o.sid
        assert tr.open_spans() == [o]
    a, b = tr.spans()
    assert (a.name, b.name) == ("inner", "outer")   # close order
    sp = tr.span_begin("x")
    tr.span_begin("y")
    with pytest.raises(RuntimeError, match="out of order"):
        tr.span_end(sp)


def test_use_tracer_scopes_and_restores():
    tr = Tracer()
    assert get_tracer() is NULL_TRACER
    with use_tracer(tr):
        assert get_tracer() is tr
    assert get_tracer() is NULL_TRACER


def test_charged_spans_replay_clock_accumulation_exactly():
    clk = VirtualClock()
    tr = Tracer(clock=clk)
    rng = np.random.default_rng(0)
    for _ in range(200):                 # awkward floats on purpose
        d = float(rng.random()) * 1e-3
        ch = ("storage", "compute")[int(rng.integers(2))]
        with tr.span("w", channel=ch, charge=d):
            clk.advance(d, ch)
    tr.assert_matches_clock()            # exact ==, no tolerance
    clk.advance(1e-7, "storage")         # an advance outside any span
    with pytest.raises(AssertionError, match="escaped its span"):
        tr.assert_matches_clock()


def test_assert_matches_clock_rejects_open_spans():
    clk = VirtualClock()
    tr = Tracer(clock=clk)
    tr.span_begin("left-open")
    with pytest.raises(AssertionError, match="open spans"):
        tr.assert_matches_clock()


def test_ring_retention_drops_oldest_without_breaking_anything():
    clk = VirtualClock()
    tr = Tracer(clock=clk, ring=4)
    with tr.span("outer") as outer:          # stays OPEN while ring churns
        for i in range(10):
            with tr.span(f"s{i}", channel="c", charge=0.5):
                clk.advance(0.5, "c")
    assert tr.dropped == 7                   # 11 finished - 4 retained
    kept = tr.spans()
    assert len(kept) == 4 and kept[-1] is outer
    assert [s.name for s in kept] == ["s7", "s8", "s9", "outer"]
    assert all(s.parent == outer.sid for s in kept[:-1])  # tree intact
    tr.assert_matches_clock()                # conservation survives drops
    with pytest.raises(ValueError):
        Tracer(ring=0)


def test_virtual_clock_assert_conserved_detects_leak():
    clk = VirtualClock(start=2.0)
    clk.advance(0.25, "storage")
    clk.tick_to(3.0)
    clk.assert_conserved()
    clk.now += 0.5                           # a second conjured channel-free
    with pytest.raises(AssertionError, match="leaked"):
        clk.assert_conserved()


# ----------------------------------------- conservation through the stack --
@pytest.mark.parametrize("shards", [1, 2])
def test_frontend_run_span_channels_equal_clock_exactly(shards):
    fe, st, tracer = _traced_run(shards=shards)
    assert len(st.request_latencies) > 0
    assert tracer.dropped == 0
    # every channel the clock booked, matched exactly — including idle
    assert set(fe.clock.channels) == set(tracer.channel_seconds)
    for ch in fe.clock.channels:
        assert tracer.channel_seconds[ch] == fe.clock.spent(ch)
    assert fe.clock.spent("idle") > 0.0 and fe.clock.spent("compute") > 0.0
    tracer.assert_matches_clock(fe.clock)
    fe.clock.assert_conserved()


def test_request_spans_carry_exact_stage_identities():
    fe, st, tracer = _traced_run()
    reqs = tracer.find(kind="request")
    served = [sp for sp in reqs if not sp.attrs["shed"]]
    assert len(served) == len(st.request_latencies)
    for sp in served:
        at = sp.attrs
        assert at["queue_s"] + at["service_s"] == at["latency_s"]
        assert at["fetch_s"] + at["compute_s"] == at["service_s"]
        assert sp.end_t - sp.start_t == pytest.approx(at["latency_s"])
    # trace-derived latency per rid == the stats' ledger
    assert sorted(sp.attrs["latency_s"] for sp in served) \
        == sorted(st.request_latencies)
    # span trees from deeper layers arrived too
    assert tracer.find(name="dispatch", kind="frontend")
    assert tracer.find(name="fetch", kind="engine")
    assert tracer.find(name="schedule", kind="policy")


# ---------------------------------------------------- zero perturbation --
def _bench_style_metrics(fe, st):
    """The BENCH_traffic per-pass dict shape (subset, same keys)."""
    lat = np.asarray(st.request_latencies, dtype=np.float64)
    return {
        "offered": st.offered_requests, "served": len(lat),
        "shed": st.shed_requests, "slo_misses": st.slo_misses,
        "goodput": st.goodput, "batches": st.batches,
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "hit_ratio": fe.engine.server.pool.hit_ratio,
        "clock_ms": fe.clock.now * 1e3,
    }


def test_tracing_on_vs_off_is_bit_identical():
    fe_on, st_on, _ = _traced_run()
    fe_off, st_off, _ = _traced_run(tracer=NULL_TRACER)
    # bench-style JSON: byte-identical
    assert json.dumps(_bench_style_metrics(fe_on, st_on), sort_keys=True) \
        == json.dumps(_bench_style_metrics(fe_off, st_off), sort_keys=True)
    # per-request logits: bit-identical
    assert fe_on.results.keys() == fe_off.results.keys()
    for rid in fe_on.results:
        np.testing.assert_array_equal(fe_on.results[rid],
                                      fe_off.results[rid])
    # and the virtual clocks agree to the last ulp
    assert fe_on.clock.now == fe_off.clock.now
    assert fe_on.clock.channels == fe_off.clock.channels


# -------------------------------------------------------------- exporters --
def test_chrome_trace_export_validates_and_roundtrips(tmp_path):
    fe, st, tracer = _traced_run()
    doc = to_chrome_trace(tracer, clock=fe.clock)
    assert validate_chrome_trace(doc) == []
    # conservation re-checkable from the document alone, still exact
    other = doc["otherData"]
    assert other["tracer_channel_seconds"] == other["clock_channels"]
    # one track per channel + the requests track
    names = {ev["args"]["name"] for ev in doc["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert {"channel/storage", "channel/compute", "channel/idle",
            "requests"} <= names

    cj = write_trace(str(tmp_path / "t.json"), tracer, clock=fe.clock)
    jl = write_trace(str(tmp_path / "t.jsonl"), tracer)
    from_chrome, from_jsonl = load_trace(cj), load_trace(jl)
    assert len(from_chrome) == len(from_jsonl) == len(tracer.spans())
    # request-span stage attrs survive the JSON roundtrip bit-exactly
    for spans in (from_chrome, from_jsonl):
        served = [s for s in spans if s["kind"] == "request"
                  and not s["attrs"]["shed"]]
        assert served
        for s in served:
            at = s["attrs"]
            assert at["queue_s"] + at["service_s"] == at["latency_s"]


def test_trace_report_script_passes_and_fails(tmp_path):
    fe, st, tracer = _traced_run()
    path = write_trace(str(tmp_path / "t.json"), tracer, clock=fe.clock)
    script = str(ROOT / "scripts" / "trace_report.py")
    ok = subprocess.run([sys.executable, script, path],
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert "exact identities OK" in ok.stdout
    assert "critical path" in ok.stdout
    # corrupt one stage attr -> the exact check must hard-fail
    doc = json.loads(Path(path).read_text())
    for ev in doc["traceEvents"]:
        if ev.get("cat") == "request" and not ev["args"].get("shed"):
            ev["args"]["queue_s"] += 1e-9
            break
    bad_path = tmp_path / "bad.json"
    bad_path.write_text(json.dumps(doc))
    bad = subprocess.run([sys.executable, script, str(bad_path)],
                         capture_output=True, text=True)
    assert bad.returncode == 1
    assert "queue_s+service_s != latency_s" in bad.stderr


# -------------------------------------------------------- metrics registry --
def test_metrics_registry_kinds_snapshot_and_diff():
    reg = MetricsRegistry()
    box = {"n": 0, "vals": [1.0, 2.0, 3.0], "by": {"a": 1.0}}
    reg.counter("x.n", lambda: box["n"])
    reg.histogram("x.vals", lambda: box["vals"])
    reg.gauge("x.by", lambda: box["by"])
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x.n", lambda: 0)
    with pytest.raises(ValueError, match="unknown metric kind"):
        reg.register("x.y", "meter", lambda: 0)
    before = reg.snapshot()
    assert before["x.vals"] == {"count": 3, "mean": 2.0,
                                "p50": 2.0, "p99": 3.0}
    box["n"] = 7
    box["vals"].append(9.0)
    d = reg.diff(before)
    assert d == {"x.n": 7.0}               # counters only, by delta
    assert "x.n" in reg and len(reg) == 3
    assert reg.names("histogram") == ["x.vals"]


def test_serve_and_recovery_stats_register_every_field():
    reg = MetricsRegistry()
    st, rs = ServeStats(), RecoveryStats()
    st.register_into(reg)
    rs.register_into(reg)
    for f in dataclasses.fields(ServeStats):
        assert f"serve.{f.name}" in reg
    for f in dataclasses.fields(RecoveryStats):
        assert f"recovery.{f.name}" in reg
    # kinds follow the field shapes
    assert reg.kind("serve.latencies") == "histogram"
    assert reg.kind("serve.shard_batches") == "gauge"
    assert reg.kind("serve.requests") == "counter"
    st.requests = 3
    assert reg.snapshot()["serve.requests"] == 3.0


# ------------------------------------------------------- report-line audit --
def test_every_serve_stat_has_exactly_one_report_line():
    """REPORT_FIELDS is the audit: every ServeStats field maps to
    exactly one [tag] line (dict => at most one; this pins at least
    one, and that the line actually prints the mapped key)."""
    fields = {f.name for f in dataclasses.fields(ServeStats)}
    assert set(REPORT_FIELDS) == fields
    known_tags = {"serve", "device", "transfer", "prefetch", "shards",
                  "faults", "traffic"}
    src = (ROOT / "src/repro/launch/serve.py").read_text()
    for field, (tag, key) in REPORT_FIELDS.items():
        assert tag in known_tags, field
        assert f"[{tag}]" in src, f"{field}: no [{tag}] line"
        for k in key.split("/"):
            assert k in src, f"{field}: key {k!r} not printed"
