"""Crash-point registry semantics plus the exhaustive kill-at-every-seam
sweep (DESIGN.md §11).  The sweep itself SIGKILLs one subprocess per
(seam, backend) pair and is marked slow; the registry/arming tests are
cheap and always run.
"""
import pytest

from repro.storage.crashpoints import (CrashPointReached, all_crash_points,
                                       armed, crash_point, run_sweep)


def test_registry_is_populated_at_import_time():
    reg = all_crash_points()
    assert len(reg) >= 20
    # at least one seam per durable layer, so no layer silently drops out
    prefixes = {name.split(".", 1)[0] for name in reg}
    assert {"localdir", "sqlite", "store", "recover"} <= prefixes
    assert all(desc for desc in reg.values())


def test_unregistered_crash_point_is_a_hard_error():
    with pytest.raises(RuntimeError, match="not registered"):
        crash_point("no.such.seam")
    with pytest.raises(ValueError, match="unknown crash point"):
        with armed("no.such.seam"):
            pass


def test_armed_raises_then_disarms():
    name = sorted(all_crash_points())[0]
    with armed(name, mode="raise"):
        with pytest.raises(CrashPointReached, match=name.split(".")[0]):
            crash_point(name)
    crash_point(name)                      # disarmed again: no-op


def test_armed_only_fires_on_its_own_seam():
    a, b = sorted(all_crash_points())[:2]
    with armed(a, mode="raise"):
        crash_point(b)                     # a different seam: no-op
        with pytest.raises(CrashPointReached):
            crash_point(a)


@pytest.mark.slow
def test_exhaustive_crash_sweep_recovers_every_seam(tmp_path):
    """Every registered seam is killed at least once; every kill
    recovers to a readable store with zero orphans, zero temps, an
    empty journal, and logits bit-exact against the legal golden."""
    results = run_sweep(base_dir=str(tmp_path))
    failed = [r for r in results if not r["ok"]]
    assert not failed, "\n".join(
        f"{r['seam']} ({r['kind']}): {'; '.join(r['problems'])}"
        for r in failed)
    swept = {r["seam"] for r in results if r["triggered"]}
    assert swept == set(all_crash_points()), \
        f"unreached seams: {sorted(set(all_crash_points()) - swept)}"
