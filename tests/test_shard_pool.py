"""Sharded page-pool serving: placement invariants (property tests),
routing, logit equivalence vs the single-slab device backend and the
numpy path at 1/2/4 shards (host + Pallas-interpret kernel modes), the
per-shard residency invariant under churn, borrow-protocol accounting,
and repack consistency of replicated pages after a model update."""
import numpy as np
import pytest

from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.serving.router import ShardRouter
from repro.serving.shard_pool import (PLACEMENTS, ShardedWeightServer,
                                      hash_placement, make_placement,
                                      sharers_placement)

from hypothesis_compat import given, settings, st


def _scenario(vocab=1024, d=32, num_models=4, block=(32, 32), l=4, seed=0):
    task = SyntheticTextTask(vocab=vocab, d=d, seed=seed)
    store, heads = build_store(task, num_models=num_models,
                               block_shape=block, blocks_per_page=l)
    return task, store, heads


def _run_batches(engine, task, num_models, batches=8, batch=16, seed=0):
    out = []
    for b in range(batches):
        v = b % num_models
        docs, _ = task.sample(batch, variant=v, seed=seed + 100 + b)
        engine.submit(f"word2vec-v{v}", docs)
        engine.run(max_batches=1)
        out.append(engine.last_logits.copy())
    return out


# ---------------------------------------------------- placement invariants --
def _random_sharers(rng, num_pages, num_models):
    models = [f"m{i}" for i in range(num_models)]
    out = {}
    for p in range(num_pages):
        k = int(rng.integers(1, num_models + 1))
        out[p] = frozenset(rng.choice(models, size=k, replace=False))
    return out


@pytest.mark.parametrize("policy", PLACEMENTS)
def test_placement_total_and_deterministic_randomized(policy):
    """Satellite: both policies produce a TOTAL (every page owned by >= 1
    shard, every owner in range) and DETERMINISTIC (same inputs -> same
    assignment) page->shard map, across random sharing structures."""
    rng = np.random.default_rng(0)
    for trial in range(25):
        num_pages = int(rng.integers(1, 60))
        num_shards = int(rng.integers(1, 6))
        sharers = _random_sharers(rng, num_pages, int(rng.integers(1, 7)))
        budget = int(rng.integers(0, num_pages + 1))

        def build():
            if policy == "hash":
                return hash_placement(num_pages, num_shards)
            return sharers_placement(num_pages, num_shards, sharers, budget)

        a, b = build(), build()
        assert a.owners == b.owners                       # deterministic
        assert len(a.owners) == num_pages                 # total
        for pid, owners in enumerate(a.owners):
            assert len(owners) >= 1
            assert all(0 <= s < num_shards for s in owners)
            assert sorted(set(owners)) == list(owners)    # sorted, unique
        # owned_sets are the exact inverse of owners
        for s in range(num_shards):
            assert a.owned_sets[s] == frozenset(
                p for p in range(num_pages) if s in a.owners[p])
        if policy == "hash":
            assert not a.replicated                       # single-owner
        if num_shards == 1:
            assert all(o == (0,) for o in a.owners)


@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=64),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_sharers_placement_property(num_pages, num_shards, budget, seed):
    """Property form of the same invariants + replication bound: the
    replicated set never exceeds the budget, and contains only pages
    with >= 2 sharers."""
    rng = np.random.default_rng(seed)
    sharers = _random_sharers(rng, num_pages, 4)
    pl = sharers_placement(num_pages, num_shards, sharers, budget)
    assert len(pl.owners) == num_pages
    assert all(len(o) >= 1 for o in pl.owners)
    assert len(pl.replicated) <= budget
    for p in pl.replicated:
        assert len(sharers[p]) >= 2
        assert pl.owners[p] == tuple(range(num_shards))


def test_make_placement_keys_on_pack_generation():
    _, store, _ = _scenario()
    a = make_placement("sharers", store, 2)
    assert a.pack_generation == store.pack_generation
    b = make_placement("sharers", store, 2)
    assert a.owners == b.owners


def test_unknown_placement_rejected():
    _, store, _ = _scenario()
    with pytest.raises(ValueError):
        make_placement("roulette", store, 2)
    with pytest.raises(ValueError):
        ShardedWeightServer(store, 4, shards=2, placement="roulette")


# ---------------------------------------------------------------- routing --
def test_router_majority_cover_and_split():
    _, store, _ = _scenario()
    srv = ShardedWeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"),
                              shards=2, placement="hash")
    pl = srv.sharded.placement()
    router = ShardRouter(srv.sharded.placement)
    evens = sorted(pl.owned_sets[0])[:3]
    odds = sorted(pl.owned_sets[1])[:1]
    r = router.route(evens + odds)
    assert r.shard == 0                       # majority owner wins
    assert set(r.owned) == set(evens)
    assert set(r.borrowed) == set(odds)
    # deterministic ties: equal cover -> lowest shard id
    r2 = router.route(evens[:1] + odds[:1])
    assert r2.shard == 0
    assert router.batches_per_shard[0] == 2
    assert router.borrowed_pages == len(odds) + 1


def test_submit_shard_annotation_matches_runtime_routing():
    """The advisory ``ScheduledBatch.shard`` set at submit() equals the
    shard the server actually routes to at run time (routing is
    deterministic over one placement) — and after a repack the server
    re-routes under the NEW placement instead of trusting it.

    ``balance_replicas=False``: with load balancing on, a replication-
    tied batch may legitimately move off the advisory shard as load
    accrues between submit and run (see test_transfer.py for that
    behavior); this test pins the load-oblivious deterministic mode."""
    task, store, heads = _scenario(num_models=3)
    srv = ShardedWeightServer(store, max(4, store.num_pages() // 2),
                              storage=StorageModel("dram"),
                              shards=2, placement="sharers",
                              balance_replicas=False)
    engine = EmbeddingServingEngine(srv, heads)
    for b in range(6):
        v = b % 3
        docs, _ = task.sample(16, variant=v, seed=700 + b)
        engine.submit(f"word2vec-v{v}", docs)
    for batch in engine.scheduler.pending_batches():
        assert batch.shard is not None
    while engine.scheduler.pending():
        batch = engine.scheduler.next_batch(srv.pool.resident_pages())
        advisory = batch.shard
        engine._infer(batch)
        assert srv._route.shard == advisory
    # repack: the queued advisory may be stale; execution must follow
    # the fresh placement, not the annotation
    docs, _ = task.sample(16, variant=0, seed=777)
    engine.submit("word2vec-v0", docs)
    store.update("word2vec-v0",
                 {"embedding": task.variant_embedding(0) + 0.25})
    engine.run(max_batches=1)
    assert srv._route.pack_generation == store.pack_generation
    srv.sharded.check_invariants()


# ------------------------------------------------------------- equivalence --
@pytest.mark.parametrize("kernel_mode", ["host", "pallas"])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_embedding_matches_numpy_and_single_device(shards,
                                                           kernel_mode):
    """Acceptance: sharded logits == single-slab device == numpy to 1e-5
    at 1/2/4 shards, for both placements, incl. Pallas interpret mode."""
    small = kernel_mode == "pallas"
    task, store, heads = _scenario(vocab=256 if small else 1024,
                                   num_models=3)
    n, batches, batch = 3, 4 if small else 8, 8 if small else 16
    cap = max(4, store.num_pages() // max(2, shards) + 2)

    def logits_of(server):
        engine = EmbeddingServingEngine(server, heads)
        return _run_batches(engine, task, n, batches=batches,
                            batch=batch), engine.stats

    ref, _ = logits_of(WeightServer(store, store.num_pages(),
                                    storage=StorageModel("dram"),
                                    backend="numpy"))
    dev, _ = logits_of(WeightServer(store, store.num_pages(),
                                    storage=StorageModel("dram"),
                                    backend="device",
                                    kernel_mode=kernel_mode))
    for placement in PLACEMENTS:
        srv = ShardedWeightServer(store, cap,
                                  storage=StorageModel("dram"),
                                  shards=shards, placement=placement,
                                  kernel_mode=kernel_mode)
        got, stats = logits_of(srv)
        for a, b, c in zip(ref, dev, got):
            np.testing.assert_allclose(a, c, atol=1e-5)
            np.testing.assert_allclose(b, c, atol=1e-5)
        srv.sharded.check_invariants()


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_lm_matches_numpy_and_single_device(shards):
    """Acceptance (LM engine): generate() through a sharded server ==
    numpy backend == single-slab device backend, Pallas interpret mode."""
    from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
    from repro.serving.engine import LMServingEngine

    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(16, 16),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=4))
    rng = np.random.default_rng(0)
    base = rng.standard_normal((48, 32)).astype(np.float32)
    for v in range(2):
        store.register(f"lm-v{v}", {"w": base + v * 1e-5,
                                    "b": base[:16] * 0.5 + v * 1e-5})

    class TinyApi:
        """Linear 'LM': prefill/decode are matmuls against the faulted
        tensors, so logits expose any wrong-page bytes immediately."""

        def prefill(self, params, batch, _):
            x = np.asarray(batch["tokens"], np.float32)
            h = x @ params["w"][:x.shape[-1]]
            logits = h @ params["b"][:, :h.shape[-1]].T
            return logits[:, None, :], h             # [B, 1, V], cache

        def decode(self, params, cache, toks):
            h = cache + np.asarray(toks, np.float32).mean()
            logits = h @ params["b"][:, :h.shape[-1]].T
            return logits[:, None, :], h

    def rebuild(ts):
        return {k: np.asarray(v) for k, v in ts.items()}

    apis = {m: TinyApi() for m in ("lm-v0", "lm-v1")}
    templates = {m: {"rebuild": rebuild} for m in ("lm-v0", "lm-v1")}
    prompts = rng.standard_normal((2, 48)).astype(np.float32)

    def generate(server):
        engine = LMServingEngine(server, apis, templates)
        outs = []
        for m in ("lm-v0", "lm-v1", "lm-v0"):
            out, _ = engine.generate(m, prompts, steps=3)
            outs.append(out)
        return outs, engine.stats

    ref, _ = generate(WeightServer(store, store.num_pages(),
                                   storage=StorageModel("dram"),
                                   backend="numpy"))
    dev, dstats = generate(WeightServer(store, store.num_pages(),
                                        storage=StorageModel("dram"),
                                        backend="device",
                                        kernel_mode="pallas"))
    assert dstats.dense_fallbacks == 0
    cap = max(4, store.num_pages() // max(2, shards) + 2)
    for placement in PLACEMENTS:
        srv = ShardedWeightServer(store, cap, storage=StorageModel("dram"),
                                  shards=shards, placement=placement,
                                  kernel_mode="pallas")
        got, stats = generate(srv)
        for a, b, c in zip(ref, dev, got):
            np.testing.assert_allclose(a, c, atol=1e-5)
            np.testing.assert_allclose(b, c, atol=1e-5)
        srv.sharded.check_invariants()


def test_single_shard_identical_to_device_backend():
    """shards=1 is the identity: same pool decisions (hit/miss/evict
    sequence), same slab loads, zero borrows — bit-identical serving."""
    task, store, heads = _scenario()
    cap = max(4, store.num_pages() // 2)

    def serve(server):
        engine = EmbeddingServingEngine(server, heads)
        logits = _run_batches(engine, task, 4, batches=10)
        return logits, engine.stats

    base = WeightServer(store, cap, storage=StorageModel("dram"),
                        backend="device")
    a, astats = serve(base)
    srv = ShardedWeightServer(store, cap, storage=StorageModel("dram"),
                              shards=1)
    b, bstats = serve(srv)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert (base.pool.hits, base.pool.misses, base.pool.evictions) \
        == (srv.pool.hits, srv.pool.misses, srv.pool.evictions)
    assert base.device_pool.loads == srv.device_pool.loads
    assert base.device_pool.evicts == srv.device_pool.evicts
    assert srv.stats.borrow_pages == 0
    assert astats.device_batches == bstats.device_batches


# ------------------------------------------------------ borrows / invariant --
def test_borrow_protocol_counts_and_serves_off_device():
    """hash-mod placement scatters cover sets, so multi-shard serving
    must borrow — staged from owner mirrors, never slab-resident on the
    borrower — while batches stay on the device path."""
    task, store, heads = _scenario(vocab=2048, num_models=4)
    srv = ShardedWeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"),
                              shards=2, placement="hash")
    engine = EmbeddingServingEngine(srv, heads)
    _run_batches(engine, task, 4, batches=8)
    assert srv.stats.borrow_pages > 0
    assert engine.stats.device_batches > 0
    assert srv.stats.borrow_seconds > 0.0
    assert srv.stats.borrow_mirror_hits + srv.stats.borrow_store_faults \
        == srv.stats.borrow_pages
    assert sum(srv.stats.shard_batches.values()) == 8
    srv.sharded.check_invariants()     # borrowed pages never went resident


def test_per_shard_residency_invariant_under_churn():
    """Acceptance: under random access/prefetch churn, every shard's
    slab == its pool's resident set and no page is resident on a shard
    placement didn't assign it."""
    _, store, _ = _scenario(num_models=4)
    for placement in PLACEMENTS:
        srv = ShardedWeightServer(store, max(2, store.num_pages() // 3),
                                  storage=StorageModel("dram"),
                                  shards=3, placement=placement)
        pl = srv.sharded.placement()
        rng = np.random.default_rng(0)
        models = list(store.dedup.models)
        for step in range(250):
            m = models[int(rng.integers(len(models)))]
            p = int(rng.integers(store.num_pages()))
            if rng.random() < 0.25:
                srv.pool.prefetch(m, p)
            else:
                s = pl.shards_of(p)[0]
                srv.sharded.buffer_pools[s].access(m, p)
            srv.sharded.check_invariants()
        # slab bytes match the physical pages everywhere they're resident
        for s, dev in enumerate(srv.sharded.pools):
            for pid, slot in dev.slot_of.items():
                np.testing.assert_array_equal(dev.slot_page(slot),
                                              store.page_array(pid))


def test_on_load_rejects_non_owner():
    _, store, _ = _scenario()
    srv = ShardedWeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"),
                              shards=2, placement="hash")
    pl = srv.sharded.placement()
    victim = next(p for p in range(store.num_pages())
                  if pl.shards_of(p) == (1,))
    with pytest.raises(RuntimeError):
        srv.sharded.buffer_pools[0].access("m", victim)


# ------------------------------------------------------- update / repack --
def test_update_repack_keeps_replicated_pages_consistent():
    """Satellite: after a model update() repack, placement is rebuilt
    for the new packing and every replicated page that is resident on
    several shards holds identical (current-packing) bytes on each."""
    task, store, heads = _scenario(num_models=3)
    srv = ShardedWeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"),
                              shards=2, placement="sharers")
    engine = EmbeddingServingEngine(srv, heads)
    _run_batches(engine, task, 3, batches=6)
    gen0 = store.pack_generation
    pl0 = srv.sharded.placement()

    store.update("word2vec-v0",
                 {"embedding": task.variant_embedding(0) + 0.25})
    _run_batches(engine, task, 3, batches=6, seed=50)

    assert store.pack_generation > gen0
    pl1 = srv.sharded.placement()
    assert pl1.pack_generation == store.pack_generation != pl0.pack_generation
    srv.sharded.check_invariants()
    # replicate consistency: force every replicated page resident on BOTH
    # shards (legal — both own it) and check each copy holds the *new*
    # packing's bytes
    assert pl1.replicated, "scenario produced no shared pages to replicate"
    for pid in sorted(pl1.replicated)[:4]:
        for s in range(srv.num_shards):
            srv.sharded.buffer_pools[s].access("word2vec-v0", pid)
        want = store.page_array(pid)
        for dev in srv.sharded.pools:
            assert pid in dev.slot_of
            np.testing.assert_array_equal(dev.slot_page(dev.slot_of[pid]),
                                          want)
    srv.sharded.check_invariants()
    # and the logits now reflect the updated weights on the device path
    docs, _ = task.sample(16, variant=0, seed=999)
    engine.submit("word2vec-v0", docs)
    engine.run(max_batches=1)
    emb = store.materialize("word2vec-v0", "embedding")
    expect = emb[docs].mean(axis=1) @ heads["word2vec-v0"]
    np.testing.assert_allclose(engine.last_logits, expect, atol=1e-5)


def test_update_between_submit_and_run_cannot_fault_stale_pages():
    """Acceptance: a model update between submit() and run() must not
    fault old-packing page ids on ANY shard — the batch recomputes its
    pages and routing under the new placement."""
    task, store, heads = _scenario(num_models=3)
    srv = ShardedWeightServer(store, max(4, store.num_pages() // 2),
                              storage=StorageModel("dram"),
                              shards=2, placement="sharers")
    engine = EmbeddingServingEngine(srv, heads)
    _run_batches(engine, task, 3, batches=3)          # warm
    docs, _ = task.sample(16, variant=0, seed=321)
    engine.submit("word2vec-v0", docs)                # old packing + shard
    store.update("word2vec-v0",
                 {"embedding": task.variant_embedding(0) + 0.125})
    engine.run(max_batches=1)                         # new packing
    srv.sharded.check_invariants()
    emb = store.materialize("word2vec-v0", "embedding")
    expect = emb[docs].mean(axis=1) @ heads["word2vec-v0"]
    np.testing.assert_allclose(engine.last_logits, expect, atol=1e-5)


# -------------------------------------------------------------- mesh slab --
def test_stacked_slab_lowers_with_named_sharding():
    """The mesh view: per-shard slabs stack to [S, cap, l, bh, bw] and
    lay out with NamedSharding over the serving mesh's shard axis."""
    from repro.launch.mesh import make_shard_mesh
    _, store, heads = _scenario()
    srv = ShardedWeightServer(store, 4, storage=StorageModel("dram"),
                              shards=2, placement="sharers",
                              kernel_mode="pallas")
    pl = srv.sharded.placement()
    for s in range(2):
        for pid in sorted(pl.owned_sets[s])[:2]:
            srv.sharded.buffer_pools[s].access("word2vec-v0", pid)
    mesh = make_shard_mesh(2)
    slab = srv.sharded.stacked_slab(mesh)
    assert slab.shape[:2] == (2, 4)
    assert slab.sharding.is_fully_replicated or \
        slab.sharding.spec[0] == "shard"
