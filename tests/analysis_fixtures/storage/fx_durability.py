"""Seeded violations for the durability pass (tests/test_analysis.py).

Lives outside ``repro/storage/`` so the default-configured pass ignores
it; the test re-scopes the pass onto this file with ``files=``.
"""
import os


def unjournaled_replace(tmp, dst):
    """Rule A trips: an atomic rename with no crash seam around it."""
    os.replace(tmp, dst)


def suppressed_replace(tmp, dst):
    """The pragma'd twin stays quiet."""
    os.replace(tmp, dst)  # repro: allow-unjournaled (fixture rationale)


def seamed_replace(tmp, dst):
    """A crash_point call in the same function satisfies the rule."""
    crash_point("fixture.seam")
    os.replace(tmp, dst)


def unjournaled_commit(con):
    """Rule B trips: a db transaction commit with no crash seam."""
    con.commit()


def nested_seam_does_not_count(tmp, dst):
    """A seam inside a nested helper does not journal the OUTER
    function's rename — Rule A still trips."""
    def inner():
        crash_point("fixture.inner")
    inner()
    os.replace(tmp, dst)


def crash_point(name):
    """Local stub so the fixture never imports the real registry."""
    del name
