"""Seeded wallclock violation (never imported; parsed by the lints)."""
import time


def measure():
    t0 = time.time()                                   # banned
    return time.time() - t0                            # banned


def allowed():
    return time.time()  # repro: allow-wallclock (fixture)
