"""Seeded span-discipline violations: raw span plumbing + an
unspanned charged fetch.

Parsed by tests with SpanDisciplinePass(path_fragment=
"analysis_fixtures/"); never imported.
"""


class SpanPool:
    """Stand-in for a traced fetch path."""

    def raw_plumbing(self, tr):
        sp = tr.span_begin("fetch")                    # Rule A: raw begin
        tr.span_end(sp)                                # Rule A: raw end

    def unspanned_charge(self, store, storage, pids):
        stack = store.page_stack(pids)                 # fetch ...
        storage.fetch_group_seconds(len(pids), 0)      # ... charged, no span
        return stack

    def good_spanned(self, tr, store, storage, pids):
        with tr.span("fault_group", kind="storage", pages=len(pids)):
            stack = store.page_stack(pids)
            storage.fetch_group_seconds(len(pids), 0)
        return stack

    # repro: allow-unspanned (the caller opens the span)
    def helper_caller_spans(self, store, storage, pids):
        stack = store.page_stack(pids)
        storage.fetch_group_seconds(len(pids), 0)
        return stack
