"""Fixture for FrontendClockPass: wall-time calls and an uncharged
dispatch must trip; the charged dispatcher and the pragma'd helper stay
quiet.  (Linted with files=("analysis_fixtures/serving/fx_frontend.py",).)
"""
import time


class Frontend:
    def bad_wall_time(self):
        return time.perf_counter()            # trip: wall time

    def bad_free_latency(self, engine):
        engine.run(max_batches=1)             # trip: no clock charge
        return engine.stats

    def good_charged(self, engine, clock):
        engine.run(max_batches=1)
        clock.advance(1e-3, "compute")        # charged: quiet

    # repro: allow-untimed (caller owns the charge)
    def helper_caller_charges(self, engine):
        engine.run(max_batches=1)
