"""Seeded hot-path host-sync + uncharged-fetch violations.

Parsed by tests with HostSyncPass(hot={"serving/fx_hot.py": ...}) and
ChannelChargePass(path_fragment="analysis_fixtures/serving/"); never
imported.
"""
import numpy as np


class HotPool:
    """Stand-in for a pool with a hot compute path."""

    def gather(self, dev_map, x):
        x = np.asarray(x)                              # host sync in hot path
        return float(x.sum())                          # and a device float()

    def cold(self, x):
        return np.asarray(x)                           # not configured hot

    def uncharged_fetch(self, store, pids):
        return store.page_stack(pids)                  # fetch, no charge

    def charged_fetch(self, store, storage, pids):
        stack = store.page_stack(pids)
        storage.fetch_group_seconds(len(pids), stack.nbytes)
        return stack
