"""Seeded unused/shadow/dead-code violations (parsed, never imported)."""
import json                                            # unused import
import os


def unused_local(xs):
    total = sum(xs)                                    # assigned, never read
    return len(xs)


def shadows(list, id):                                 # two shadowed builtins
    return [list, id]


def dead_code(x):
    return x + 1
    x = os.getpid()                                    # unreachable


def allowed_shadow(next):  # repro: allow-shadow (fixture)
    return next
