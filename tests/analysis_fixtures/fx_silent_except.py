"""Fixture for SilentExceptPass: a bare except and a broad silent
handler trip; a pragma'd broad catch and a narrow typed probe stay
quiet."""


def swallow_everything(fn):
    try:
        fn()
    except:                                    # TRIP: bare except
        print("recovered?")
    try:
        fn()
    except Exception:                          # TRIP: broad + do-nothing
        pass
    try:
        fn()
    except BaseException:  # repro: allow-silent-except (fixture rationale)
        ...
    try:
        return {"k": 1}["missing"]
    except KeyError:                           # narrow probe: legal
        pass
