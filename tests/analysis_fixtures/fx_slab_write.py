"""Seeded slab-write violations (never imported; parsed by the lints)."""
import jax


def sneak_scatter(pool, rows, slots):
    pool.slab = pool.slab.at[slots].set(rows)          # grouped-path bypass
    return pool.slab


def sneak_mirror(pool, page, slot):
    pool.host_slab[slot] = page                        # mirror write
    return slot


def sneak_dus(slab, rows, slot):
    return jax.lax.dynamic_update_slice(slab, rows, (slot, 0, 0, 0))


def allowed_scatter(pool, rows, slots):
    # repro: allow-slab-write (fixture: pragma suppression must work)
    pool.slab = pool.slab.at[slots].set(rows)
    return pool.slab
