"""Seeded __all__ / docstring-drift violations (parsed, never imported)."""
__all__ = ["real_fn", "ghost_fn", "real_fn"]           # ghost + duplicate


def real_fn(alpha, beta):
    """Combine ``alpha=`` and ``gamma=`` (gamma was renamed to beta)."""
    return alpha, beta


def undocumented(x):
    return x
