import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.lsh import L2LSH, LSHConfig, LSHIndex, estimate_r


def _cfg(**kw):
    base = dict(num_bands=16, rows_per_band=4, r=1.0,
                collision_threshold=10, seed=0)
    base.update(kw)
    return LSHConfig(**base)


def test_signature_deterministic():
    lsh = L2LSH(64, _cfg())
    x = np.random.default_rng(0).standard_normal((5, 8, 8))
    s1, s2 = lsh.signatures(x), lsh.signatures(x)
    assert np.array_equal(s1, s2)
    assert s1.shape == (5, 64)


def test_similar_blocks_collide_dissimilar_dont():
    rng = np.random.default_rng(1)
    base = rng.standard_normal(64).astype(np.float32)
    near = base + rng.standard_normal(64).astype(np.float32) * 0.01
    far = rng.standard_normal(64).astype(np.float32) * 3
    idx = LSHIndex(64, _cfg(r=2.0))
    sigs = idx.lsh.signatures(np.stack([base, near, far]))
    gid = idx.insert_group(sigs[0], ("m", "t", 0))
    assert idx.query(sigs[1]) == gid
    assert idx.query(sigs[2]) is None


def test_threshold_monotonic():
    """Lower collision threshold -> more matches (Tab. 6 behaviour)."""
    rng = np.random.default_rng(2)
    base = rng.standard_normal(256).astype(np.float32)
    variants = base + rng.standard_normal((50, 256)).astype(np.float32) * 0.4
    matches = {}
    for thr in (4, 8, 14):
        idx = LSHIndex(256, _cfg(r=1.5, collision_threshold=thr))
        s0 = idx.lsh.signatures(base[None])[0]
        idx.insert_group(s0, ("m", "t", 0))
        sig = idx.lsh.signatures(variants)
        matches[thr] = sum(idx.query(s) is not None for s in sig)
    assert matches[4] >= matches[8] >= matches[14]


def test_remove_member_drops_empty_group():
    idx = LSHIndex(16, _cfg(num_bands=4, rows_per_band=2,
                            collision_threshold=2))
    x = np.ones((1, 4, 4), np.float32)
    s = idx.lsh.signatures(x)[0]
    gid = idx.insert_group(s, ("m", "t", 0))
    assert len(idx) == 1
    assert idx.remove_member(gid, ("m", "t", 0))
    assert len(idx) == 0
    assert idx.query(s) is None


@given(st.integers(2, 30))
@settings(max_examples=10, deadline=None)
def test_estimate_r_positive(n):
    rng = np.random.default_rng(n)
    blocks = rng.standard_normal((n, 4, 4))
    assert estimate_r(blocks) > 0
