import numpy as np
import pytest

from repro.core.bufferpool import POLICIES, BufferPool, PoolConfig, run_trace


def _pool(cap=4, policy="lru", sharers=None, locality=None, **kw):
    return BufferPool(PoolConfig(cap, policy, **kw),
                      page_sharers=sharers, page_locality=locality)


def test_lru_eviction_order():
    p = _pool(2, "lru")
    p.access("m", "a")
    p.access("m", "b")
    p.access("m", "a")           # refresh a
    p.access("m", "c")           # evicts b (least recent)
    assert "b" not in p.resident
    assert {"a", "c"} <= set(p.resident)


def test_mru_eviction_order():
    p = _pool(2, "mru")
    p.access("m", "a")
    p.access("m", "b")
    p.access("m", "c")           # evicts b (most recent resident)
    assert set(p.resident) == {"a", "c"}


def test_lfu_prefers_frequency():
    p = _pool(2, "lfu")
    for _ in range(3):
        p.access("m", "hot")
    p.access("m", "cold")
    p.access("m", "new")         # cold has lowest freq -> evicted
    assert "hot" in p.resident and "cold" not in p.resident


def test_hit_ratio_accounting():
    p = _pool(8)
    trace = [("m", i % 4) for i in range(40)]
    hr = run_trace(p, trace)
    assert p.hits == 36 and p.misses == 4
    assert hr == pytest.approx(0.9)


def test_eq2_shared_pages_survive():
    """Pages shared by more models get higher p_reuse (Eq. 2) -> kept."""
    sharers = {"shared": ["m1", "m2", "m3"], "p1": ["m1"],
               "p2": ["m2"], "p3": ["m3"]}
    locality = {k: "L" for k in sharers}      # one locality set
    p = _pool(2, "optimized_lru", sharers=sharers, locality=locality,
              horizon_t=8.0)
    rng = np.random.default_rng(0)
    models = ["m1", "m2", "m3"]
    # every request touches the shared page + the model's private page
    for i in range(60):
        m = models[int(rng.integers(0, 3))]
        p.access(m, "shared")
        p.access(m, f"p{m[1]}")
    assert "shared" in p.resident


def test_optimized_beats_lru_on_shared_trace():
    """The paper's claim (Fig. 14): Eq.-2-aware eviction improves hit ratio
    on multi-model traffic with shared pages."""
    def build(policy):
        sharers = {f"s{i}": ["m1", "m2", "m3", "m4"] for i in range(3)}
        sharers.update({f"q{m}{i}": [f"m{m}"] for m in range(1, 5)
                        for i in range(4)})
        locality = {k: ("S" if k.startswith("s") else f"P{k[1]}")
                    for k in sharers}
        return BufferPool(PoolConfig(6, policy, horizon_t=12.0),
                          page_sharers=sharers, page_locality=locality)

    def trace(seed=1, n=400):
        rng = np.random.default_rng(seed)
        out = []
        for _ in range(n):
            m = f"m{int(rng.integers(1, 5))}"
            for i in range(3):
                out.append((m, f"s{i}"))           # shared working set
            out.append((m, f"q{m[1]}{int(rng.integers(0, 4))}"))
        return out

    hr = {pol: run_trace(build(pol), trace())
          for pol in ("lru", "optimized_lru")}
    assert hr["optimized_lru"] > hr["lru"]


def test_callbacks_fire():
    loaded, evicted = [], []
    p = BufferPool(PoolConfig(1, "lru"), on_load=loaded.append,
                   on_evict=evicted.append)
    p.access("m", "a")
    p.access("m", "b")
    assert loaded == ["a", "b"] and evicted == ["a"]


def test_all_policies_run():
    trace = [("m%d" % (i % 3), i % 7) for i in range(100)]
    for pol in POLICIES:
        p = _pool(3, pol)
        hr = run_trace(p, trace)
        assert 0 <= hr <= 1
        assert len(p.resident) <= 3


def test_bad_policy_rejected():
    with pytest.raises(ValueError):
        PoolConfig(4, "clock")
