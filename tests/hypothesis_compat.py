"""Import `given` / `settings` / `st` from here instead of `hypothesis`.

When hypothesis is installed this re-exports the real thing.  When it is
not (it's an optional dev dependency, see pyproject.toml), property tests
degrade to per-test skips via ``pytest.importorskip`` at call time — the
rest of the module still collects and runs.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `hypothesis.strategies`: any strategy expression
        evaluated at decoration time resolves to an inert placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            # Deliberately NOT functools.wraps: pytest must see a
            # zero-argument signature, not the strategy parameters.
            def run():
                pytest.importorskip("hypothesis")
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
