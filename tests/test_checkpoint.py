import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layer": {"w": jnp.asarray(rng.standard_normal((4, 4)),
                                       jnp.float32),
                      "b": jnp.asarray(rng.standard_normal(4),
                                       jnp.bfloat16)},
            "step_scale": jnp.asarray(1.5, jnp.float32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    params = _tree(0)
    opt = {"m": _tree(1)}
    mgr.save(7, params, opt, extra={"loss": 1.25})
    p2, o2, manifest = mgr.restore(7, params, opt)
    for a, b in zip(__import__("jax").tree.leaves(params),
                    __import__("jax").tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert manifest["extra"]["loss"] == 1.25


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, params)
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]       # keep=2 garbage-collected


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    params = _tree()
    mgr.save(5, params)
    # simulate a crash mid-save: directory without manifest
    os.makedirs(tmp_path / "step-9")
    np.savez(tmp_path / "step-9" / "params.npz", x=np.zeros(3))
    assert mgr.latest_step() == 5          # 9 has no manifest -> ignored


def test_restore_latest_none_when_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.restore_latest(_tree()) is None


def test_dtype_preserved(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    params = _tree()
    mgr.save(1, params)
    p2, _, _ = mgr.restore(1, params)
    assert p2["layer"]["b"].dtype == jnp.bfloat16
