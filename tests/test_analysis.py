"""Contract-lint framework tests: each pass trips on its seeded fixture
under tests/analysis_fixtures/, pragmas suppress, src/ is clean at HEAD,
and the scripts/run_lints.py driver exits non-zero on violations.

Stdlib-only on purpose (no jax import): the lints must work in a bare
container.
"""
import subprocess
import sys
from pathlib import Path

from repro.analysis.lint import Source, parse_pragmas, run_lint
from repro.analysis.passes import default_passes
from repro.analysis.passes.api_drift import ApiDriftPass
from repro.analysis.passes.channel_charge import ChannelChargePass
from repro.analysis.passes.durability import DurabilityPass
from repro.analysis.passes.frontend_clock import FrontendClockPass
from repro.analysis.passes.host_sync import HostSyncPass
from repro.analysis.passes.slab_writes import SlabWritePass
from repro.analysis.passes.unused import UnusedBindingPass
from repro.analysis.passes.wallclock import WallClockPass

ROOT = Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "analysis_fixtures"


def _names(findings):
    return [f.name for f in findings]


def _msgs(findings):
    return "\n".join(f.message for f in findings)


# ------------------------------------------------------------- framework --
def test_pragma_parsing_tokens_and_rationale():
    pragmas = parse_pragmas(
        "x = 1  # repro: allow-host (reason text is fine)\n"
        "y = 2  # repro: allow-host, allow-uncharged\n"
        "z = 3  # unrelated comment\n")
    assert pragmas[1] == frozenset({"allow-host"})
    assert pragmas[2] == frozenset({"allow-host", "allow-uncharged"})
    assert 3 not in pragmas


def test_pragma_suppresses_on_line_and_line_above():
    src = Source("m.py", "# repro: allow-wallclock\n"
                         "import time\n"
                         "t = time.time()  # repro: allow-wallclock\n"
                         "u = time.time()\n")
    findings = WallClockPass().run(src)
    assert len(findings) == 1 and findings[0].line == 4


def test_syntax_error_becomes_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    findings = run_lint([bad])
    assert _names(findings) == ["syntax"]


# ----------------------------------------------------------- fixture trips --
def test_slab_write_fixture_trips_and_pragma_suppresses():
    findings = SlabWritePass().run(Source.load(FIXTURES / "fx_slab_write.py"))
    assert len(findings) == 3                  # scatter + mirror + dus
    assert {f.name for f in findings} == {"slab-write"}
    # the pragma'd fourth site stays quiet
    assert all(f.line < 19 for f in findings)


def test_slab_write_silent_in_owner_modules():
    text = Path(ROOT / "src/repro/serving/transfer.py").read_text()
    src = Source("src/repro/serving/transfer.py", text)
    assert SlabWritePass().run(src) == []


def test_wallclock_fixture_trips():
    findings = WallClockPass().run(Source.load(FIXTURES / "fx_wallclock.py"))
    assert len(findings) == 2


def test_unused_fixture_trips():
    findings = UnusedBindingPass().run(Source.load(FIXTURES / "fx_unused.py"))
    msgs = _msgs(findings)
    assert "import `json` is never used" in msgs
    assert "local `total`" in msgs
    assert "parameter `list`" in msgs and "parameter `id`" in msgs
    assert "unreachable statement" in msgs
    assert "`next`" not in msgs                # pragma'd shadow stays quiet


def test_drift_fixture_trips():
    src = Source.load(FIXTURES / "fx_drift.py")
    findings = ApiDriftPass(surface=("analysis_fixtures/",)).run(src)
    msgs = _msgs(findings)
    assert "`ghost_fn` which is not defined" in msgs
    assert "more than once" in msgs
    assert "``gamma=``" in msgs and "``alpha=``" not in msgs
    assert "`undocumented` has no docstring" in msgs


def test_host_sync_fixture_trips_only_configured_qualnames():
    src = Source.load(FIXTURES / "serving" / "fx_hot.py")
    findings = HostSyncPass(
        hot={"serving/fx_hot.py": {"HotPool.gather"}}).run(src)
    assert len(findings) == 2                  # asarray + float, not cold()
    assert all("HotPool.gather" in f.message for f in findings)


def test_channel_charge_fixture_trips_uncharged_only():
    src = Source.load(FIXTURES / "serving" / "fx_hot.py")
    findings = ChannelChargePass(
        path_fragment="analysis_fixtures/serving/").run(src)
    assert len(findings) == 1
    assert "uncharged_fetch" in findings[0].message


def test_frontend_clock_fixture_trips_wall_time_and_free_latency():
    src = Source.load(FIXTURES / "serving" / "fx_frontend.py")
    findings = FrontendClockPass(
        files=("analysis_fixtures/serving/fx_frontend.py",)).run(src)
    assert len(findings) == 2
    assert {f.name for f in findings} == {"frontend-clock"}
    msgs = _msgs(findings)
    assert "time.perf_counter()" in msgs          # Rule A: wall time
    assert "free latency" in msgs                 # Rule B: uncharged run()
    assert "bad_free_latency" in msgs
    # the charged dispatcher and the pragma'd helper stay quiet
    assert "good_charged" not in msgs
    assert "helper_caller_charges" not in msgs


def test_frontend_clock_scoped_to_frontend_files_only():
    # the same wall-time call outside the configured files is ignored
    src = Source("src/repro/serving/engine.py",
                 "import time\nt = time.perf_counter()\n")
    assert FrontendClockPass().run(src) == []
    # ... and the real frontend modules ARE in scope by default
    src = Source("src/repro/serving/frontend.py",
                 "import time\nt = time.perf_counter()\n")
    assert len(FrontendClockPass().run(src)) == 1


def test_span_discipline_fixture_trips_raw_and_unspanned():
    from repro.analysis.passes.span_discipline import SpanDisciplinePass
    src = Source.load(FIXTURES / "serving" / "fx_span.py")
    findings = SpanDisciplinePass(
        path_fragment="analysis_fixtures/").run(src)
    assert {f.name for f in findings} == {"span-discipline"}
    msgs = _msgs(findings)
    assert "raw span_begin() call" in msgs          # Rule A: begin
    assert "raw span_end() call" in msgs            # Rule A: end
    assert "unspanned_charge" in msgs               # Rule B trips
    assert "good_spanned" not in msgs               # with-span stays quiet
    assert "helper_caller_spans" not in msgs        # pragma'd stays quiet
    assert len(findings) == 3


def test_span_discipline_raw_calls_allowed_in_tracer_module():
    from repro.analysis.passes.span_discipline import SpanDisciplinePass
    text = Path(ROOT / "src/repro/obs/trace.py").read_text()
    src = Source("src/repro/obs/trace.py", text)
    assert SpanDisciplinePass().run(src) == []


def test_durability_fixture_trips_and_pragma_suppresses():
    src = Source.load(FIXTURES / "storage" / "fx_durability.py")
    findings = DurabilityPass(
        files=("analysis_fixtures/storage/fx_durability.py",)).run(src)
    assert {f.name for f in findings} == {"durability"}
    msgs = _msgs(findings)
    assert "unjournaled_replace" in msgs          # Rule A: os.replace
    assert "unjournaled_commit" in msgs           # Rule B: con.commit()
    assert "nested_seam_does_not_count" in msgs   # nested defs don't count
    assert "suppressed_replace" not in msgs       # pragma'd stays quiet
    assert "seamed_replace" not in msgs           # seam in-function: clean
    assert len(findings) == 3


def test_durability_scoped_to_storage_layer_by_default():
    # the same rename outside the storage layer is ignored
    src = Source("src/repro/serving/frontend.py",
                 "import os\n\ndef f(a, b):\n    os.replace(a, b)\n")
    assert DurabilityPass().run(src) == []
    # ... while repro/storage/ and core/store.py ARE in default scope
    src = Source("src/repro/storage/newbackend.py",
                 "import os\n\ndef f(a, b):\n    os.replace(a, b)\n")
    assert len(DurabilityPass().run(src)) == 1
    src = Source("src/repro/core/store.py",
                 "def f(con):\n    con.commit()\n")
    assert len(DurabilityPass().run(src)) == 1


def test_silent_except_fixture_trips_pragma_and_narrow_stay_quiet():
    from repro.analysis.passes.silent_except import SilentExceptPass
    findings = SilentExceptPass().run(
        Source.load(FIXTURES / "fx_silent_except.py"))
    assert len(findings) == 2                  # bare + broad-silent
    assert {f.name for f in findings} == {"silent-except"}
    msgs = _msgs(findings)
    assert "bare except" in msgs
    assert "do-nothing body" in msgs
    # the pragma'd BaseException catch and the KeyError probe stay quiet
    assert "BaseException" not in msgs


# ------------------------------------------------------------ HEAD is clean --
def test_src_tree_is_clean():
    findings = run_lint([ROOT / "src"], default_passes())
    assert findings == [], "\n".join(str(f) for f in findings)


# ----------------------------------------------------------------- driver --
def test_run_lints_driver_fails_on_fixtures_and_passes_on_src():
    script = str(ROOT / "scripts" / "run_lints.py")
    bad = subprocess.run(
        [sys.executable, script, "--no-ruff", str(FIXTURES)],
        capture_output=True, text=True)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "slab-write" in bad.stdout and "wallclock" in bad.stdout
    good = subprocess.run(
        [sys.executable, script, "--no-ruff", str(ROOT / "src")],
        capture_output=True, text=True)
    assert good.returncode == 0, good.stdout + good.stderr
