import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import blocks as B


@given(h=st.integers(1, 90), w=st.integers(1, 90),
       bh=st.sampled_from([4, 8, 16, 32]), bw=st.sampled_from([4, 8, 16, 32]))
@settings(max_examples=40, deadline=None)
def test_roundtrip_2d(h, w, bh, bw):
    rng = np.random.default_rng(h * 100 + w)
    x = rng.standard_normal((h, w)).astype(np.float32)
    blk, grid = B.block_tensor(x, (bh, bw))
    assert blk.shape == (grid.num_blocks, bh, bw)
    assert np.array_equal(B.unblock_tensor(blk, grid), x)


@pytest.mark.parametrize("shape", [(5,), (7, 11), (3, 4, 5), (2, 3, 4, 5)])
def test_roundtrip_nd(shape):
    rng = np.random.default_rng(0)
    x = rng.standard_normal(shape).astype(np.float32)
    blk, grid = B.block_tensor(x, (8, 8))
    assert np.array_equal(B.unblock_tensor(blk, grid), x)


def test_block_order_row_major():
    x = np.arange(16 * 16, dtype=np.float32).reshape(16, 16)
    blk, grid = B.block_tensor(x, (8, 8))
    assert grid.grid == (2, 2)
    assert np.array_equal(blk[0], x[:8, :8])
    assert np.array_equal(blk[1], x[:8, 8:])
    assert np.array_equal(blk[2], x[8:, :8])


def test_materialize_with_map():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((32, 32)).astype(np.float32)
    blk, grid = B.block_tensor(x, (16, 16))
    pool = blk[[0, 2]]                     # distinct blocks only
    bmap = np.array([0, 0, 1, 1])          # both col-blocks mapped to one
    y = B.materialize(pool, bmap, grid)
    assert np.array_equal(y[:16, :16], x[:16, :16])
    assert np.array_equal(y[:16, 16:], x[:16, :16])


def test_padding_is_zero():
    x = np.ones((10, 10), np.float32)
    blk, grid = B.block_tensor(x, (8, 8))
    assert grid.padded2d == (16, 16)
    assert blk[3, 2:, 2:].sum() == 0
