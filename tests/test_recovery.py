"""Journaled store recovery + warm-restart serving (DESIGN.md §11):
journal semantics, the orphan-leak regression, recovery idempotence,
frontend snapshot/restore at-most-once delivery, and the full-flag
composition run through the launcher.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.store import ModelStore
from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.launch.serve import main as serve_main
from repro.serving import (BatchComputeModel, EmbeddingServingEngine,
                           OpenLoopTraffic, ServingFrontend, StorageModel,
                           WeightServer)
from repro.storage import open_backend
from repro.storage.crashpoints import (CrashPointReached, armed,
                                       mutate_store, prime_store,
                                       serve_logits)
from repro.storage.journal import Journal, recover_backend
from repro.storage.localdir import LocalDirBackend


# ------------------------------------------------------------- journal ----
def test_journal_roundtrip_and_compaction(tmp_path):
    backend = LocalDirBackend(str(tmp_path / "store"))
    jr = Journal(backend)
    seq = jr.begin("save", keep=["a", "b"])
    assert [r["seq"] for r in jr.pending()] == [seq]
    jr.commit(seq)
    assert jr.records() == []          # resolved pair compacted away


def test_journal_pending_intent_survives_other_writers(tmp_path):
    backend = LocalDirBackend(str(tmp_path / "store"))
    jr = Journal(backend)
    mine = jr.begin("save", keep=["a"])
    theirs = jr.begin("save", keep=["b"])
    jr.commit(mine)
    # the concurrent writer's open intent survives my compaction verbatim
    pend = jr.pending()
    assert [r["seq"] for r in pend] == [theirs]
    assert pend[0]["keep"] == ["b"]


def test_journal_torn_tail_is_ignored(tmp_path):
    backend = LocalDirBackend(str(tmp_path / "store"))
    jr = Journal(backend)
    jr.begin("save", keep=["a"])
    # a crash mid-append leaves a torn half-record at the tail: it never
    # became durable, so it never happened
    with open(os.path.join(backend.path, "journal.jsonl"), "a") as f:
        f.write('{"v": 1, "phase": "inte')
    recs = backend.journal_records()
    assert len(recs) == 1 and recs[0]["keep"] == ["a"]


# ------------------------------------------------------------ recovery ----
def test_orphan_leak_regression_crash_between_commit_and_prune(tmp_path):
    """The original leak: a crash after commit_manifest but before
    delete_pages strands the previous generation's pages forever (no
    manifest references them, nothing ever deletes them).  The journal
    replay must finish the prune on the next open."""
    url = f"file://{tmp_path / 'store'}"
    prime_store(url)
    with pytest.raises(CrashPointReached):
        with armed("store.save.manifest_committed", mode="raise"):
            mutate_store(url)
    # wreckage: manifest committed, prune never ran -> orphans on disk
    raw = LocalDirBackend(str(tmp_path / "store"))
    refs = {p["hash"] for p in raw.load_manifest()["pages"]}
    assert set(raw.list_pages()) - refs, "scenario must strand orphans"
    assert raw.journal_records(), "scenario must leave a dirty journal"
    # any open replays the journal: orphans gone, store = mutated state
    store = ModelStore.open(url)
    assert sorted(store.dedup.models) == ["m0", "m1", "m2"]
    assert set(raw.list_pages()) == refs
    assert raw.journal_records() == []
    assert raw.sweep_temp() == 0


def test_crashed_save_before_commit_rolls_back(tmp_path):
    url = f"file://{tmp_path / 'store'}"
    prime_store(url)
    golden = serve_logits(url)
    with pytest.raises(CrashPointReached):
        with armed("store.save.pages_put", mode="raise"):
            mutate_store(url)
    # fresh pages with no committed manifest: recovery undoes them
    backend = open_backend(url)        # open_backend replays the journal
    refs = {p["hash"] for p in backend.load_manifest()["pages"]}
    assert set(backend.list_pages()) == refs
    assert backend.journal_records() == []
    backend.close()
    assert np.array_equal(serve_logits(url), golden)


def test_recovery_is_idempotent_when_recovery_itself_crashes(tmp_path):
    url = f"file://{tmp_path / 'store'}"
    prime_store(url)
    golden = serve_logits(url)
    with pytest.raises(CrashPointReached):
        with armed("store.save.pages_put", mode="raise"):
            mutate_store(url)
    # first recovery attempt dies mid-GC; the journal stays dirty
    with pytest.raises(CrashPointReached):
        with armed("recover.gc_journaled", mode="raise"):
            ModelStore.open(url)
    # ... so the next open just runs the same idempotent GC again
    ModelStore.open(url)
    raw = LocalDirBackend(str(tmp_path / "store"))
    refs = {p["hash"] for p in raw.load_manifest()["pages"]}
    assert set(raw.list_pages()) == refs
    assert raw.journal_records() == []
    assert np.array_equal(serve_logits(url), golden)


@pytest.mark.parametrize("scheme", ["file", "sqlite"])
def test_open_backend_recovers_both_schemes(tmp_path, scheme):
    url = f"file://{tmp_path / 'store'}" if scheme == "file" \
        else f"sqlite:///{tmp_path / 'store.db'}"
    prime_store(url)
    with pytest.raises(CrashPointReached):
        with armed("store.save.pages_put", mode="raise"):
            mutate_store(url)
    backend = open_backend(url)
    try:
        assert backend.journal_records() == []
        refs = {p["hash"] for p in backend.load_manifest()["pages"]}
        assert set(backend.list_pages()) == refs
        assert backend.sweep_temp() == 0
    finally:
        backend.close()


def test_temp_sweep_and_list_pages_ignore_staging_debris(tmp_path):
    backend = LocalDirBackend(str(tmp_path / "store"))
    backend.put_pages({"cafe01": np.zeros((4, 4), np.float32)})
    # crash-stranded mkstemp debris, including a page-look-alike
    for name in ("tmpabc123.npy.tmp", "page-dead.npy.tmp", "m.json.tmp"):
        with open(os.path.join(backend.path, name), "w") as f:
            f.write("debris")
    assert backend.list_pages() == ["cafe01"]
    assert backend.sweep_temp() == 3
    assert backend.sweep_temp() == 0               # idempotent
    assert backend.list_pages() == ["cafe01"]


def test_recover_backend_reports_redo_vs_undo(tmp_path):
    backend = LocalDirBackend(str(tmp_path / "store"))
    backend.commit_manifest({"version": 2, "pages": [{"hash": "aa"}],
                             "models": {}})
    backend.put_pages({"aa": np.zeros((2, 2), np.float32),
                       "bb": np.ones((2, 2), np.float32)})
    jr = Journal(backend)
    jr.begin("save", keep=["aa"])      # its manifest landed: redo
    jr.begin("save", keep=["zz"])      # never committed: undo
    report = recover_backend(backend)
    assert report.recovered
    assert (report.redo, report.undo) == (1, 1)
    assert report.orphan_pages_deleted == 1        # bb
    assert backend.list_pages() == ["aa"]
    assert not recover_backend(backend).recovered  # second pass: clean


# ------------------------------------------------------- warm restart ----
def _scenario(num_models=4, vocab=512):
    task = SyntheticTextTask(vocab=vocab, d=32, seed=0)
    store, heads = build_store(task, num_models, block_shape=(32, 32),
                               blocks_per_page=4)
    return task, store, heads


def _payload(task):
    def fn(model, rid, rng):
        v = int(model.rsplit("-v", 1)[1])
        docs, _ = task.sample(2, variant=v, seed=900 + rid)
        return docs
    return fn


def _frontend(store, heads, **kw):
    server = WeightServer(store, max(2, store.num_pages() // 2),
                          storage=StorageModel("dram"))
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo")
    return ServingFrontend(engine, max_batch=4,
                           compute_model=BatchComputeModel(), **kw)


def _gen(task, heads):
    return OpenLoopTraffic(sorted(heads), rate=300.0, zipf_alpha=1.1,
                           slo_s=0.5, seed=5, payload_fn=_payload(task))


def test_warm_restart_is_bit_exact_and_at_most_once(tmp_path):
    task, store, heads = _scenario()
    n = 60
    fe0 = _frontend(store, heads)
    st0 = fe0.run(_gen(task, heads).generate(n))
    golden = dict(fe0.results)
    assert len(golden) == n

    snap_path = str(tmp_path / "fe.json")
    fe1 = _frontend(store, heads, snapshot_path=snap_path)
    fe1.run(_gen(task, heads).generate(n), max_dispatches=4)
    served_before = dict(fe1.results)
    assert 0 < len(served_before) < n
    # simulated process death: only the snapshot file survives
    with open(snap_path) as f:
        snap = json.load(f)
    task2, store2, heads2 = _scenario()            # fresh everything
    server2 = WeightServer(store2, max(2, store2.num_pages() // 2),
                           storage=StorageModel("dram"))
    engine2 = EmbeddingServingEngine(server2, heads2, scheduler="fifo")
    fe2 = ServingFrontend.restore(engine2, snap,
                                  _gen(task2, heads2).generate(n),
                                  compute_model=BatchComputeModel(),
                                  snapshot_path=snap_path)
    assert fe2.ledger.readmitted > 0
    st2 = fe2.run(_gen(task2, heads2).generate(n))
    fe2.assert_ledger_conserved()
    # at-most-once: no rid served on both sides of the crash
    assert not set(served_before) & set(fe2.results)
    combined = {**served_before, **fe2.results}
    assert set(combined) == set(golden)
    for rid, out in golden.items():
        assert np.array_equal(combined[rid], out), f"rid {rid} diverged"
    # the merged books cover the whole stream exactly once (timing may
    # differ — the fresh engine's pools are cold, so the continuation
    # re-pays fetches — but accounting and outputs may not)
    assert st2.offered_requests == st0.offered_requests == n
    assert len(st2.request_latencies) == n
    assert fe2.clock.now >= fe0.clock.now


def test_in_flight_requests_are_readmitted_not_lost(tmp_path):
    """Kill *mid-dispatch*: the in-flight rids are already in the
    durable snapshot (persisted before the engine computes), so the
    restart re-queues exactly those for recompute."""
    task, store, heads = _scenario()
    n = 40
    snap_path = str(tmp_path / "fe.json")
    fe1 = _frontend(store, heads, snapshot_path=snap_path)
    engine1 = fe1.engine
    orig_run = engine1.run
    calls = {"n": 0}

    def dying_run(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("simulated crash mid-compute")
        return orig_run(*a, **kw)

    engine1.run = dying_run
    with pytest.raises(RuntimeError, match="mid-compute"):
        fe1.run(_gen(task, heads).generate(n))
    with open(snap_path) as f:
        snap = json.load(f)
    assert snap["ledger"]["in_flight"], \
        "the dispatch intent must be durable before the engine runs"
    in_flight = set(snap["ledger"]["in_flight"])
    assert not in_flight & set(snap["ledger"]["served"])

    task2, store2, heads2 = _scenario()
    server2 = WeightServer(store2, max(2, store2.num_pages() // 2),
                           storage=StorageModel("dram"))
    engine2 = EmbeddingServingEngine(server2, heads2, scheduler="fifo")
    fe2 = ServingFrontend.restore(engine2, snap,
                                  _gen(task2, heads2).generate(n),
                                  compute_model=BatchComputeModel(),
                                  snapshot_path=snap_path)
    assert fe2.ledger.readmitted >= len(in_flight)
    fe2.run(_gen(task2, heads2).generate(n))
    fe2.assert_ledger_conserved()
    led = fe2.ledger
    # every in-flight rid resolved exactly once, nothing dropped
    assert in_flight <= (led.served | led.shed)
    assert len(led.served) + len(led.shed) == len(led.offered) == n


def test_restore_requires_every_referenced_rid():
    task, store, heads = _scenario()
    fe = _frontend(store, heads)
    fe.run(_gen(task, heads).generate(20))
    snap = fe.snapshot()
    snap["ledger"]["in_flight"] = [19]
    with pytest.raises(KeyError):
        ServingFrontend.restore(fe.engine, snap, [])


# ----------------------------------------------------------- launcher ----
def test_serve_cli_kill_then_resume(tmp_path, capsys):
    snap = str(tmp_path / "fe.json")
    argv = ["--traffic", "rate=400,requests=40,slo_ms=200,max_batch=4",
            "--models", "4", "--vocab", "512", "--snapshot", snap]
    serve_main(argv + ["--kill-after", "3"])
    out1 = capsys.readouterr().out
    assert "[restart] stopped after 3 dispatches" in out1
    assert os.path.exists(snap)
    serve_main(argv)
    out2 = capsys.readouterr().out
    assert "[restart] resumed from" in out2
    assert "readmitted=" in out2
    # the resumed run finishes the whole stream: offered == served+shed
    line = [ln for ln in out2.splitlines() if ln.startswith("[traffic]")][0]
    kv = dict(p.split("=", 1) for p in line.split()[1:] if "=" in p)
    assert int(kv["offered"]) == 40
    assert int(kv["served"]) + int(kv["shed"]) == 40


def test_serve_cli_flag_validation():
    with pytest.raises(SystemExit, match="--snapshot requires --traffic"):
        serve_main(["--snapshot", "/tmp/x.json"])
    with pytest.raises(SystemExit, match="--kill-after requires"):
        serve_main(["--traffic", "requests=5", "--kill-after", "1"])


@pytest.mark.slow
def test_composition_all_flags_together(tmp_path, capsys):
    """One launcher run with traffic + faults + 2 shards + trace +
    report-json at once: every report line prints, the virtual clock
    conserves (asserted inside fe.run / _export_obs), and the exported
    trace validates."""
    from repro.obs import validate_chrome_trace
    trace = str(tmp_path / "trace.json")
    report = str(tmp_path / "report.json")
    serve_main([
        "--store-url", f"sqlite:///{tmp_path / 'm.db'}",
        "--faults", "transient=0.05,seed=7",
        "--traffic", "rate=300,requests=40,slo_ms=200,max_batch=4",
        "--shards", "2", "--backend", "device",
        "--models", "4", "--vocab", "512",
        "--trace", trace, "--report-json", report])
    out = capsys.readouterr().out
    for tag in ("[store-url]", "[faults]", "[shards]", "[traffic]",
                "[serve]", "[trace]", "[report-json]"):
        assert any(ln.startswith(tag) for ln in out.splitlines()), \
            f"missing report line {tag}:\n{out}"
    with open(trace) as f:
        validate_chrome_trace(json.load(f))
    with open(report) as f:
        snap = json.load(f)
    assert any(k.startswith("serve.") for k in snap)
    assert any(k.startswith("clock.") for k in snap)
