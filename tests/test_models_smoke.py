"""Per-architecture smoke tests: reduced same-family config, one forward/
train step on CPU, output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.data.pipeline import make_batch_from_specs
from repro.models import build, input_specs
from repro.configs.base import ShapeSpec

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, B=2, S=24):
    spec = ShapeSpec("smoke", S, B, "train")
    sds = input_specs(cfg, spec)
    return make_batch_from_specs(sds, seed=1)


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    api = build(cfg)
    params = api.init(KEY, 64)
    batch = {k: jnp.asarray(v) for k, v in _smoke_batch(cfg).items()}
    loss, grads = jax.value_and_grad(lambda p: api.loss(p, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gleaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all()
               for g in gleaves), f"{arch}: non-finite grads"
    # one optimizer step moves the loss
    from repro.optim import make_optimizer
    opt = make_optimizer(cfg.optimizer, lr=1e-2)
    state = opt.init(params)
    new_params, state, gnorm = opt.update(grads, state, params)
    loss2 = api.loss(new_params, batch)
    assert np.isfinite(float(loss2))
    assert float(gnorm) > 0


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_shapes(arch):
    cfg = reduced(get_config(arch))
    api = build(cfg)
    params = api.init(KEY, 64)
    B, S = 2, 16
    if cfg.encdec:
        batch = {"frames": jnp.ones((B, S, cfg.d_model), "float32"),
                 "tokens": jnp.ones((B, 8), "int32")}
    elif cfg.vlm_stub:
        batch = {"tokens": jnp.ones((B, S), "int32"),
                 "image_embeds": jnp.ones((B, cfg.num_patches, cfg.d_model),
                                          "float32")}
    else:
        batch = {"tokens": jnp.ones((B, S), "int32")}
    logits, cache = api.prefill(params, batch, 32)
    assert logits.shape == (B, 1, cfg.vocab)
    lg2, cache2 = api.decode(params, cache, jnp.ones((B, 1), "int32"))
    assert lg2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg2)).all(), f"{arch}: NaN decode logits"
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma2-9b", "hymba-1.5b",
                                  "mamba2-1.3b", "kimi-k2-1t-a32b",
                                  "whisper-small", "phi-3-vision-4.2b"])
def test_decode_matches_forward(arch):
    """KV/SSM cache correctness: prefill+decode == full forward."""
    cfg = reduced(get_config(arch))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(1), 64)
    B, S = 2, 13
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab)
    if cfg.encdec:
        from repro.models import encdec
        frames = jax.random.normal(jax.random.PRNGKey(3),
                                   (B, 12, cfg.d_model))
        enc = encdec.encode(params, cfg, frames)
        full = encdec.decode_train(params, cfg, toks, enc)
        _, cache = api.prefill(params, {"frames": frames,
                                        "tokens": toks[:, :S]}, 32)
        ref = full[:, S]
    elif cfg.vlm_stub:
        from repro.models import transformer
        img = jax.random.normal(jax.random.PRNGKey(4),
                                (B, cfg.num_patches, cfg.d_model))
        full = transformer.forward(params, cfg, toks, img)
        _, cache = api.prefill(params, {"tokens": toks[:, :S],
                                        "image_embeds": img},
                               cfg.num_patches + S + 4)
        ref = full[:, cfg.num_patches + S]
    else:
        from repro.models import transformer
        full = transformer.forward(params, cfg, toks)
        _, cache = api.prefill(params, {"tokens": toks[:, :S]}, S + 4)
        ref = full[:, S]
    lg, _ = api.decode(params, cache, toks[:, S:S + 1])
    err = float(jnp.abs(lg[:, 0] - ref).max())
    assert err < 2e-3, f"{arch}: decode/forward divergence {err}"


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the assigned hyperparameters."""
    expect = {
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }
    for arch, (L, d, H, K, ff, V) in expect.items():
        c = get_config(arch)
        got_ff = c.moe.d_ff if c.moe else c.d_ff
        assert (c.num_layers, c.d_model, c.num_heads, c.kv_heads,
                got_ff, c.vocab) == (L, d, H, K, ff, V), arch
    assert get_config("arctic-480b").moe.num_experts == 128
    assert get_config("arctic-480b").moe.top_k == 2
    assert get_config("kimi-k2-1t-a32b").moe.num_experts == 384
    assert get_config("kimi-k2-1t-a32b").moe.top_k == 8
    assert get_config("mamba2-1.3b").ssm.d_state == 128
    assert get_config("hymba-1.5b").ssm.d_state == 16
