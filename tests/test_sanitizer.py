"""PoolSanitizer: each protocol violation class, injected deliberately,
must raise PoolSanitizerError at the violating call site — and clean
production flows must stay silent under instrumentation.

Injection pattern: break the instance FIRST (bypass or corrupt the
production method), attach the sanitizer SECOND, trigger THIRD.
"""
import numpy as np
import pytest

from repro.analysis.sanitizer import (PoolSanitizer, PoolSanitizerError,
                                      enable, disable, enabled)
from repro.core.bufferpool import BufferPool, PoolConfig
from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.serving.device_pool import DevicePagePool
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.serving.shard_pool import ShardedPagePool


def _store(num_models=3, l=4):
    # vocab=1024 -> ~10 pages: enough for group loads and a borrow tail
    task = SyntheticTextTask(vocab=1024, d=32, seed=0)
    store, heads = build_store(task, num_models=num_models,
                               block_shape=(32, 32), blocks_per_page=l)
    return task, store, heads


def _pool(store, capacity=None):
    return DevicePagePool(store, capacity or store.num_pages(),
                          kernel_mode="host")


# ------------------------------------------------------------ clean flows --
def test_clean_serving_flow_is_silent():
    """Full engine loop under instrumentation: no violations."""
    task, store, heads = _store()
    san = PoolSanitizer(strict=True)
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"), backend="device",
                          transfer="grouped")
    san.attach_device_pool(server.device_pool)
    san.attach_buffer_pool(server.pool)
    engine = EmbeddingServingEngine(server, heads)
    for b in range(4):
        docs, _ = task.sample(8, variant=b % 3, seed=b)
        engine.submit(f"word2vec-v{b % 3}", docs)
        engine.run(max_batches=1)
    assert san.violations == []
    assert len(san.events) > 0
    assert "0 violations" in san.report()


def test_clean_update_flush_reload_is_silent():
    """Model update -> repack -> flush -> reload: the invalidation path
    is exactly what the sanitizer watches; it must not false-positive."""
    task, store, heads = _store()
    san = PoolSanitizer(strict=True)
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"), backend="device")
    san.attach_device_pool(server.device_pool)
    san.attach_buffer_pool(server.pool)
    engine = EmbeddingServingEngine(server, heads)
    docs, _ = task.sample(8, variant=0, seed=1)
    engine.submit("word2vec-v0", docs)
    engine.run(max_batches=1)
    store.update("word2vec-v0", {"embedding":
                                 task.variant_embedding(0) + 0.5})
    engine.submit("word2vec-v0", docs)
    engine.run(max_batches=1)
    assert san.violations == []


# ------------------------------------------------------- injected: stale --
def test_stale_remap_read_detected():
    """A dev_map minted before a load must not feed gather_rows after
    the slab generation moved on."""
    _, store, _ = _store()
    pool = _pool(store, capacity=store.num_pages())
    vt = store.virtual_tensor("word2vec-v0", "embedding")
    for pid in vt.page_ids[:-1]:
        pool.load(pid)
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool)
    stale_map = pool.remap(vt, strict=False)     # minted at gen g
    pool.load(vt.page_ids[-1])                   # gen bump -> map is stale
    with pytest.raises(PoolSanitizerError, match="stale-remap"):
        pool.gather_rows(stale_map, vt.grid, np.arange(4))


def test_fresh_remap_read_is_silent():
    _, store, _ = _store()
    pool = _pool(store)
    vt = store.virtual_tensor("word2vec-v0", "embedding")
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool)
    for pid in vt.page_ids:
        pool.load(pid)
    dev_map = pool.remap(vt)
    pool.gather_rows(dev_map, vt.grid, np.arange(4))
    assert san.violations == []


def test_cross_pool_remap_read_detected():
    """A remap from pool A consumed by pool B is a wrong-shard read even
    if the generations happen to line up."""
    _, store, _ = _store()
    pool_a, pool_b = _pool(store), _pool(store)
    vt = store.virtual_tensor("word2vec-v0", "embedding")
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool_a)
    san.attach_device_pool(pool_b)
    for pid in vt.page_ids:
        pool_a.load(pid)
        pool_b.load(pid)
    map_a = pool_a.remap(vt)
    with pytest.raises(PoolSanitizerError, match="different pool"):
        pool_b.gather_rows(map_a, vt.grid, np.arange(4))


# ------------------------------------------- injected: generation bumps --
def test_missed_generation_bump_on_load_detected():
    _, store, _ = _store()
    pool = _pool(store)

    def broken_load(pid):                        # admits without bumping
        slot = pool._free.pop()
        pool.slot_of[pid] = slot
        pool._page_to_slot[pid] = slot

    pool.load = broken_load
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool)
    with pytest.raises(PoolSanitizerError, match="missed generation bump"):
        pool.load(0)


def test_missed_generation_bump_on_evict_detected():
    _, store, _ = _store()
    pool = _pool(store)
    pool.load(0)

    def broken_evict(pid):                       # frees without bumping
        slot = pool.slot_of.pop(pid)
        pool._free.append(slot)
        pool._page_to_slot[pid] = -1

    pool.evict = broken_evict
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool)
    with pytest.raises(PoolSanitizerError, match="missed generation bump"):
        pool.evict(0)


def test_group_load_multi_bump_detected():
    """PR 5 contract: ONE grouped load = ONE generation bump."""
    _, store, _ = _store()
    pool = _pool(store)

    def per_page_group(pids):                    # K bumps for one group
        for p in pids:
            DevicePagePool.load(pool, p)

    pool.load_group = per_page_group
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool)
    with pytest.raises(PoolSanitizerError, match="one-group-one-bump"):
        pool.load_group([0, 1, 2])


def test_stage_must_not_bump_generation():
    _, store, _ = _store()
    pool = _pool(store)

    orig_stage = pool.transfer.stage

    def bumping_stage(pids):
        out = orig_stage(pids)
        pool.generation += 1                     # staging leaked a bump
        return out

    pool.transfer.stage = bumping_stage
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool)
    with pytest.raises(PoolSanitizerError, match="stage"):
        pool.transfer.stage([0, 1])


# ------------------------------------------------- injected: double-load --
def test_double_load_detected():
    _, store, _ = _store()
    pool = _pool(store)
    pool.load(0)

    def readmitting_load(pid):                   # skips the residency check
        slot = pool._free.pop()
        pool.slot_of[pid] = slot
        pool._page_to_slot[pid] = slot
        pool.generation += 1

    pool.load = readmitting_load
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool)
    with pytest.raises(PoolSanitizerError, match="double-load"):
        pool.load(0)


def test_slot_aliasing_detected():
    _, store, _ = _store()
    pool = _pool(store)
    pool.load(0)

    def aliasing_load(pid):                      # reuses an occupied slot
        pool.slot_of[pid] = pool.slot_of[0]
        pool.generation += 1

    pool.load = aliasing_load
    san = PoolSanitizer(strict=True)
    san.attach_device_pool(pool)
    with pytest.raises(PoolSanitizerError, match="slot aliasing"):
        pool.load(1)


# ------------------------------------------ injected: evict-while-pinned --
def test_evict_while_pinned_detected():
    cfg = PoolConfig(capacity_pages=2)
    bp = BufferPool(cfg)

    def pinned_blind_victim():                   # ignores the pinned set
        return next(iter(bp.resident))

    bp._pick_victim = pinned_blind_victim
    san = PoolSanitizer(strict=True)
    san.attach_buffer_pool(bp)
    bp.access("m", 0)
    bp.access("m", 1)
    bp._pinned = {0, 1}                          # in-flight access_group
    with pytest.raises(PoolSanitizerError, match="evict-while-pinned"):
        bp.access("m", 2)


def test_clean_buffer_pool_churn_is_silent():
    bp = BufferPool(PoolConfig(capacity_pages=4))
    san = PoolSanitizer(strict=True)
    san.attach_buffer_pool(bp)
    for i in range(64):
        bp.access("m", i % 9)
    assert san.violations == []


# -------------------------------------------- injected: non-owner shard --
def test_non_owner_shard_load_detected():
    _, store, _ = _store()
    sp = ShardedPagePool(store, 2, capacity_per_shard=store.num_pages(),
                         placement="hash")
    san = PoolSanitizer(strict=True)
    san.attach_sharded_pool(sp)
    pl = sp.placement()
    pid = next(p for p in range(store.num_pages())
               if 0 not in pl.shards_of(p))
    with pytest.raises(PoolSanitizerError, match="non-owner shard load"):
        sp.pools[0].load(pid)                    # bypasses _check_owner


def test_owner_shard_load_is_silent():
    _, store, _ = _store()
    sp = ShardedPagePool(store, 2, capacity_per_shard=store.num_pages(),
                         placement="hash")
    san = PoolSanitizer(strict=True)
    san.attach_sharded_pool(sp)
    pl = sp.placement()
    pid = next(p for p in range(store.num_pages())
               if 0 in pl.shards_of(p))
    sp.pools[0].load(pid)
    assert san.violations == []


# ----------------------------------------- injected: borrow-slab aliasing --
def test_borrow_slab_aliasing_detected():
    _, store, _ = _store()
    sp = ShardedPagePool(store, 2, capacity_per_shard=store.num_pages(),
                         placement="hash", borrow_capacity=8)
    pl = sp.placement()
    borrowed = [p for p in range(store.num_pages())
                if 0 not in pl.shards_of(p)][:2]
    assert len(borrowed) == 2

    orig = sp.stage_borrows

    def aliasing_stage(shard, pages, model):
        out = orig(shard, pages, model)
        st = sp._staged[shard]                   # corrupt: collapse slots
        first = next(iter(st.values()))
        for k in st:
            st[k] = first
        return out

    sp.stage_borrows = aliasing_stage
    san = PoolSanitizer(strict=True)
    san.attach_sharded_pool(sp)
    with pytest.raises(PoolSanitizerError, match="borrow-slab aliasing"):
        sp.stage_borrows(0, borrowed, "word2vec-v0")


def test_borrow_of_owned_page_detected():
    _, store, _ = _store()
    sp = ShardedPagePool(store, 2, capacity_per_shard=store.num_pages(),
                         placement="hash", borrow_capacity=8)
    pl = sp.placement()
    owned = next(p for p in range(store.num_pages())
                 if 0 in pl.shards_of(p))

    orig = sp.stage_borrows

    def sneaky_stage(shard, pages, model):       # stages an owned page
        out = orig(shard, [p for p in pages if shard
                           not in pl.shards_of(p)], model)
        sp._staged[shard][owned] = 7
        return out

    sp.stage_borrows = sneaky_stage
    san = PoolSanitizer(strict=True)
    san.attach_sharded_pool(sp)
    with pytest.raises(PoolSanitizerError, match="owned by this shard"):
        sp.stage_borrows(0, [owned], "word2vec-v0")


# ------------------------------------------------------- non-strict mode --
def test_non_strict_mode_accumulates():
    _, store, _ = _store()
    pool = _pool(store)

    def broken_load(pid):
        slot = pool._free.pop()
        pool.slot_of[pid] = slot
        pool._page_to_slot[pid] = slot

    pool.load = broken_load
    san = PoolSanitizer(strict=False)
    san.attach_device_pool(pool)
    pool.load(0)
    pool.load(1)
    assert len(san.violations) >= 2
    assert "VIOLATION" in san.report()


# ------------------------------------------------------- global enable() --
def test_enable_instruments_new_pools():
    was_on = enabled() is not None               # REPRO_SANITIZE=1 run
    if was_on:
        disable()
    san = enable(strict=True)
    try:
        assert enabled() is san
        assert enable() is san                   # idempotent
        _, store, _ = _store()
        pool = _pool(store)
        assert getattr(pool, "_repro_sanitizer", None) is san
        bp = BufferPool(PoolConfig(capacity_pages=4))
        assert getattr(bp, "_repro_sanitizer", None) is san
        sp = ShardedPagePool(store, 2,
                             capacity_per_shard=store.num_pages(),
                             placement="hash")
        assert getattr(sp, "_repro_sanitizer", None) is san
        pool.load(0)
        assert any(e.op == "load" for e in san.events)
    finally:
        disable()
        if was_on:                               # restore the env switch
            enable(strict=True)
    assert (enabled() is not None) == was_on
