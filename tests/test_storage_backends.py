"""PageBackend API: cross-backend round trips, orphan pruning, crash
safety, lazy paged opens, grouped fetches, calibration, and the DedupDB
facade."""
import os

import numpy as np
import pytest

from repro.core import (DedupConfig, LSHConfig, ModelStore, StoreConfig,
                        load_store_tensors)
from repro.db import DedupDB
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.storage import (LocalDirBackend, MemoryBackend,
                           ObjectStoreSimBackend, PageBackend,
                           SQLiteBackend, open_backend)

BACKENDS = ("file", "sqlite", "objsim")


def make_backend(kind: str, tmp_path) -> PageBackend:
    if kind == "file":
        return LocalDirBackend(str(tmp_path / "store"))
    if kind == "sqlite":
        return SQLiteBackend(str(tmp_path / "models.db"))
    if kind == "objsim":
        return ObjectStoreSimBackend(
            LocalDirBackend(str(tmp_path / "obj_store")))
    raise ValueError(kind)


def _store(l=4, block=16):
    return ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(block, block),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=l))


def _variants(n=3, shape=(64, 64), noise=1e-4, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    base = rng.standard_normal(shape).astype(np.float32)
    return {f"m{i}": {"w": (base + rng.standard_normal(shape)
                            .astype(np.float32) * noise * i).astype(dtype)}
            for i in range(n)}


def _bits(x: np.ndarray) -> np.ndarray:
    """Bit view for exact comparison across any float dtype (bf16-safe)."""
    return x.view(f"u{x.dtype.itemsize}")


def _dtypes():
    out = [np.dtype(np.float32), np.dtype(np.float16)]
    try:
        import ml_dtypes
        out.append(np.dtype(ml_dtypes.bfloat16))
    except ImportError:
        pass
    return out


# ------------------------------------------------------ round-trip matrix --
@pytest.mark.parametrize("kind", BACKENDS)
@pytest.mark.parametrize("dtype", _dtypes(), ids=lambda d: d.name)
def test_roundtrip_matrix_bit_exact(kind, dtype, tmp_path):
    """register -> save -> open -> materialize is bit-exact per dtype,
    for every backend (the paper's lossless-storage contract)."""
    store = _store()
    models = _variants(dtype=dtype)
    for name, t in models.items():
        store.register(name, t)
    backend = make_backend(kind, tmp_path)
    manifest = store.save(backend)
    assert manifest["page_dtype"] == dtype.name   # no float32 detour
    back = ModelStore.open(backend)
    for name in models:
        a = store.materialize(name, "w")
        b = back.materialize(name, "w")
        assert a.dtype == dtype and b.dtype == dtype
        assert np.array_equal(_bits(a), _bits(b))
    # content dedup in the backend: stored pages <= packed pages
    assert len(backend.list_pages()) <= store.num_pages()


@pytest.mark.parametrize("kind", BACKENDS)
def test_roundtrip_randomized_property(kind, tmp_path):
    """Randomized round-trip sweep: varying shapes/noise/model counts all
    reopen bit-exact (the cheap deterministic stand-in for hypothesis)."""
    rng = np.random.default_rng(7)
    for trial in range(3):
        shape = (int(rng.integers(2, 5)) * 16, int(rng.integers(2, 5)) * 16)
        store = _store()
        models = _variants(n=int(rng.integers(2, 5)), shape=shape,
                           noise=float(rng.uniform(1e-5, 1e-3)),
                           seed=100 + trial)
        for name, t in models.items():
            store.register(name, t)
        backend = make_backend(kind, tmp_path / f"t{trial}")
        store.save(backend)
        back = ModelStore.open(backend)
        for name in models:
            assert np.array_equal(store.materialize(name, "w"),
                                  back.materialize(name, "w"))


# --------------------------------------------------------- orphan pruning --
@pytest.mark.parametrize("kind", BACKENDS)
def test_save_prunes_orphaned_pages(kind, tmp_path):
    """save -> repack (new model) -> save leaves no pages from the old
    packing generation behind (the historical orphan leak)."""
    backend = make_backend(kind, tmp_path)
    store = _store()
    models = _variants(2)
    for name, t in models.items():
        store.register(name, t)
    m1 = store.save(backend)
    assert set(backend.list_pages()) == {p["hash"] for p in m1["pages"]}
    # register a dissimilar model: repack renames/extends the page set
    rng = np.random.default_rng(42)
    store.register("mx", {"w": rng.standard_normal((64, 64))
                          .astype(np.float32)})
    m2 = store.save(backend)
    assert {p["hash"] for p in m2["pages"]} != {p["hash"]
                                                for p in m1["pages"]}
    assert set(backend.list_pages()) == {p["hash"] for p in m2["pages"]}
    # and the store still reopens cleanly after the prune
    back = ModelStore.open(backend)
    assert np.array_equal(back.materialize("mx", "w"),
                          store.materialize("mx", "w"))


# ----------------------------------------------------------- crash safety --
def test_localdir_interrupted_commit_keeps_previous_manifest(tmp_path,
                                                             monkeypatch):
    backend = LocalDirBackend(str(tmp_path / "store"))
    store = _store()
    for name, t in _variants().items():
        store.register(name, t)
    store.save(backend)

    import repro.storage.localdir as localdir_mod
    real_replace = os.replace

    def crash_on_manifest(src, dst):
        if dst.endswith("manifest.json"):
            raise OSError("simulated crash mid-commit")
        return real_replace(src, dst)

    monkeypatch.setattr(localdir_mod.os, "replace", crash_on_manifest)
    other = _store()
    other.register("fresh", {"w": np.ones((64, 64), np.float32)})
    with pytest.raises(OSError):
        other.save(backend)
    monkeypatch.undo()
    # the previous manifest survived the torn commit
    back = ModelStore.open(backend)
    assert set(back.dedup.models) == {"m0", "m1", "m2"}
    assert np.array_equal(back.materialize("m0", "w"),
                          store.materialize("m0", "w"))


def test_sqlite_interrupted_commit_rolls_back(tmp_path):
    backend = SQLiteBackend(str(tmp_path / "models.db"))
    store = _store()
    for name, t in _variants().items():
        store.register(name, t)
    store.save(backend)

    def crash():
        raise RuntimeError("simulated crash before COMMIT")

    backend._pre_commit_hook = crash
    other = _store()
    other.register("fresh", {"w": np.ones((64, 64), np.float32)})
    with pytest.raises(RuntimeError):
        other.save(backend)
    backend._pre_commit_hook = None
    # transaction rolled back: previous relational manifest intact
    back = ModelStore.open(backend)
    assert set(back.dedup.models) == {"m0", "m1", "m2"}
    assert np.array_equal(back.materialize("m1", "w"),
                          store.materialize("m1", "w"))


# ------------------------------------------------------- live paged opens --
def test_open_is_lazy_and_faults_grouped(tmp_path):
    """open() densifies nothing; serving faults pages in grouped backend
    calls; a single page_array touch fetches only that page."""
    inner = SQLiteBackend(str(tmp_path / "models.db"))
    backend = ObjectStoreSimBackend(inner)     # counts get_pages calls
    store = _store()
    for name, t in _variants(4, noise=3e-1).items():
        store.register(name, t)
    store.save(backend)

    back = ModelStore.open(backend)
    assert backend.get_calls == 0              # nothing fetched at open
    assert len(back._unfetched) == back.num_pages()
    back.page_array(0)
    assert backend.get_calls == 1
    assert len(back._unfetched) == back.num_pages() - 1
    # grouped miss path: one get_pages for a whole page-id group
    back2 = ModelStore.open(backend)
    calls0 = backend.get_calls
    fetched = back2.fault_pages(range(back2.num_pages()))
    assert fetched == back2.num_pages()
    assert backend.get_calls == calls0 + 1
    assert back2.fault_pages(range(back2.num_pages())) == 0  # idempotent


def test_numpy_rows_path_stays_paged(tmp_path):
    """materialize_rows on an opened store faults only the pages covering
    the touched row blocks — the numpy serving path must not densify the
    whole store for one batch."""
    backend = ObjectStoreSimBackend(SQLiteBackend(str(tmp_path / "m.db")))
    store = _store()
    models = _variants(4, noise=3e-1)
    for name, t in models.items():
        store.register(name, t)
    store.save(backend)

    back = ModelStore.open(backend)
    rows = np.array([0, 1, 5])
    got = back.materialize_rows("m0", "w", rows)
    want = store.materialize("m0", "w")[rows]
    assert np.allclose(got, want, atol=1e-6)
    assert backend.get_calls == 1              # one grouped fetch
    assert back._unfetched                     # other pages still remote
    # the full-store paths still work afterwards
    assert np.array_equal(back.materialize("m3", "w"),
                          store.materialize("m3", "w"))


def test_register_after_open_dedups_against_reloaded_blocks(tmp_path):
    """A store reopened from a backend stays *live*: registering a new
    near-duplicate variant dedups against the reloaded distinct blocks
    (LSH index rebuilt), and the next save commits the merged set."""
    backend = SQLiteBackend(str(tmp_path / "models.db"))
    store = _store()
    models = _variants()
    for name, t in models.items():
        store.register(name, t)
    store.save(backend)

    back = ModelStore.open(backend)
    res = back.register("m_new", {"w": models["m0"]["w"]
                                  + np.float32(1e-5)})
    assert res.deduped_blocks > 0              # found the reloaded blocks
    back.save(backend)
    again = ModelStore.open(backend)
    assert set(again.dedup.models) == {"m0", "m1", "m2", "m_new"}
    assert np.allclose(again.materialize("m_new", "w"),
                       models["m0"]["w"], atol=1e-2)


def test_device_serving_from_opened_store_matches_numpy(tmp_path):
    """End-to-end: device-backend serving out of a reopened SQLite store
    produces the same logits as numpy serving from the original
    in-memory store; slab faults source pages through the backend."""
    from repro.data.pipeline import SyntheticTextTask
    from repro.launch.serve import build_store

    task = SyntheticTextTask(vocab=512, d=32, seed=0)
    store, heads = build_store(task, num_models=3, block_shape=(32, 32),
                               blocks_per_page=4)
    url = f"sqlite:///{tmp_path}/models.db"
    store.save(url)

    db = DedupDB.open(url)
    engine = db.serve_embedding(heads, capacity_pages=12,
                                compute_backend="device", overlap=True)
    ref = EmbeddingServingEngine(
        WeightServer(store, 12, storage=StorageModel("ssd")), heads)
    rng = np.random.default_rng(5)
    for b in range(6):
        v = int(rng.integers(0, 3))
        docs, _ = task.sample(32, variant=v, seed=300 + b)
        engine.submit(f"word2vec-v{v}", docs)
        ref.submit(f"word2vec-v{v}", docs)
    stats = engine.run()
    ref.run()
    assert stats.device_batches > 0
    assert np.allclose(engine.last_logits, ref.last_logits, atol=1e-4)
    db.close()


# ------------------------------------------------- URL factory + presets --
def test_open_backend_url_grammar(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)               # relative sqlite paths land here
    assert isinstance(open_backend(str(tmp_path / "bare")), LocalDirBackend)
    assert isinstance(open_backend(f"file://{tmp_path}/f"), LocalDirBackend)
    b = open_backend("sqlite:///rel.db")
    assert isinstance(b, SQLiteBackend)       # sqlite:/// is relative-style
    assert b.path == "rel.db"
    b2 = open_backend(f"sqlite:////{str(tmp_path)[1:]}/abs.db")
    assert isinstance(b2, SQLiteBackend)
    assert os.path.isabs(b2.path)
    o = open_backend("objsim://?seek_ms=30&bandwidth_mbps=100")
    assert isinstance(o, ObjectStoreSimBackend)
    assert o.seek == pytest.approx(30e-3)
    assert o.bandwidth == pytest.approx(100e6)
    assert isinstance(open_backend("memory://"), MemoryBackend)
    assert isinstance(open_backend(MemoryBackend()), MemoryBackend)
    with pytest.raises(ValueError):
        open_backend("s3://bucket/key")
    # backends round-trip through their own URL, inner type included
    assert isinstance(open_backend(o.url()), ObjectStoreSimBackend)
    o_dir = ObjectStoreSimBackend(LocalDirBackend(str(tmp_path / "od")),
                                  seek=2e-3)
    r = open_backend(o_dir.url())
    assert isinstance(r.inner, LocalDirBackend)
    assert r.seek == pytest.approx(2e-3)
    o_db = ObjectStoreSimBackend(SQLiteBackend(str(tmp_path / "rt.db")))
    r2 = open_backend(o_db.url())
    assert isinstance(r2.inner, SQLiteBackend)
    assert os.path.abspath(r2.inner.path) == str(tmp_path / "rt.db")


def test_storage_model_calibration_from_backend():
    """Microbench calibration replaces the hardcoded presets: the object
    store sim reports its injected parameters exactly, and fetch costs
    order correctly against a fast local tier."""
    slow = ObjectStoreSimBackend(seek=30e-3, bandwidth=100e6)
    sm = StorageModel.from_backend(slow)
    assert sm.seek == pytest.approx(30e-3)
    assert sm.bw == pytest.approx(100e6)
    assert sm.kind == "calibrated:objsim"
    fast = StorageModel.from_backend(MemoryBackend())
    nbytes = 1 << 20
    assert sm.fetch_seconds(nbytes) > fast.fetch_seconds(nbytes)
    # grouped fetch amortizes the (large, injected) seek
    assert sm.fetch_group_seconds(nbytes, 4) < 4 * sm.fetch_seconds(nbytes)
    with pytest.raises(ValueError):
        StorageModel("not-a-preset")


def test_weight_server_page_bytes_tracks_page_dtype():
    store = _store()
    for name, t in _variants(dtype=np.float16).items():
        store.register(name, t)
    fp16_bytes = WeightServer(store, 2).page_bytes
    store32 = _store()
    for name, t in _variants(dtype=np.float32).items():
        store32.register(name, t)
    assert WeightServer(store32, 2).page_bytes == 2 * fp16_bytes


# ------------------------------------------------------------- the facade --
def test_dedupdb_facade_lifecycle(tmp_path):
    """open (fresh) -> register -> commit -> reopen -> update -> commit
    -> serve, all through the facade."""
    url = f"sqlite:///{tmp_path}/db.sqlite"
    db = DedupDB.open(url)
    models = _variants()
    for name, t in models.items():
        db.register(name, t)
    manifest = db.commit()
    assert set(manifest["models"]) == {"m0", "m1", "m2"}
    db.close()

    db2 = DedupDB.open(url)
    assert db2.models() == ["m0", "m1", "m2"]
    new_w = {"w": models["m1"]["w"] + np.float32(0.5)}
    db2.update("m1", new_w)
    db2.commit()
    assert np.allclose(db2.store.materialize("m1", "w"), new_w["w"],
                       atol=1e-5)

    db3 = DedupDB.open(url)
    heads = {m: np.eye(64, 8, dtype=np.float32) for m in db3.models()}
    engine = db3.serve_embedding(heads, embed_tensor="w", capacity_pages=4)
    rng = np.random.default_rng(0)
    for m in db3.models():
        engine.submit(m, rng.integers(0, 64, size=(4, 6)))
    stats = engine.run()
    assert stats.batches == 3
    assert engine.server.pool.hits + engine.server.pool.misses > 0
    # miss charging came from the calibrated model, not a preset
    assert engine.server.storage.kind.startswith("calibrated:")
    db3.close()


def test_legacy_path_api_still_works(tmp_path):
    """Back-compat shims: save(path-string) and load_store_tensors(path)
    keep working against the same on-disk layout as before."""
    store = _store()
    models = _variants()
    for name, t in models.items():
        store.register(name, t)
    store.save(str(tmp_path))
    assert (tmp_path / "manifest.json").exists()
    assert any(f.startswith("page-") for f in os.listdir(tmp_path))
    back = load_store_tensors(str(tmp_path))
    for name in models:
        assert np.allclose(back[name]["w"], store.materialize(name, "w"))


# ------------------------------------------------- concurrent writers ------
def test_sqlite_two_writer_commit_conflict(tmp_path):
    """Satellite (multi-backend remainder): optimistic locking on the
    SQLite commit counter.  Two handles on one database; the writer that
    commits second on a stale view gets a typed ManifestConflictError,
    its transaction rolls back (winner's manifest intact), and a reload
    + retry succeeds."""
    from repro.storage import ManifestConflictError

    path = str(tmp_path / "models.db")
    store = _store()
    for name, tensors in _variants(2).items():
        store.register(name, tensors)
    a = SQLiteBackend(path)
    store.save(a)                          # version 1, seen by A

    b = SQLiteBackend(path)                # second writer
    manifest_b = b.load_manifest()         # observes version 1
    manifest_a = a.load_manifest()

    # A commits a mutation first (drops one model from the manifest)
    m2 = dict(manifest_a)
    m2["models"] = {k: v for k, v in manifest_a["models"].items()
                    if k == "m0"}
    a.commit_manifest(m2)                  # version 2

    # B's view is stale: its commit must conflict, not clobber
    with pytest.raises(ManifestConflictError):
        b.commit_manifest(manifest_b)
    assert sorted(b.load_manifest()["models"]) == ["m0"]   # winner intact

    # reload adopted the new version: retry on top of it succeeds
    b.commit_manifest(manifest_b)
    assert sorted(a.load_manifest()["models"]) == ["m0", "m1"]
    a.close()
    b.close()


def test_sqlite_store_save_propagates_conflict(tmp_path):
    """ModelStore.save through a stale handle surfaces the typed error
    (no silent lost update at the store layer either)."""
    from repro.storage import ManifestConflictError

    path = str(tmp_path / "models.db")
    store = _store()
    for name, tensors in _variants(2).items():
        store.register(name, tensors)
    a = SQLiteBackend(path)
    store.save(a)

    b = SQLiteBackend(path)
    other = ModelStore.open(b)             # live store on handle B

    store.register("m9", _variants(1, seed=9)["m0"])
    store.save(a)                          # A commits again

    other.register("mX", _variants(1, seed=7)["m0"])
    with pytest.raises(ManifestConflictError):
        other.save(b)                      # stale: must not clobber A
    b.load_manifest()                      # adopt A's commit...
    other.save(b)                          # ...then the retry lands
    names = sorted(SQLiteBackend(path).load_manifest()["models"])
    assert "mX" in names
    a.close()
    b.close()
