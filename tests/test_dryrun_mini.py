"""Dry-run machinery test: spawns subprocesses with a mini 8-device host
platform (REPRO_DRYRUN_DEVICES) — the main test process keeps 1 device."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run_cell(tmp, arch, shape, multi=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_DRYRUN_DEVICES"] = "8"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mini", "--out", tmp]
    if multi:
        cmd.append("--multi-pod")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    mesh = "multi" if multi else "single"
    path = os.path.join(tmp, f"{arch}__{shape}__{mesh}.json")
    with open(path) as f:
        return json.load(f)


@pytest.mark.slow
def test_train_cell_single_pod(tmp_path):
    rec = _run_cell(str(tmp_path), "deepseek-7b", "train_4k")
    assert rec["status"] == "ok"
    assert rec["cost_analysis"]["flops"] > 0
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                           "collective_s")
    assert rec["collectives"]["total"] > 0          # sharded -> collectives
    assert rec["memory_analysis"]["argument_size_in_bytes"] > 0


@pytest.mark.slow
def test_decode_cell_multi_pod(tmp_path):
    rec = _run_cell(str(tmp_path), "mamba2-1.3b", "decode_32k", multi=True)
    assert rec["status"] == "ok"
    assert rec["meta"]["mesh"] == "2x2x2"


@pytest.mark.slow
def test_skip_rule_recorded(tmp_path):
    rec = _run_cell(str(tmp_path), "qwen3-14b", "long_500k")
    assert rec["status"] == "skipped"
    assert "full-attention" in rec["reason"]


def test_main_process_has_one_device():
    import jax
    assert jax.device_count() == 1


def test_production_mesh_shapes():
    """Pure metadata check (no devices needed)."""
    from repro.configs import SHAPES, get_config, list_archs, shape_supported
    cells = [(a, s) for a in list_archs() for s in SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells if shape_supported(get_config(c[0]),
                                                    c[1])[0]]
    assert len(runnable) == 32             # 8 long_500k skips
