import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (_fit_rank, cache_specs, make_recipe,
                                        param_spec, param_specs,
                                        sanitize_spec, use_recipe)


def test_param_rules():
    rec = make_recipe("train")
    assert param_spec("blocks/attn/wq", 3, rec) == P(None, "data", "model")
    assert param_spec("blocks/attn/wo", 3, rec) == P(None, "model", "data")
    assert param_spec("blocks/mlp/w2", 3, rec) == P(None, "model", "data")
    assert param_spec("blocks/moe/ew1", 4, rec) == P(None, "model", "data",
                                                     None)
    assert param_spec("embed", 2, rec) == P("model", "data")
    assert param_spec("blocks/ln1/scale", 2, rec) == P()
    assert param_spec("blocks/mamba/in_proj", 3, rec) == \
        P(None, "data", "model")
    assert param_spec("dec_blocks/cross/cq", 3, rec) == \
        P(None, "data", "model")


def test_param_specs_tree_structure():
    params = {"embed": np.zeros((16, 8)),
              "blocks": {"attn": {"wq": np.zeros((2, 8, 8))},
                         "ln1": {"scale": np.zeros((2, 8))}}}
    specs = param_specs(params, make_recipe("train"))
    assert specs["embed"] == P("model", "data")
    assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model")


class _FakeMesh:
    shape = {"data": 4, "model": 2, "pod": 2}


def test_sanitize_spec_drops_nondivisible():
    mesh = _FakeMesh()
    assert sanitize_spec(P("data", None), (8, 3), mesh) == P("data", None)
    assert sanitize_spec(P("data", None), (6, 3), mesh) == P(None, None)
    assert sanitize_spec(P(("pod", "data"), None), (8, 3), mesh) == \
        P(("pod", "data"), None)
    # 4 % (2*4) != 0 but 4 % 2 == 0 -> keep only the leading axis
    assert sanitize_spec(P(("pod", "data"),), (4,), mesh) == P("pod")


def test_fit_rank():
    assert _fit_rank(P("data", None, "model"), 2) == P("data", "model")
    assert _fit_rank(P("data",), 3) == P("data", None, None)


def test_hint_identity_without_recipe():
    from repro.distributed.sharding import hint
    x = np.ones((4, 4))
    assert hint(x, "residual") is x


def test_recipe_modes():
    for mode in ("train", "prefill", "decode"):
        rec = make_recipe(mode, multi_pod=True)
        assert rec.dp == ("pod", "data")
        assert rec.site("residual") is not None
    with pytest.raises(ValueError):
        make_recipe("nope")


def test_cache_specs_structure():
    cache = {"pos": np.zeros(()),
             "blocks": {"k": np.zeros((2, 1, 8, 2, 4)),
                        "v": np.zeros((2, 1, 8, 2, 4)),
                        "ssm_state": np.zeros((2, 1, 4, 4, 4)),
                        "conv_state": np.zeros((2, 1, 3, 8))}}
    rec = make_recipe("decode")
    specs = cache_specs(cache, rec)
    assert specs["blocks"]["k"] == P(None, ("data",), "model", None, None)
    assert specs["pos"] == P()
