"""Fault injection + the end-to-end recovery layer (DESIGN.md §8):
seeded schedules, the typed taxonomy and retry policy, store-level
verify/quarantine/refetch, SQLite lock contention, pool consistency
after mid-load failures, engine degradation, and the chaos acceptance
runs — bit-exact logits under injected faults on the embedding and LM
paths, single-slab and 2-shard (with a mid-run shard failover)."""
import sqlite3
import threading
import time

import numpy as np
import pytest

from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.serving.engine import (EmbeddingServingEngine, StorageModel,
                                  WeightServer)
from repro.serving.shard_pool import ShardedWeightServer
from repro.storage import (ManifestConflictError, MemoryBackend,
                           SQLiteBackend, open_backend)
from repro.storage.faults import (CorruptPageError, FatalStorageError,
                                  FaultInjectingBackend, FaultSpec,
                                  RetryPolicy, StorageFaultError,
                                  TransientStorageError, fault_layer,
                                  global_fault_spec, is_transient,
                                  set_global_fault_spec)


def _store(l=4, block=16):
    return ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(block, block),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=l))


def _variants(n=2, shape=(64, 64), seed=0):
    rng = np.random.default_rng(seed)
    base = rng.standard_normal(shape).astype(np.float32)
    return {f"m{i}": {"w": base + np.float32(1e-4) * i} for i in range(n)}


def _saved(n=2):
    """A populated store committed to a MemoryBackend (the clean inner
    tier every chaos wrapper composes over)."""
    store = _store()
    tensors = _variants(n)
    for name, ts in tensors.items():
        store.register(name, ts)
    inner = MemoryBackend()
    store.save(inner)
    return store, tensors, inner


# ----------------------------------------------------------- spec grammar --
def test_fault_spec_parse_and_str_roundtrip():
    spec = FaultSpec.parse("transient=0.1,corrupt=0.05,lock=0.2,"
                           "torn=0.02,latency=0.3,latency_ms=2.5,"
                           "seed=7,max_consecutive=3")
    assert spec.transient == 0.1 and spec.corrupt == 0.05
    assert spec.lock == 0.2 and spec.torn == 0.02
    assert spec.latency == 0.3 and spec.latency_ms == 2.5
    assert spec.seed == 7 and spec.max_consecutive == 3
    # str() emits only non-default fields and parses back to equality
    assert FaultSpec.parse(str(spec)) == spec
    assert FaultSpec.parse("") == FaultSpec()
    assert not FaultSpec.parse("").any_faults()
    assert FaultSpec.parse(spec) is spec            # idempotent
    assert FaultSpec.parse(None) == FaultSpec()


def test_fault_spec_parse_rejects_garbage():
    with pytest.raises(ValueError):
        FaultSpec.parse("transient")                # no '='
    with pytest.raises(ValueError):
        FaultSpec.parse("bogus_knob=1.0")           # unknown key
    with pytest.raises(ValueError):
        FaultSpec.parse("transient=lots")           # not a float


def test_is_transient_classification():
    assert is_transient(TransientStorageError("x"))
    assert is_transient(sqlite3.OperationalError("database is locked"))
    assert not is_transient(sqlite3.OperationalError("no such table: t"))
    assert not is_transient(ManifestConflictError("stale"))
    assert not is_transient(ValueError("x"))
    assert not is_transient(CorruptPageError("x"))


def test_fault_url_grammar_and_roundtrip():
    b = open_backend("fault+memory://#transient=0.1,seed=7")
    assert isinstance(b, FaultInjectingBackend)
    assert isinstance(b.inner, MemoryBackend)
    assert b.spec.transient == 0.1 and b.spec.seed == 7
    # wrapper URLs round-trip through open_backend, spec included
    r = open_backend(b.url())
    assert isinstance(r, FaultInjectingBackend)
    assert r.spec == b.spec
    # fault_layer resolves through composition chains
    assert fault_layer(b) is b
    assert fault_layer(MemoryBackend()) is None


# ------------------------------------------------------------- injection --
def test_injection_schedule_is_deterministic():
    """Same spec + same call sequence -> identical faults, bit for bit
    (including which page corrupted and which bit flipped)."""
    def run():
        _, _, inner = _saved()
        fb = FaultInjectingBackend(
            inner, "transient=0.3,corrupt=0.3,latency=0.5,seed=42")
        hashes = list(inner.list_pages())
        events, got = [], {}
        for _ in range(6):
            try:
                got = fb.get_pages(hashes)
                events.append("ok")
            except TransientStorageError:
                events.append("transient")
        return events, dict(fb.injected), \
            np.concatenate([got[h].reshape(-1) for h in sorted(got)])

    ev_a, inj_a, bytes_a = run()
    ev_b, inj_b, bytes_b = run()
    assert ev_a == ev_b
    assert inj_a == inj_b and sum(inj_a.values()) > 0
    np.testing.assert_array_equal(bytes_a, bytes_b)


def test_transient_injection_forced_success_after_cap():
    """max_consecutive bounds every failure run: two injected failures,
    then the op is forced clean — the property that makes bounded
    retries convergent by construction."""
    _, _, inner = _saved()
    fb = FaultInjectingBackend(inner, "transient=1.0,max_consecutive=2")
    hashes = list(inner.list_pages())
    for _ in range(2):
        with pytest.raises(TransientStorageError):
            fb.get_pages(hashes)
    got = fb.get_pages(hashes)                      # forced clean
    assert sorted(got) == sorted(hashes)
    assert fb.injected["transient"] == 2


def test_corruption_is_on_a_copy_inner_stays_clean():
    """A bit flip lands on a copy: the quarantine refetch must be able
    to observe the true bytes from the inner tier."""
    _, _, inner = _saved()
    fb = FaultInjectingBackend(inner, "corrupt=1.0,max_consecutive=2")
    hashes = sorted(inner.list_pages())
    clean = inner.get_pages(hashes)
    got = fb.get_pages(hashes)
    assert fb.injected["corrupt"] >= 1
    assert any(not np.array_equal(got[h], clean[h]) for h in hashes)
    # the inner tier never saw the flip
    again = inner.get_pages(hashes)
    for h in hashes:
        np.testing.assert_array_equal(again[h], clean[h])


def test_lock_and_torn_commit_injection():
    _, _, inner = _saved()
    lock = FaultInjectingBackend(inner, "lock=1.0,max_consecutive=1")
    manifest = inner.load_manifest()
    with pytest.raises(sqlite3.OperationalError) as ei:
        lock.commit_manifest(manifest)
    assert is_transient(ei.value)                   # classifier, not type
    lock.commit_manifest(manifest)                  # forced clean

    # torn commit: the write LANDS, only the ack is lost — the error is
    # transient and the blind re-commit must be idempotent
    torn = FaultInjectingBackend(inner, "torn=1.0,max_consecutive=1")
    m2 = dict(manifest)
    with pytest.raises(TransientStorageError):
        torn.commit_manifest(m2)
    assert inner.load_manifest()["pages"] == manifest["pages"]
    torn.commit_manifest(m2)                        # idempotent retry


def test_latency_spikes_accumulate_and_drain_virtually():
    _, _, inner = _saved()
    fb = FaultInjectingBackend(inner, "latency=1.0,latency_ms=5.0")
    hashes = list(inner.list_pages())
    t0 = time.perf_counter()
    fb.get_pages(hashes)
    fb.get_pages(hashes)
    wall = time.perf_counter() - t0
    drained = fb.drain_injected_latency()
    assert drained == pytest.approx(2 * 5e-3)
    assert fb.drain_injected_latency() == 0.0       # drain resets
    assert wall < 1.0                               # spikes never sleep


def test_bench_scratch_pages_exempt_from_injection():
    """Calibration is not traffic: zbench- pages bypass the schedule."""
    inner = MemoryBackend()
    inner.put_pages({"zbench-0": np.zeros(8, np.float32)})
    fb = FaultInjectingBackend(inner, "transient=1.0,corrupt=1.0,"
                               "max_consecutive=0")
    for _ in range(5):
        got = fb.get_pages(["zbench-0"])            # never raises
        assert not got["zbench-0"].any()
    assert fb.injected == {}


# ----------------------------------------------------------- retry policy --
def test_retry_policy_recovers_and_charges_virtual_backoff():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientStorageError("flap")
        return "ok"

    t0 = time.perf_counter()
    result, outcome = RetryPolicy(max_retries=4).run(flaky)
    assert result == "ok" and calls["n"] == 3
    assert outcome.retries == 2
    assert outcome.backoff_seconds > 0.0            # charged, not slept
    assert time.perf_counter() - t0 < 0.5


def test_retry_policy_exhaustion_is_fatal_and_chained():
    def always():
        raise TransientStorageError("down")

    with pytest.raises(FatalStorageError) as ei:
        RetryPolicy(max_retries=2).run(always, describe="probe")
    assert isinstance(ei.value.__cause__, TransientStorageError)
    assert "probe" in str(ei.value)


def test_retry_policy_passes_through_non_transient():
    def conflict():
        raise ManifestConflictError("stale view")

    # hard conflicts must surface on attempt 1 — blind re-commit on a
    # stale manifest is exactly the bug the taxonomy exists to prevent
    with pytest.raises(ManifestConflictError):
        RetryPolicy(max_retries=5).run(conflict)
    with pytest.raises(ValueError):
        RetryPolicy().run(lambda: (_ for _ in ()).throw(ValueError("x")))


def test_retry_policy_virtual_timeout_budget():
    def always():
        raise TransientStorageError("down")

    with pytest.raises(FatalStorageError) as ei:
        RetryPolicy(max_retries=10_000, backoff_base=0.4,
                    call_timeout=1.0).run(always)
    assert "budget" in str(ei.value)


# --------------------------------------------------------- chaos attach --
def test_global_spec_wraps_url_opens_only(tmp_path, monkeypatch):
    """REPRO_FAULTS / set_global_fault_spec wrap backends at the URL
    resolution attach points ONLY — an explicitly constructed backend
    instance is never wrapped (exact call-count tests stay exact)."""
    store, _, inner = _saved()
    dest = str(tmp_path / "store")
    store.save(dest)
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    set_global_fault_spec(None)
    try:
        assert global_fault_spec() is None
        assert fault_layer(ModelStore.open(dest).backend) is None

        set_global_fault_spec("transient=0.2,seed=3")
        fl = fault_layer(ModelStore.open(dest).backend)
        assert fl is not None and fl.spec.transient == 0.2
        # instance attach point: never wrapped, even in chaos mode
        assert fault_layer(ModelStore.open(inner).backend) is None

        # env fallback, and the programmatic override beats it
        set_global_fault_spec(None)
        monkeypatch.setenv("REPRO_FAULTS", "corrupt=0.5")
        assert global_fault_spec().corrupt == 0.5
        set_global_fault_spec("corrupt=0.25")
        assert global_fault_spec().corrupt == 0.25
    finally:
        set_global_fault_spec(None)


# ------------------------------------------------------- store recovery --
def test_store_verifies_quarantines_and_refetches_corrupt_pages():
    """Opt-in sha256 verification (auto-on behind a fault layer): bit
    flips are detected, the bad pages are re-fetched as their own
    grouped call, and the served bytes are the TRUE bytes."""
    store, tensors, inner = _saved()
    fb = FaultInjectingBackend(inner, "corrupt=0.6,seed=5")
    opened = ModelStore.open(fb)
    assert opened._verification_enabled()           # auto: fault layer on
    opened.fault_all()
    fs = opened.fault_stats
    assert fs.corrupt_detected > 0
    assert fs.refetch_pages > 0
    # recovery serves exactly what a clean open serves (the store is
    # approximately deduplicated, so the reference is the dedup'd
    # bytes, not the raw registered tensors)
    clean = ModelStore.open(inner)
    for model, ts in tensors.items():
        for name in ts:
            np.testing.assert_array_equal(
                opened.materialize(model, name),
                clean.materialize(model, name))


def test_naive_store_serves_corrupt_bytes():
    """The same schedule with verification forced off silently serves
    flipped bytes — the load-bearing proof for the recovery layer."""
    store, tensors, inner = _saved()
    fb = FaultInjectingBackend(inner, "corrupt=0.6,seed=5")
    opened = ModelStore.open(fb)
    opened.verify_pages = False
    opened.retry_policy = RetryPolicy(max_retries=0)
    try:
        opened.fault_all()
        served = np.concatenate([
            opened.materialize(m, t).reshape(-1)
            for m, ts in tensors.items() for t in ts])
        clean = ModelStore.open(inner)
        truth = np.concatenate([
            clean.materialize(m, t).reshape(-1)
            for m, ts in tensors.items() for t in ts])
        assert not np.array_equal(served, truth)
    except StorageFaultError:
        pass                                        # crashing also proves it
    assert opened.fault_stats.corrupt_detected == 0


def test_torn_commit_save_retries_idempotently():
    """store.save through a torn-commit backend: the ack-lost commit is
    retried blind, the retry is idempotent, and a clean reopen serves
    bit-exact tensors."""
    store = _store()
    tensors = _variants()
    for name, ts in tensors.items():
        store.register(name, ts)
    inner = MemoryBackend()
    fb = FaultInjectingBackend(inner, "torn=1.0,max_consecutive=1,seed=1")
    store.save(fb)
    assert store.fault_stats.retries >= 1
    reopened = ModelStore.open(inner)               # clean tier
    for model, ts in tensors.items():
        for name in ts:
            np.testing.assert_array_equal(
                reopened.materialize(model, name),
                store.materialize(model, name))


# ------------------------------------------------------- sqlite satellite --
def test_sqlite_commit_retries_through_real_lock_contention(tmp_path):
    """Two contending writers on one database file: writer A holds the
    reservation (BEGIN IMMEDIATE) while B commits.  B's bounded backoff
    retry must ride out the contention and land once A releases —
    distinct from the ManifestConflictError path, which is a version
    conflict and never retried blindly."""
    path = str(tmp_path / "models.db")
    store = _store()
    for name, ts in _variants().items():
        store.register(name, ts)
    writer = SQLiteBackend(path, timeout=0.05, lock_retries=10,
                           lock_backoff=0.02)
    store.save(writer)
    manifest = writer.load_manifest()

    holder = sqlite3.connect(path, timeout=0.05, check_same_thread=False)
    holder.execute("BEGIN IMMEDIATE")               # take the write lock

    def release():
        time.sleep(0.25)
        holder.commit()

    t = threading.Thread(target=release)
    t.start()
    try:
        writer.commit_manifest(manifest)            # retries until release
    finally:
        t.join()
        holder.close()
    assert sorted(writer.load_manifest()["models"]) == ["m0", "m1"]
    writer.close()


def test_sqlite_lock_exhaustion_surfaces_typed_transient(tmp_path):
    """A lock that never releases exhausts the bounded retry budget and
    surfaces as TransientStorageError (the caller may still retry at a
    higher level) — never a raw sqlite3 stack or a silent clobber."""
    path = str(tmp_path / "models.db")
    store = _store()
    for name, ts in _variants().items():
        store.register(name, ts)
    writer = SQLiteBackend(path, timeout=0.01, lock_retries=2,
                           lock_backoff=0.005)
    store.save(writer)
    manifest = writer.load_manifest()

    holder = sqlite3.connect(path, timeout=0.01)
    holder.execute("BEGIN IMMEDIATE")
    try:
        with pytest.raises(TransientStorageError):
            writer.commit_manifest(manifest)
    finally:
        holder.rollback()
        holder.close()
    writer.commit_manifest(manifest)                # fine once released
    writer.close()


# ------------------------------------------------ pool exception safety --
def _embedding_scenario(vocab=512, d=32, num_models=3, batches=8,
                        batch=32, seed=0):
    task = SyntheticTextTask(vocab=vocab, d=d, seed=seed)
    store, heads = build_store(task, num_models=num_models,
                               block_shape=(32, 32), blocks_per_page=4)
    rng = np.random.default_rng(seed)
    traffic = []
    for b in range(batches):
        v = int(rng.integers(0, num_models))
        docs, _ = task.sample(batch, variant=v, seed=7_000 + b)
        traffic.append((f"word2vec-v{v}", docs))
    probe = WeightServer(store, 2)
    worst = max(len(probe.embedding_rows_pages(m, "embedding",
                                               np.unique(docs)))
                for m, docs in traffic)
    cap = min(store.num_pages(), worst + 1)         # all-miss regime
    inner = MemoryBackend()
    store.save(inner)
    return heads, traffic, cap, inner


def _serve(heads, traffic, cap, backend, shards=0, fail_at=None,
           revive_at=None, placement="sharers"):
    opened = ModelStore.open(backend)
    if shards:
        server = ShardedWeightServer(opened, cap,
                                     storage=StorageModel("dram"),
                                     shards=shards, placement=placement)
    else:
        server = WeightServer(opened, cap, "optimized_mru",
                              StorageModel("dram"), backend="device")
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    overlap=True)
    logits = []
    for i, (model, docs) in enumerate(traffic):
        if fail_at is not None and i == fail_at:
            server.fail_shard(0)
        if revive_at is not None and i == revive_at:
            server.revive_shard(0)
        engine.submit(model, docs)
        engine.run(max_batches=1)
        logits.append(np.asarray(engine.last_logits, np.float32))
    return np.concatenate([l.reshape(-1) for l in logits]), server, engine


def test_failed_grouped_load_leaves_pool_consistent():
    """Satellite: an exception mid-grouped-load must not leak slots or
    half-admit pages — after the failure heals, the same server serves
    bit-exact logits (REPRO_SANITIZE=1 CI re-checks this test with the
    pool sanitizer armed)."""
    heads, traffic, cap, inner = _embedding_scenario()
    fb = FaultInjectingBackend(inner)               # clean for open()
    opened = ModelStore.open(fb)
    # max_consecutive=0: never forced clean, so the retry budget
    # genuinely exhausts and the failure escapes to the pool layers
    fb.spec = FaultSpec.parse("transient=1.0,max_consecutive=0")
    server = WeightServer(opened, cap, "optimized_mru",
                          StorageModel("dram"), backend="device")
    model, docs = traffic[0]
    pages = server.embedding_rows_pages(model, "embedding",
                                        np.unique(docs))
    free_before = len(server.device_pool._free)
    with pytest.raises(FatalStorageError):
        server.access_pages_grouped(model, pages)
    assert opened.fault_stats.retries > 0
    # nothing half-admitted: no resident entries, no leaked slots
    assert not server.pool.resident
    assert len(server.device_pool._free) == free_before

    fb.spec = FaultSpec()                           # storage heals
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    overlap=True)
    got = []
    for m, d in traffic:
        engine.submit(m, d)
        engine.run(max_batches=1)
        got.append(np.asarray(engine.last_logits, np.float32))
    clean, _, _ = _serve(heads, traffic, cap, inner)
    np.testing.assert_array_equal(
        np.concatenate([l.reshape(-1) for l in got]), clean)


def test_engine_degrades_batch_on_device_fault(monkeypatch):
    """Graceful degradation: a device-path failure past its budget costs
    that batch a host fallback (degraded_batches++), never the run."""
    heads, traffic, cap, inner = _embedding_scenario(batches=4)
    clean, _, _ = _serve(heads, traffic, cap, inner)

    opened = ModelStore.open(inner)
    server = WeightServer(opened, cap, "optimized_mru",
                          StorageModel("dram"), backend="device")
    real = server.device_gather_rows
    state = {"fired": False}

    def flaky_gather(*a, **kw):
        if not state["fired"]:
            state["fired"] = True
            raise FatalStorageError("injected device-path failure")
        return real(*a, **kw)

    monkeypatch.setattr(server, "device_gather_rows", flaky_gather)
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    overlap=True)
    got = []
    for m, d in traffic:
        engine.submit(m, d)
        engine.run(max_batches=1)
        got.append(np.asarray(engine.last_logits, np.float32))
    assert engine.stats.degraded_batches == 1
    assert engine.stats.dense_fallbacks >= 1
    assert engine.stats.batches == len(traffic)
    np.testing.assert_allclose(
        np.concatenate([l.reshape(-1) for l in got]), clean, atol=1e-5)


# ------------------------------------------------------ chaos acceptance --
def _chaos_spec(rate, seed=11):
    return FaultSpec(transient=rate, corrupt=rate, lock=rate, torn=rate,
                     latency=min(1.0, 2 * rate), seed=seed)


@pytest.mark.parametrize("rate", [0.05, 0.10])
def test_chaos_embedding_single_slab_bit_exact(rate):
    """Acceptance: identical traffic at fault rate 0 vs `rate` through
    the recovery layer -> bit-identical logits, with the recovery
    actually engaged (injection counters non-zero)."""
    heads, traffic, cap, inner = _embedding_scenario()
    clean, _, _ = _serve(heads, traffic, cap, inner)
    fb = FaultInjectingBackend(inner, _chaos_spec(rate))
    chaos, server, engine = _serve(heads, traffic, cap, fb)
    np.testing.assert_array_equal(clean, chaos)
    assert sum(fb.injected.values()) > 0            # schedule engaged
    fs = server.stats
    assert fs.retries + fs.corrupt_detected \
        + engine.stats.degraded_batches >= 0
    if fs.corrupt_detected:
        assert fs.refetch_pages > 0
    assert fs.fault_backoff_seconds >= 0.0


def test_chaos_embedding_two_shards_with_midrun_failover():
    """Acceptance: 2-shard config, one shard failed mid-run and revived
    later, at 10% injection — logits bit-identical to the same sharded
    run without faults, invariants + failover accounting intact."""
    heads, traffic, cap, inner = _embedding_scenario()
    kw = dict(shards=2, fail_at=3, revive_at=6)
    clean, ref_srv, _ = _serve(heads, traffic, cap, inner, **kw)
    fb = FaultInjectingBackend(inner, _chaos_spec(0.10))
    chaos, srv, _ = _serve(heads, traffic, cap, fb, **kw)
    np.testing.assert_array_equal(clean, chaos)
    assert sum(fb.injected.values()) > 0
    assert srv.stats.failovers == 1
    assert ref_srv.stats.failovers == 1
    srv.sharded.check_invariants()
    # the failover run agrees with an undisturbed single-slab run too
    flat, _, _ = _serve(heads, traffic, cap, inner)
    np.testing.assert_allclose(chaos, flat, atol=1e-5)


def test_chaos_lm_path_bit_exact():
    """Acceptance (LM engine): generate() under 10% injection returns
    the exact tokens of the fault-free run, device path retained."""
    from repro.serving.engine import LMServingEngine

    store = _store(l=4, block=16)
    rng = np.random.default_rng(0)
    base = rng.standard_normal((48, 32)).astype(np.float32)
    for v in range(2):
        store.register(f"lm-v{v}", {"w": base + v * 1e-5,
                                    "b": base[:16] * 0.5 + v * 1e-5})
    inner = MemoryBackend()
    store.save(inner)

    class TinyApi:
        def prefill(self, params, batch, _):
            x = np.asarray(batch["tokens"], np.float32)
            h = x @ params["w"][:x.shape[-1]]
            logits = h @ params["b"][:, :h.shape[-1]].T
            return logits[:, None, :], h

        def decode(self, params, cache, toks):
            h = cache + np.asarray(toks, np.float32).mean()
            logits = h @ params["b"][:, :h.shape[-1]].T
            return logits[:, None, :], h

    apis = {m: TinyApi() for m in ("lm-v0", "lm-v1")}
    templates = {m: {"rebuild": lambda ts: {k: np.asarray(v)
                                            for k, v in ts.items()}}
                 for m in ("lm-v0", "lm-v1")}
    prompts = rng.standard_normal((2, 48)).astype(np.float32)

    def generate(backend):
        opened = ModelStore.open(backend)
        cap = max(2, opened.num_pages() // 2)
        server = WeightServer(opened, cap, "optimized_mru",
                              StorageModel("dram"), backend="device")
        engine = LMServingEngine(server, apis, templates)
        outs = []
        for m in ("lm-v0", "lm-v1", "lm-v0"):
            out, _ = engine.generate(m, prompts, steps=3)
            outs.append(out)
        return outs, engine

    clean, _ = generate(inner)
    fb = FaultInjectingBackend(inner, _chaos_spec(0.10, seed=3))
    chaos, engine = generate(fb)
    for a, b in zip(clean, chaos):
        np.testing.assert_array_equal(a, b)
    assert sum(fb.injected.values()) > 0
    assert engine.stats.batches == 3
