import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.pagepack import (alg2_bound, check_coverage,
                                 equivalent_classes, pack, pack_dedup_base,
                                 pack_greedy1, pack_greedy2, pack_two_stage)


def _random_tensor_sets(draw_seed, k=4, n=40):
    rng = np.random.default_rng(draw_seed)
    sets = {}
    for i in range(k):
        size = int(rng.integers(1, n))
        sets[("m", f"t{i}")] = frozenset(
            int(b) for b in rng.choice(n, size, replace=False))
    return sets


@given(seed=st.integers(0, 1000), l=st.sampled_from([2, 3, 5, 8]),
       k=st.integers(1, 5))
@settings(max_examples=60, deadline=None)
def test_coverage_invariant_all_strategies(seed, l, k):
    """MTPPDP conditions hold for every strategy on random instances."""
    sets = _random_tensor_sets(seed, k=k)
    for fn in (pack_greedy1, pack_greedy2, pack_two_stage):
        res = fn(sets, l)
        check_coverage(res, sets, l)


@given(seed=st.integers(0, 500), l=st.sampled_from([2, 4, 8]))
@settings(max_examples=30, deadline=None)
def test_alg2_bound_thm2(seed, l):
    """Thm. 2: Alg2(P) <= OPT_lower + 2^k - 1."""
    sets = _random_tensor_sets(seed, k=4)
    res = pack_greedy1(sets, l)
    assert res.num_pages <= alg2_bound(sets, l)


def test_paper_fig5_example():
    """Fig. 5/6: blocks 1-16 shared by both tensors, 17-20 private to t1,
    page limit 4 -> the good packing stores 5 distinct pages
    (4 shared + 1 private)."""
    shared = frozenset(range(16))
    t1 = shared | frozenset(range(16, 20))
    sets = {("m", "t1"): frozenset(t1), ("m", "t2"): shared}
    for fn in (pack_greedy1, pack_two_stage):
        res = fn(sets, 4)
        check_coverage(res, sets, 4)
        assert res.num_pages == 5


def test_paper_fig7_repacking_wins():
    """Fig. 7: classes C1 (shared t1,t2), C2 (t2), C6 (t1), page l=2:
    greedy-1 leaves 3 non-full pages; two-stage packs 2."""
    sets = {("m", "t1"): frozenset({1, 6}),   # C1={1}, C6={6}
            ("m", "t2"): frozenset({1, 2})}   # C2={2}
    g1 = pack_greedy1(sets, 2)
    ts = pack_two_stage(sets, 2)
    check_coverage(g1, sets, 2)
    check_coverage(ts, sets, 2)
    assert g1.num_pages == 3
    assert ts.num_pages == 2


def test_dedup_base_eliminates_duplicate_pages():
    seq = np.array([0, 1, 2, 3, 0, 1, 2, 3])
    seqs = {("m", "a"): seq, ("m", "b"): seq.copy()}
    res = pack_dedup_base(seqs, 4)
    sets = {k: frozenset(int(x) for x in v) for k, v in seqs.items()}
    check_coverage(res, sets, 4)
    # both tensors repeat the same 4 blocks twice -> one physical page
    assert res.num_pages == 1
    assert res.tensor_pages[("m", "a")] == [0, 0]


def test_two_stage_not_worse_than_dedup_base():
    rng = np.random.default_rng(3)
    shared = list(range(30))
    sets, seqs = {}, {}
    for i in range(3):
        priv = list(range(100 + 10 * i, 105 + 10 * i))
        blocks = shared + priv
        sets[("m", f"t{i}")] = frozenset(blocks)
        seqs[("m", f"t{i}")] = np.array(blocks)
    ts = pack_two_stage(sets, 8)
    db = pack_dedup_base(seqs, 8)
    assert ts.num_pages <= db.num_pages


def test_equivalent_classes_partition():
    sets = {("m", "a"): frozenset({1, 2, 3}),
            ("m", "b"): frozenset({2, 3, 4})}
    classes = equivalent_classes(sets)
    all_blocks = sorted(b for blocks in classes.values() for b in blocks)
    assert all_blocks == [1, 2, 3, 4]
    assert frozenset({("m", "a"), ("m", "b")}) in classes


def test_pack_dispatch_and_errors():
    sets = {("m", "a"): frozenset({1})}
    with pytest.raises(ValueError):
        pack(sets, 4, "nope")
    with pytest.raises(ValueError):
        pack(sets, 4, "dedup_base")        # needs sequences
