import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim import (adafactor, adamw, clip_by_global_norm,
                         cosine_schedule, make_optimizer)


def _quadratic_losses(opt, steps=60):
    target = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((8, 8)), jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32),
              "b": jnp.zeros((8,), jnp.float32)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)

    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(grads, state, params)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_converge(name):
    opt = make_optimizer(name, lr=5e-2)
    losses = _quadratic_losses(opt)
    assert losses[-1] < losses[0] * 0.2


def test_grad_clip():
    grads = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(100.0 * np.sqrt(10), rel=1e-5)
    cn = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(cn) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    s = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(s(jnp.asarray(100))) < 2e-4


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_state_specs_match_state_structure(name):
    """in_shardings for the dry-run require exact structure match."""
    opt = make_optimizer(name)
    params = {"layer": {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}}
    state = opt.init(params)
    sds = jax.eval_shape(opt.init, params)
    pspecs = {"layer": {"w": P(None, "model"), "b": P()}}
    specs = opt.state_specs(
        jax.eval_shape(lambda p: p, params), pspecs)
    t1 = jax.tree_util.tree_structure(state)
    t2 = jax.tree_util.tree_structure(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert t1 == t2


def test_adafactor_memory_smaller_than_adam():
    params = {"w": jnp.zeros((256, 256))}
    a = adamw().init(params)
    f = adafactor().init(params)
    bytes_a = sum(np.prod(l.shape) * 4 for l in jax.tree.leaves(a["m"]))
    bytes_f = sum(np.prod(l.shape) * 4
                  for l in jax.tree.leaves(f["v"]))
    assert bytes_f < bytes_a / 10
