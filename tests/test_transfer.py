"""Batched/overlapped host->HBM transfers (serving/transfer.py, PR 5):
grouped-vs-per-page logit equivalence (embedding + LM; host, Pallas
interpret and XLA kernel modes; 1/2/4 shards), single-generation-bump
per group, double-buffer overlap stats, grouped prefetcher backend
reads, replica load balancing, and cross-batch borrow coalescing."""
import numpy as np
import pytest

from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.serving.engine import (EmbeddingServingEngine, LMServingEngine,
                                  ServeStats, StorageModel, WeightServer)
from repro.serving.prefetch import Prefetcher
from repro.serving.router import ShardRouter
from repro.serving.scheduler import FifoScheduler
from repro.serving.shard_pool import (ShardedWeightServer,
                                      sharers_placement)
from repro.serving.transfer import fit_channel
from repro.storage import ObjectStoreSimBackend


def _scenario(vocab=512, d=32, num_models=3, block=(32, 32), l=4, seed=0):
    task = SyntheticTextTask(vocab=vocab, d=d, seed=seed)
    store, heads = build_store(task, num_models=num_models,
                               block_shape=block, blocks_per_page=l)
    return task, store, heads


def _run_batches(engine, task, num_models, batches=6, batch=16, seed=0):
    out = []
    for b in range(batches):
        v = b % num_models
        docs, _ = task.sample(batch, variant=v, seed=seed + 100 + b)
        engine.submit(f"word2vec-v{v}", docs)
        engine.run(max_batches=1)
        out.append(engine.last_logits.copy())
    return out


# ------------------------------------------------------------- equivalence --
@pytest.mark.parametrize("kernel_mode", ["host", "pallas", "xla"])
def test_grouped_matches_per_page_embedding(kernel_mode):
    """Acceptance: transfer="grouped" logits == transfer="per_page"
    logits == numpy logits, in every kernel mode, with the pool small
    enough that every batch faults a real miss group."""
    small = kernel_mode == "pallas"
    task, store, heads = _scenario(vocab=256 if small else 512)
    batches, batch = (4, 8) if small else (6, 16)
    # capacity holds any one batch but not necessarily the union, so
    # batches fault real miss groups without tearing their own pins
    probe = WeightServer(store, 2)
    worst = 0
    for b in range(batches):
        v = b % 3
        docs, _ = task.sample(batch, variant=v, seed=100 + b)
        worst = max(worst, len(probe.embedding_rows_pages(
            f"word2vec-v{v}", "embedding", np.unique(docs))))
    cap = min(store.num_pages(), worst + 1)

    def serve(backend, transfer):
        server = WeightServer(store, cap, storage=StorageModel("dram"),
                              backend=backend, kernel_mode=kernel_mode,
                              transfer=transfer)
        engine = EmbeddingServingEngine(server, heads)
        logits = _run_batches(engine, task, 3, batches=batches, batch=batch)
        return logits, engine.stats, server

    ref, _, _ = serve("numpy", "grouped")
    pp, pp_stats, _ = serve("device", "per_page")
    gp, gp_stats, gp_server = serve("device", "grouped")
    assert gp_stats.device_batches == len(gp)
    assert gp_stats.dense_fallbacks == 0
    # the grouped path moved the same pages in far fewer operations
    assert gp_stats.transfer_pages == pp_stats.transfer_pages
    assert gp_stats.transfer_groups <= pp_stats.transfer_groups
    assert gp_server.pool.misses > 0
    for a, b, c in zip(ref, pp, gp):
        np.testing.assert_allclose(a, b, atol=1e-5)
        np.testing.assert_allclose(a, c, atol=1e-5)


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_grouped_matches_per_page_sharded(shards):
    """Sharded serving through grouped per-shard transfers == per_page
    == numpy, at 1/2/4 shards (host mode; per-shard capacity below the
    working set so owned groups and borrows both move)."""
    task, store, heads = _scenario(vocab=1024, num_models=4)
    cap = max(4, store.num_pages() - 2)

    ref_server = WeightServer(store, cap, storage=StorageModel("dram"),
                              backend="numpy")
    ref = _run_batches(EmbeddingServingEngine(ref_server, heads),
                       task, 4, batches=8)
    out = {}
    for transfer in ("per_page", "grouped"):
        srv = ShardedWeightServer(store, cap, storage=StorageModel("dram"),
                                  shards=shards, placement="sharers",
                                  transfer=transfer)
        out[transfer] = _run_batches(EmbeddingServingEngine(srv, heads),
                                     task, 4, batches=8)
        srv.sharded.check_invariants()
    for a, b, c in zip(ref, out["per_page"], out["grouped"]):
        np.testing.assert_allclose(a, b, atol=1e-5)
        np.testing.assert_allclose(a, c, atol=1e-5)


class _TinyLMAPI:
    """Minimal prefill/decode API over {emb, head} params: enough to
    drive LMServingEngine's model-switch fault path deterministically."""

    def prefill(self, params, batch, max_len):
        import jax.numpy as jnp
        tokens = jnp.asarray(batch["tokens"])
        emb = jnp.asarray(params["emb"])
        x = emb[tokens].mean(axis=1)                     # [B, d]
        logits = x @ jnp.asarray(params["head"])         # [B, V]
        return logits[:, None, :], {"x": x}

    def decode(self, params, cache, tokens):
        import jax.numpy as jnp
        emb = jnp.asarray(params["emb"])
        x = cache["x"] * 0.5 + emb[jnp.asarray(tokens)[:, 0]]
        logits = x @ jnp.asarray(params["head"])
        return logits[:, None, :], {"x": x}


def _lm_setup(seed=0):
    rng = np.random.default_rng(seed)
    vocab, d = 96, 32
    emb = (rng.standard_normal((vocab, d)) * 0.1).astype(np.float32)
    head = (rng.standard_normal((d, vocab)) * 0.1).astype(np.float32)
    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(16, 16),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=4))
    names = []
    for v in range(3):
        name = f"lm-v{v}"
        names.append(name)
        emb_v = emb.copy()                   # private stripe per variant:
        lo = v * vocab // 3                  # switches must refault pages
        emb_v[lo:lo + vocab // 3] += (
            rng.standard_normal((vocab // 3, d)) * 0.3).astype(np.float32)
        store.register(name, {"emb": emb_v, "head": head})
    api = _TinyLMAPI()
    apis = {n: api for n in names}
    templates = {n: {"rebuild": lambda ts: dict(ts)} for n in names}
    return store, names, apis, templates


def test_grouped_matches_per_page_lm():
    """LM model switches fault whole page working sets: the grouped and
    per-page transfer paths must produce identical generations."""
    outs = {}
    for transfer in ("per_page", "grouped"):
        store, names, apis, templates = _lm_setup()
        cap = max(2, store.num_pages() // 2)     # switches must refault
        server = WeightServer(store, cap, storage=StorageModel("dram"),
                              backend="device", transfer=transfer)
        engine = LMServingEngine(server, apis, templates,
                                 scheduler="fifo", overlap=True)
        rng = np.random.default_rng(7)
        for b in range(6):
            prompts = rng.integers(1, 96, size=(2, 5)).astype(np.int32)
            engine.submit(names[b % 3], prompts, steps=3)
        engine.run()
        assert engine.stats.batches == 6
        assert engine.stats.transfer_pages > 0
        outs[transfer] = engine.stats
        # capture generations through a direct call for bit-equality
        out, _ = engine.generate(names[0],
                                 np.ones((2, 4), np.int32), steps=3)
        outs[transfer + "_gen"] = out
    np.testing.assert_array_equal(outs["per_page_gen"], outs["grouped_gen"])
    assert outs["grouped"].transfer_groups < outs["per_page"].transfer_groups


# ---------------------------------------------------- generation accounting --
def test_group_load_bumps_generation_once():
    """The remap-cache generation bumps ONCE per committed group, not
    once per page (the per_page path keeps its bump-per-page)."""
    _, store, _ = _scenario()
    pages = list(range(store.num_pages()))
    for transfer, expected in (("grouped", 1), ("per_page", len(pages))):
        server = WeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"),
                              backend="device", transfer=transfer)
        gen0 = server.device_pool.generation
        server.access_pages_grouped("word2vec-v0", pages)
        assert server.device_pool.generation - gen0 == expected, transfer
        assert server.device_pool.loads == len(pages)
        assert set(server.device_pool.slot_of) == set(pages)
        # slab contents identical to the store's pages either way
        for pid in pages:
            np.testing.assert_array_equal(
                server.device_pool.slot_page(server.device_pool.slot_of[pid]),
                store.page_array(pid))


def test_page_stack_matches_page_arrays():
    _, store, _ = _scenario()
    pids = list(range(store.num_pages()))[::2]
    stack = store.page_stack(pids)
    for i, pid in enumerate(pids):
        np.testing.assert_array_equal(stack[i], store.page_array(pid))


# ------------------------------------------------------- overlap / prestage --
def test_overlap_prestages_next_batch():
    """Double buffer: with overlap on, the next queued batch's pages are
    staged while the current batch computes, so its commit finds the
    bytes in flight (overlap_fraction > 0) — and the stats stay sane."""
    task, store, heads = _scenario(vocab=1024, num_models=4)
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"),
                          backend="device", transfer="grouped")
    engine = EmbeddingServingEngine(server, heads, scheduler="fifo",
                                    overlap=True)
    for b in range(4):                       # queue up front: real lookahead
        v = b % 4
        docs, _ = task.sample(16, variant=v, seed=900 + b)
        engine.submit(f"word2vec-v{v}", docs)
    stats = engine.run()
    assert stats.transfer_pages == server.device_pool.loads
    assert stats.transfer_groups > 0
    assert 0.0 < stats.overlap_fraction <= 1.0
    assert stats.transfer_seconds >= 0.0
    assert stats.group_sizes and min(stats.group_sizes) >= 1.0
    assert stats.mean_group_size > 1.0       # groups actually coalesced


def test_serial_engine_reports_zero_overlap():
    """No overlap => no prestaging: the stat must not pretend."""
    task, store, heads = _scenario()
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"),
                          backend="device", transfer="grouped")
    engine = EmbeddingServingEngine(server, heads, overlap=False)
    _run_batches(engine, task, 3, batches=4)
    assert engine.stats.overlap_fraction == 0.0
    assert engine.stats.transfer_pages == server.device_pool.loads


def test_deferred_window_drops_evicted_admissions():
    """A page admitted and then evicted inside ONE deferred window must
    never reach the physical flush: loading it would create a ghost
    slab resident (or exhaust the slab's free slots outright)."""
    _, store, _ = _scenario(num_models=3)
    assert store.num_pages() >= 3
    server = WeightServer(store, 2, storage=StorageModel("dram"),
                          backend="device", transfer="grouped")
    pool = server.pool
    with pool.deferred_loads():
        pool.access("word2vec-v0", 0)
        pool.access("word2vec-v0", 1)
        pool.access("word2vec-v0", 2)     # evicts a same-window admission
    assert pool.resident_pages() == server.device_pool.resident_pages()
    assert len(server.device_pool.slot_of) <= 2


# ------------------------------------------------- prefetcher grouped reads --
def test_prefetcher_uses_one_grouped_backend_read():
    """Satellite: prefetch-admitted pages flush as ONE grouped backend
    get_pages (and one grouped slab transfer), never a round trip per
    page."""
    _, store, _ = _scenario(num_models=3)
    backend = ObjectStoreSimBackend()
    store.save(backend)
    opened = ModelStore.open(backend)
    server = WeightServer(opened, opened.num_pages(),
                          storage=StorageModel("dram"),
                          backend="device", transfer="grouped")
    sched = FifoScheduler()
    model = sorted(opened.dedup.models)[0]
    pages = opened.model_pages(model)
    sched.submit(model, None, pages=pages,
                 pages_gen=opened.pack_generation)
    pf = Prefetcher(server, max_pages_per_step=len(pages))
    pf.attach_scheduler(sched)
    gets0 = backend.get_calls
    groups0 = server.device_pool.transfer.stats.groups
    pf.step()
    assert pf.stats.issued == len(pages)
    assert backend.get_calls - gets0 <= 1            # ONE grouped read
    assert server.device_pool.transfer.stats.groups - groups0 == 1
    assert set(pages) <= server.pool.resident_pages()


def test_prefetcher_per_page_fallback_still_loads():
    """transfer="per_page" keeps the legacy per-page on_load path alive
    under the prefetcher's deferred window."""
    _, store, _ = _scenario(num_models=3)
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"),
                          backend="device", transfer="per_page")
    sched = FifoScheduler()
    model = sorted(store.dedup.models)[0]
    pages = store.model_pages(model)
    sched.submit(model, None, pages=pages, pages_gen=store.pack_generation)
    pf = Prefetcher(server, max_pages_per_step=len(pages))
    pf.attach_scheduler(sched)
    pf.step()
    assert set(pages) <= server.device_pool.resident_pages()


# --------------------------------------------------- replica load balancing --
def test_replica_ties_spread_by_observed_load():
    """Satellite: fully-replicated page sets tie every shard; the router
    must spread them off the hot shard, counting the moves."""
    pl = sharers_placement(4, 2, {p: frozenset({"a", "b"})
                                  for p in range(4)})
    router = ShardRouter(lambda: pl)
    shards = [router.route([0, 1]).shard for _ in range(6)]
    assert router.rebalanced > 0                      # traffic moved
    assert set(shards) == {0, 1}                      # both replicas used
    assert router.batches_per_shard[0] == router.batches_per_shard[1] == 3
    # load-oblivious mode keeps the legacy lowest-id tie break
    fixed = ShardRouter(lambda: pl, balance_replicas=False)
    assert [fixed.route([0, 1]).shard for _ in range(4)] == [0] * 4
    assert fixed.rebalanced == 0


def test_replica_balancing_end_to_end_counter():
    """Identical models => every page replicated under sharers placement
    => repeated batches spread across shards with the counter proving
    it, and logits stay correct."""
    rng = np.random.default_rng(0)
    emb = (rng.standard_normal((256, 32)) * 0.1).astype(np.float32)
    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(32, 32),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=2))
    heads = {}
    for v in range(2):
        store.register(f"m{v}", {"embedding": emb})   # fully shared
        heads[f"m{v}"] = (rng.standard_normal((32, 8)) * 0.1
                          ).astype(np.float32)
    srv = ShardedWeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"), shards=2,
                              placement="sharers", replicate_frac=1.0)
    assert srv.sharded.placement().replicated          # setup sanity
    engine = EmbeddingServingEngine(srv, heads)
    docs = rng.integers(0, 256, size=(8, 4))
    expect = emb[docs].mean(axis=1) @ heads["m0"]
    for _ in range(6):
        engine.submit("m0", docs)
        engine.run(max_batches=1)
        np.testing.assert_allclose(engine.last_logits, expect, atol=1e-5)
    assert srv.router.rebalanced > 0
    assert len(srv.stats.shard_batches) == 2           # both shards served
    srv.sharded.check_invariants()


# ------------------------------------------------------- borrow coalescing --
def test_borrow_coalescing_across_same_shard_batches():
    """Satellite (ROADMAP): consecutive batches on the same shard reuse
    already-staged borrows — no re-copy, no second interconnect charge,
    counter proving it — and serve identical logits throughout."""
    task, store, heads = _scenario(vocab=1024, num_models=4)
    srv = ShardedWeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"),
                              shards=2, placement="hash")
    engine = EmbeddingServingEngine(srv, heads)
    docs, _ = task.sample(16, variant=0, seed=42)
    expect = None
    for rep in range(3):                      # same batch, same shard
        engine.submit("word2vec-v0", docs)
        engine.run(max_batches=1)
        if expect is None:
            expect = engine.last_logits.copy()
        else:
            np.testing.assert_allclose(engine.last_logits, expect,
                                       atol=1e-5)
    assert srv.stats.borrow_pages > 0
    assert srv.stats.borrow_coalesced > 0              # reuse happened
    # reused pages were not re-charged: pages staged fresh only once
    assert srv.stats.borrow_pages < 3 * (srv.stats.borrow_pages
                                         + srv.stats.borrow_coalesced) / 2
    srv.sharded.check_invariants()


def test_stage_borrows_survives_owner_thrash():
    """A borrow set larger than the owner's pool must still stage: the
    owner-side faults evict each other (capacity 1), and pages evicted
    between fault and copy source their bytes from the store instead of
    crashing on a dead mirror slot."""
    from repro.serving.shard_pool import ShardedPagePool

    _, store, _ = _scenario(num_models=3)
    assert store.num_pages() >= 4
    pool = ShardedPagePool(store, 2, capacity_per_shard=1,
                           placement="hash", borrow_capacity=8)
    odd = [p for p in range(store.num_pages()) if p % 2 == 1][:3]
    pool.buffer_pools[1].access("word2vec-v0", odd[0])   # warm a mirror hit
    res = pool.stage_borrows(0, odd, "word2vec-v0")
    assert res is not None
    staged, hits, faults, reused = res
    assert set(staged) == set(odd)
    assert hits + faults == len(odd) and reused == 0
    for pid in odd:                          # staged bytes == store bytes
        np.testing.assert_array_equal(pool._stage_host[0][staged[pid]],
                                      store.page_array(pid))
    pool.check_invariants()


# ----------------------------------------------------------- calibration --
def test_fit_channel_recovers_bandwidth_and_seek():
    bw, seek = 2e9, 5e-4
    recs = [(n, n * 65536, seek + n * 65536 / bw) for n in (1, 2, 4, 8, 16)]
    fbw, fseek = fit_channel(recs)
    assert fbw == pytest.approx(bw, rel=1e-3)
    assert fseek == pytest.approx(seek, rel=1e-3)
    # flat size axis => per-op dominated: all seek, free bytes
    flat = [(n, n * 65536, 1e-3) for n in (1, 2, 4, 8)]
    fbw, fseek = fit_channel(flat)
    assert fseek == pytest.approx(1e-3, rel=1e-6)
    assert fbw >= 1e12


def test_transfer_mode_validated():
    _, store, _ = _scenario()
    with pytest.raises(ValueError):
        WeightServer(store, 4, backend="device", transfer="teleport")
    with pytest.raises(ValueError):
        ShardedWeightServer(store, 4, shards=2, transfer="teleport")
