import os

import numpy as np
import pytest

from repro.core import (DedupConfig, LSHConfig, ModelStore, StoreConfig,
                        load_store_tensors)
from repro.core.pagepack import check_coverage


def _store(threshold=6, r=8.0, validate=False, l=4):
    return ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(16, 16),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=r, collision_threshold=threshold),
                          validate=validate),
        blocks_per_page=l))


def _variants(n=3, shape=(64, 64), noise=1e-4, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.standard_normal(shape).astype(np.float32)
    return {f"m{i}": {"w": base + rng.standard_normal(shape)
                      .astype(np.float32) * noise * i}
            for i in range(n)}


def test_register_pack_materialize_roundtrip():
    store = _store()
    models = _variants()
    for name, t in models.items():
        store.register(name, t)
    pk = store.repack()
    check_coverage(pk, store.dedup.tensor_sets(), 4)
    # m0 is the reference model: representatives come from it
    assert np.allclose(store.materialize("m0", "w"), models["m0"]["w"])
    # variants reconstruct to within the dedup approximation
    err = np.abs(store.materialize("m2", "w") - models["m2"]["w"]).max()
    assert err < 1e-2


def test_storage_reduction_for_similar_models():
    store = _store()
    for name, t in _variants(4).items():
        store.register(name, t)
    assert store.storage_bytes() < store.dense_bytes() / 2


def test_virtual_tensor_consistency():
    store = _store()
    for name, t in _variants().items():
        store.register(name, t)
    vt = store.virtual_tensor("m1", "w")
    pool = store.page_pool()
    l = store.cfg.blocks_per_page
    blocks = pool.reshape(-1, 16, 16)[vt.block_map]
    from repro.core.blocks import unblock_tensor
    rec = unblock_tensor(blocks, vt.grid)
    assert np.allclose(rec, store.materialize("m1", "w"))
    assert set(vt.page_ids) <= set(range(store.num_pages()))


def test_save_load_roundtrip(tmp_path):
    store = _store()
    models = _variants()
    for name, t in models.items():
        store.register(name, t)
    manifest = store.save(str(tmp_path))
    assert os.path.exists(tmp_path / "manifest.json")
    back = load_store_tensors(str(tmp_path))
    for name in models:
        assert np.allclose(back[name]["w"], store.materialize(name, "w"))
    # content addressing: identical pages share one file
    page_files = [f for f in os.listdir(tmp_path) if f.startswith("page-")]
    assert len(page_files) <= store.num_pages()
    assert len(manifest["pages"]) == store.num_pages()


def test_update_and_remove():
    store = _store()
    models = _variants()
    for name, t in models.items():
        store.register(name, t)
    p0 = store.num_pages()
    new_w = {"w": models["m1"]["w"] + 0.5}
    store.update("m1", new_w, approach=2)
    assert np.allclose(store.materialize("m1", "w"), new_w["w"], atol=1e-5)
    store.remove("m1")
    assert ("m1", "w") not in store.dedup.tensor_sets()
    check_coverage(store.repack(), store.dedup.tensor_sets(), 4)


def test_buffer_pool_wiring():
    store = _store()
    for name, t in _variants().items():
        store.register(name, t)
    pool = store.make_buffer_pool(4, "optimized_mru")
    pk = store.packing
    for name in ("m0", "m1", "m2"):
        for pid in pk.tensor_pages[(name, "w")]:
            pool.access(name, pid)
    assert pool.hits + pool.misses > 0
