"""Device-resident page pool: slab residency invariants, device-vs-numpy
logit equivalence (Pallas interpret + host-mirror modes), slot-remap
contract, and stale-cache invalidation on model updates."""
import numpy as np
import pytest

from repro.core import DedupConfig, LSHConfig, ModelStore, StoreConfig
from repro.core.bufferpool import BufferPool, PoolConfig
from repro.data.pipeline import SyntheticTextTask
from repro.launch.serve import build_store
from repro.serving.engine import (EmbeddingServingEngine, ServeStats,
                                  StorageModel, WeightServer)


def _scenario(vocab=512, d=32, num_models=3, block=(32, 32), l=4, seed=0):
    task = SyntheticTextTask(vocab=vocab, d=d, seed=seed)
    store, heads = build_store(task, num_models=num_models,
                               block_shape=block, blocks_per_page=l)
    return task, store, heads


def _run_batches(engine, task, num_models, batches=6, batch=16, seed=0):
    """Drive the engine one batch at a time, returning per-batch logits."""
    out = []
    for b in range(batches):
        v = b % num_models
        docs, _ = task.sample(batch, variant=v, seed=seed + 100 + b)
        engine.submit(f"word2vec-v{v}", docs)
        engine.run(max_batches=1)
        out.append(engine.last_logits.copy())
    return out


# ------------------------------------------------------------ equivalence --
@pytest.mark.parametrize("kernel_mode", ["host", "pallas"])
def test_device_backend_matches_numpy_logits(kernel_mode):
    """Acceptance: backend="device" logits == numpy logits (atol 1e-5),
    pallas mode exercising the interpret-mode dedup kernels on CPU."""
    task, store, heads = _scenario(vocab=256 if kernel_mode == "pallas"
                                   else 512)
    n = 3

    def serve(backend):
        server = WeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"), backend=backend,
                              kernel_mode=kernel_mode)
        engine = EmbeddingServingEngine(server, heads)
        logits = _run_batches(engine, task, n,
                              batches=4 if kernel_mode == "pallas" else 6,
                              batch=8 if kernel_mode == "pallas" else 16)
        return logits, engine.stats

    ref, _ = serve("numpy")
    dev, stats = serve("device")
    assert stats.device_batches == len(dev)
    assert stats.dense_fallbacks == 0
    for a, b in zip(ref, dev):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_device_hot_path_never_materializes(monkeypatch):
    """Acceptance: zero calls to dedup.materialize / materialize_rows on
    the steady-state device hot path."""
    task, store, heads = _scenario()
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"), backend="device")
    engine = EmbeddingServingEngine(server, heads)
    _run_batches(engine, task, 3, batches=3)     # warm: slab + jit caches

    calls = {"n": 0}

    def bump(*a, **k):
        calls["n"] += 1
        raise AssertionError("host materialization on device hot path")

    monkeypatch.setattr(store.dedup, "materialize", bump)
    monkeypatch.setattr(store, "materialize_rows", bump)
    _run_batches(engine, task, 3, batches=6, seed=50)
    assert calls["n"] == 0
    assert engine.stats.dense_fallbacks == 0


def test_partial_residency_still_serves_from_device():
    """The slab only needs the *batch's* pages, not the whole tensor:
    with capacity far below the total working set every batch still
    computes off the slab (fig-8 regime)."""
    task, store, heads = _scenario(vocab=1024, num_models=4)
    server = WeightServer(store, 2, storage=StorageModel("dram"),
                          backend="device")
    # find a capacity that fits single batches but not the working set
    docs, _ = task.sample(16, variant=0, seed=7)
    batch_pages = len(server.embedding_rows_pages(
        "word2vec-v0", "embedding", np.unique(docs)))
    cap = min(store.num_pages() - 1, batch_pages + 2)
    server = WeightServer(store, cap, storage=StorageModel("dram"),
                          backend="device")
    engine = EmbeddingServingEngine(server, heads)
    _run_batches(engine, task, 4, batches=8)
    assert engine.stats.device_batches > 0
    assert server.pool.misses > 0                # pages churned


# ------------------------------------------------------- slab invariants --
def test_slab_residency_matches_pool_under_churn():
    """Invariant: the pool's resident set == the slab's occupied slots
    (and slot contents == the physical pages) under access/prefetch/evict
    churn."""
    _, store, _ = _scenario(num_models=4)
    cap = max(2, store.num_pages() // 3)
    server = WeightServer(store, cap, storage=StorageModel("dram"),
                          backend="device")
    pool, dev = server.pool, server.device_pool
    models = list(store.dedup.models)
    rng = np.random.default_rng(0)
    npages = store.num_pages()
    for step in range(300):
        m = models[int(rng.integers(len(models)))]
        p = int(rng.integers(npages))
        if rng.random() < 0.25:
            pool.prefetch(m, p)
        else:
            pool.access(m, p)
        assert pool.resident_pages() == dev.resident_pages()
        occ = dev.occupied_slots()
        assert len(occ) == len(dev.slot_of)              # slots unique
        assert len(occ) + len(dev._free) == dev.capacity
        assert len(pool.resident) <= cap
    for pid, slot in dev.slot_of.items():
        np.testing.assert_array_equal(dev.slot_page(slot),
                                      store.page_array(pid))


def test_access_group_pins_members():
    """A later miss in a pinned group must never evict an earlier member;
    an impossible group raises instead of thrashing."""
    pool = BufferPool(PoolConfig(3, "mru"))
    hits = pool.access_group("m", [0, 1, 2])
    assert hits == [False] * 3
    # all three must survive their own group's misses
    assert pool.resident_pages() == {0, 1, 2}
    pool.access_group("m", [3, 4, 1])
    assert {3, 4, 1} <= pool.resident_pages()
    with pytest.raises(ValueError):
        pool.access_group("m", [0, 1, 2, 3])


def test_remap_contract_covers_tensor_pages():
    """Slot-remap contract: every flat slot of a virtual tensor lies in
    one of its own cover pages, so faulting page_ids guarantees a full
    remap."""
    _, store, _ = _scenario(num_models=3)
    for m in store.dedup.models:
        vt = store.virtual_tensor(m, "embedding")
        l = store.cfg.blocks_per_page
        assert set(int(s) // l for s in vt.block_map) <= set(vt.page_ids)
        server = WeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"), backend="device")
        server.access_pages(m, vt.page_ids)
        assert server.device_pool.remap(vt) is not None


# ------------------------------------------------- staleness / invalidation --
def test_model_update_invalidates_pool_and_slab():
    """Satellite: a model update must repack and flush every consumer —
    WeightServer's cached pool array, the buffer pool's resident set and
    the device slab — so both backends serve the *new* weights."""
    task, store, heads = _scenario()
    servers = {b: WeightServer(store, store.num_pages(),
                               storage=StorageModel("dram"), backend=b)
               for b in ("numpy", "device")}
    engines = {b: EmbeddingServingEngine(s, heads)
               for b, s in servers.items()}
    for b in engines:
        _run_batches(engines[b], task, 3, batches=3)
    gen0 = store.pack_generation
    arr0 = servers["numpy"]._pages()

    new_emb = task.variant_embedding(0) + 0.25
    store.update("word2vec-v0", {"embedding": new_emb})

    logits = {}
    for b in engines:
        docs, _ = task.sample(16, variant=0, seed=999)
        engines[b].submit("word2vec-v0", docs)
        engines[b].run(max_batches=1)
        logits[b] = engines[b].last_logits
    assert store.pack_generation > gen0
    assert servers["numpy"]._pool_arr is not arr0          # refreshed
    np.testing.assert_allclose(logits["numpy"], logits["device"], atol=1e-5)
    # and the served weights really are the updated ones
    got = store.materialize("word2vec-v0", "embedding")
    np.testing.assert_allclose(got, new_emb, atol=1e-4)
    # slab was flushed and refilled from the *new* packing
    dev = servers["device"].device_pool
    for pid, slot in dev.slot_of.items():
        np.testing.assert_array_equal(dev.slot_page(slot),
                                      store.page_array(pid))


def test_update_between_submit_and_run_recomputes_pages():
    """Page ids cached in a queued batch die with their packing: a model
    update between submit() and run() must not fault stale ids (wrong
    bytes on the device slab) — both backends still agree afterwards."""
    task, store, heads = _scenario()
    logits = {}
    for b in ("numpy", "device"):
        server = WeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"), backend=b)
        engine = EmbeddingServingEngine(server, heads)
        _run_batches(engine, task, 3, batches=3)          # warm
        docs, _ = task.sample(16, variant=0, seed=321)
        engine.submit("word2vec-v0", docs)                # old packing
        store.update("word2vec-v0",
                     {"embedding": task.variant_embedding(0) + 0.125})
        engine.run(max_batches=1)                         # new packing
        logits[b] = engine.last_logits
    np.testing.assert_allclose(logits["numpy"], logits["device"],
                               atol=1e-5)


def test_post_repack_submit_cannot_alias_older_batch_pages():
    """submit(A) -> repack -> submit(B) -> run: B's fresh generation must
    not launder A's stale page ids past the guard (the generation rides
    on each batch).  Device logits must equal ground truth from the
    updated store for both batches."""
    task, store, heads = _scenario()
    server = WeightServer(store, store.num_pages(),
                          storage=StorageModel("dram"), backend="device")
    engine = EmbeddingServingEngine(server, heads)
    _run_batches(engine, task, 3, batches=3)              # warm
    docs_a, _ = task.sample(16, variant=0, seed=77)
    docs_b, _ = task.sample(16, variant=1, seed=78)
    engine.submit("word2vec-v0", docs_a)                  # old packing
    store.update("word2vec-v0",
                 {"embedding": task.variant_embedding(0) + 0.125})
    engine.submit("word2vec-v1", docs_b)                  # new packing
    out = {}
    for _ in range(2):
        batch = engine.scheduler.next_batch(server.pool.resident_pages())
        engine._infer(batch)
        out[batch.model] = engine.last_logits
    for model, docs in (("word2vec-v0", docs_a), ("word2vec-v1", docs_b)):
        emb = store.materialize(model, "embedding")
        expect = emb[docs].mean(axis=1) @ heads[model]
        np.testing.assert_allclose(out[model], expect, atol=1e-5)


def test_materialize_rows_matches_full_materialize():
    """Vectorized materialize_rows (satellite) == full materialization,
    including ragged column edges."""
    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(16, 16),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=4))
    rng = np.random.default_rng(3)
    w = rng.standard_normal((70, 40)).astype(np.float32)   # ragged both dims
    store.register("m0", {"w": w})
    rows = np.array([0, 1, 15, 16, 63, 69])
    got = store.materialize_rows("m0", "w", rows)
    np.testing.assert_allclose(got, store.materialize("m0", "w")[rows])


# ------------------------------------------------------------- serve stats --
def test_makespan_refuses_zero_overlapped_timeline():
    s = ServeStats(overlapped=True, batches=3, fetch_seconds=1.0)
    with pytest.raises(RuntimeError):
        s.makespan_seconds
    s.timeline_seconds = 2.0
    assert s.makespan_seconds == 2.0
    serial = ServeStats(batches=3, fetch_seconds=1.0, compute_seconds=0.5)
    assert serial.makespan_seconds == pytest.approx(1.5)


def test_device_matmul_and_tensor_match_dense():
    """dedup_matmul / on-device unblock against the slab == dense math."""
    store = ModelStore(StoreConfig(
        dedup=DedupConfig(block_shape=(16, 16),
                          lsh=LSHConfig(num_bands=8, rows_per_band=2,
                                        r=8.0, collision_threshold=6),
                          validate=False),
        blocks_per_page=4))
    rng = np.random.default_rng(0)
    base = rng.standard_normal((64, 40)).astype(np.float32)
    store.register("m0", {"w": base})
    store.register("m1", {"w": base + 1e-5})
    x = rng.standard_normal((8, 64)).astype(np.float32)
    for km in ("host", "pallas"):
        server = WeightServer(store, store.num_pages(),
                              storage=StorageModel("dram"),
                              backend="device", kernel_mode=km)
        server.access_pages("m1", store.model_pages("m1"))
        dense = store.materialize("m1", "w")
        y = server.device_matmul("m1", "w", x)
        np.testing.assert_allclose(np.asarray(y), x @ dense,
                                   rtol=1e-4, atol=1e-4)
        t = server.device_tensor("m1", "w")
        np.testing.assert_allclose(np.asarray(t), dense, atol=1e-6)
